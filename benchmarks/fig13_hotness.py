"""Paper Fig. 13 — time-series memory-access hotness (BERT inference).

Fine-grained access records from an instrumented run are reduced on device
into a [time-bin × 2 MiB-block] hotness matrix; blocks split into
persistent-hot (pin/prefetch candidates — long-lived params) vs bursty
(proactive-eviction candidates — transient activations), the paper's
prefetch-policy input.
"""

from __future__ import annotations

import repro.core as pasta
from repro.core.pool import CHUNK_ALIGN
from .common import instrumented_inference, row, save


def main() -> list:
    steps = 6
    # time unit = training/inference step; block = 16 KiB (scaled-down
    # analogue of the paper's 2 MiB UVM blocks at reduced model scale)
    hot_cfg = {"base": CHUNK_ALIGN, "n_blocks": 256, "n_tbins": steps,
               "t_max": float(steps), "block_shift": 5}
    tool = pasta.HotnessTool(n_tbins=steps, n_blocks=256, hot_frac=0.75)
    _session, reports = instrumented_inference(
        "paper-bert", fine=True, tools=[tool], hotness=hot_cfg, steps=steps)
    rep = reports["hotness"].data
    n_pers = len(rep["persistent_blocks"])
    n_burst = len(rep["bursty_blocks"])
    save("fig13_hotness", rep)
    return [row("fig13_hotness[paper-bert]", 0.0,
                f"persistent={n_pers};bursty={n_burst};"
                f"cold={rep['cold_blocks']};"
                f"accesses={rep['total_accesses']}")]


if __name__ == "__main__":
    main()
