"""Serving occupancy sweep + chunked-prefill stall bound.

The simulation-first xPU-analysis argument (Fake Runs, Real Fixes): batch
occupancy and goodput are THE serving quantities, so measure them under a
controlled trace instead of eyeballing throughput.  Two sections:

* **Occupancy sweep** — a fixed staggered shared-prefix trace (ragged
  prompts, one mid-flight arrival wave) runs against ``max_slots ∈ {1, 2,
  4}``; for each point the fleet ``serving`` tool reports mean decode
  occupancy, token throughput, TTFT/TPOT, prefix-cache hit rate, and the
  paged pool's duplicate-copy bytes (asserted zero: the prefix store
  aliases pool blocks).  More slots must monotonically raise mean occupancy
  (that's the continuous-batching contract — asserted), and the
  shared-prefix workload must produce nonzero prefix reuse.

* **Chunked prefill** — one long cold prompt lands next to short decoding
  requests, chunked vs unchunked.  Chunking must bound the prefill work any
  single decode tick absorbs to one chunk (token bound asserted — it is
  deterministic), and the measured per-tick stall seconds are recorded so
  the snapshot shows the longest decode-tick stall staying below one
  whole-prompt prefill.

* **Speculative sweep** — the same shared-prefix trace with long
  generations (greedy decode settles into repetitive continuations the
  n-gram proposer exploits) runs at draft depth ``k ∈ {0, 2, 4}``.
  Asserted: outputs byte-identical across every ``k`` (speculation is a
  scheduling change, never a sampling change), tokens-per-decode-tick > 1
  at ``k=4`` (the whole point of multi-token verify), fewer decode
  dispatches than ``k=0``, and the pool's block accounting balanced after
  the rollback-heavy run.  Wall-clock tok/s per point is snapshotted; the
  ``k=4`` speedup is reported rather than asserted (CI machines vary).

* **Policy sweep** — the two-tenant SLO scenario: a batch tenant (``lo``,
  long generations, lax TTFT target) floods both slots, then a
  latency-sensitive tenant (``hi``, short generations, tight TTFT target,
  priority 5) bursts in.  A calibration FCFS pass sets the ``hi`` TTFT
  target at half its FCFS p50, then FCFS / priority / EDF run the
  identical workload.  Asserted: outputs byte-identical across policies
  (scheduling must never change sampling), priority preempts (> 0) and
  recovers parked blocks through the prefix store with zero duplicate
  copies, ``hi`` SLO attainment under priority strictly beats FCFS (and
  EDF is no worse), ``hi`` TTFT p90 drops under both, and total goodput
  (tokens from SLO-meeting requests per wall second) stays within 10% of
  FCFS — preempted work is parked, not lost.

* **Chaos sweep** — the same trace runs fault-free and under the seeded
  ``transient`` / ``storm`` / ``one-poison`` chaos presets.  Asserted:
  zero innocent-request loss with byte-identical innocent outputs in
  every scenario, exactly one ``failed`` request under the persistent
  poison, balanced pool accounting and zero duplicate KV copies after
  recovery.  The chaos/fault-free goodput ratio is snapshotted.

Part of ``benchmarks.run --smoke``; payload snapshotted to
``BENCH_serve.json`` at the repo root for the per-PR perf trajectory.
"""

from __future__ import annotations

import time

import numpy as np

from . import common

SLOT_SWEEP = (1, 2, 4)
N_REQUESTS = 8
MAX_NEW = 8
SHARED_PREFIX = 24
PREFIX_BLOCK = 8


def _trace(cfg, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (SHARED_PREFIX,),
                          dtype=np.int32)
    lens = rng.integers(4, 17, N_REQUESTS)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, (int(n),),
                                         dtype=np.int32)])
            for n in lens]


def occupancy_sweep(arch: str = "paper-gpt2") -> dict:
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _trace(cfg)
    sp = SamplingParams(max_new_tokens=MAX_NEW)

    points = []
    for slots in SLOT_SWEEP:
        with pasta.Session(tools="serving", name=f"bench/slots{slots}") \
                as sess:
            eng = ServeEngine(cfg, params, max_seq=64, max_slots=slots,
                              session=sess, prefix_block=PREFIX_BLOCK)
            t0 = time.perf_counter()
            for p in prompts[:5]:
                eng.submit(p, sp)
            eng.step()
            for p in prompts[5:]:
                eng.submit(p, sp)
            while eng.sched.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        point = {
            "max_slots": slots,
            "wall_s": wall,
            "tok_per_s": rep["generated_tokens"] / wall,
            "occupancy_mean": rep["occupancy"]["mean"],
            "decode_steps": rep["decode_steps"],
            "ttft_p50_s": rep["ttft_s"]["p50"],
            "tpot_p50_s": rep["tpot_s"]["p50"],
            "prefix_hit_rate": rep["prefix_cache"]["hit_rate"],
            "prefix_reused_frac": rep["prefix_cache"]["reused_frac"],
            "pool_utilization_max": rep["pool"]["utilization_max"],
            "duplicate_copy_bytes": rep["pool"]["duplicate_copy_bytes"],
        }
        points.append(point)
        common.row(f"serve_slots{slots}",
                   wall * 1e6 / rep["generated_tokens"],
                   f"occ={point['occupancy_mean']:.2f} "
                   f"hit={point['prefix_hit_rate']:.2f}")

    occ = [p["occupancy_mean"] for p in points]
    assert occ == sorted(occ), f"occupancy must rise with slots: {occ}"
    assert occ[-1] > 1, occ
    assert any(p["prefix_hit_rate"] > 0 for p in points), points
    assert all(p["duplicate_copy_bytes"] == 0 for p in points), points
    return {
        "arch": arch, "n_requests": N_REQUESTS, "max_new_tokens": MAX_NEW,
        "shared_prefix": SHARED_PREFIX, "sweep": points,
    }


LONG_PROMPT = 96
CHUNK = 16


def chunked_prefill(arch: str = "paper-gpt2") -> dict:
    """One long cold prompt beside short decoders, chunked vs unchunked."""
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    long_p = rng.integers(0, cfg.vocab_size, (LONG_PROMPT,), dtype=np.int32)
    shorts = [rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
              for _ in range(2)]

    points = {}
    for label, chunk in (("unchunked", None), ("chunked", CHUNK)):
        with pasta.Session(tools="serving", name=f"bench/{label}") as sess:
            eng = ServeEngine(cfg, params, max_seq=128, max_slots=3,
                              session=sess, prefix_block=8,
                              prefill_chunk=chunk)
            t0 = time.perf_counter()
            for p in shorts:
                eng.submit(p, SamplingParams(max_new_tokens=16))
            eng.step()                 # shorts admit + start decoding first
            eng.submit(long_p, SamplingParams(max_new_tokens=8))
            while eng.sched.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        points[label] = {
            "prefill_chunk": chunk,
            "wall_s": wall,
            "max_prefill_tokens_per_tick":
                rep["prefill"]["max_tokens_per_tick"],
            "max_prefill_stall_s": rep["prefill"]["max_stall_s"],
            "chunked_events": rep["prefill"]["chunked_events"],
            "occupancy_mean": rep["occupancy"]["mean"],
        }
        common.row(f"serve_prefill_{label}",
                   points[label]["max_prefill_stall_s"] * 1e6,
                   f"max_tokens/tick={points[label]['max_prefill_tokens_per_tick']}")

    # the token bound is deterministic: chunking caps per-tick prefill work
    # at one chunk, the unchunked run absorbs the whole prompt in one tick
    assert points["chunked"]["max_prefill_tokens_per_tick"] <= CHUNK
    assert points["unchunked"]["max_prefill_tokens_per_tick"] >= LONG_PROMPT
    # stall seconds are recorded (timing, not asserted: CI machines vary)
    return points


SPEC_SWEEP = (0, 2, 4)
SPEC_MAX_NEW = 256          # long tails: greedy decode goes repetitive and
SPEC_MAX_SEQ = 320          # prompt-lookup acceptance climbs with position
SPEC_SLOTS = 4


def spec_sweep(arch: str = "paper-gpt2") -> dict:
    """Draft depth ``k ∈ {0, 2, 4}`` on the shared-prefix trace: byte-
    identical outputs, >1 committed token per decode tick, balanced pool."""
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _trace(cfg)
    sp = SamplingParams(max_new_tokens=SPEC_MAX_NEW)

    def one(k):
        with pasta.Session(tools="serving", name=f"bench/spec{k}") as sess:
            eng = ServeEngine(cfg, params, max_seq=SPEC_MAX_SEQ,
                              max_slots=SPEC_SLOTS, session=sess,
                              prefix_block=PREFIX_BLOCK, spec_decode=k)
            eng.warmup(sorted({len(p) for p in prompts}))
            t0 = time.perf_counter()
            for p in prompts[:5]:
                eng.submit(p, sp)
            eng.step()
            for p in prompts[5:]:
                eng.submit(p, sp)
            while eng.sched.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        outs = {rid: list(eng.requests[rid].tokens) for rid in eng.requests}
        eng.pool.scrub()
        st = eng.pool.stats()
        assert (st["blocks_live"] + st["blocks_evictable"]
                + st["blocks_free"] == st["n_blocks"]), st
        return wall, rep, outs

    points, outputs = [], {}
    for k in SPEC_SWEEP:
        one(k)                              # warm timing run
        wall, rep, outs = one(k)
        outputs[k] = outs
        spec = rep["speculative"]
        points.append({
            "spec_k": k,
            "wall_s": wall,
            "tok_per_s": rep["generated_tokens"] / wall,
            "decode_steps": rep["decode_steps"],
            "tokens_per_tick": spec["tokens_per_tick"],
            "acceptance_rate": spec["acceptance_rate"],
            "drafted_tokens": spec["drafted_tokens"],
            "accepted_tokens": spec["accepted_tokens"],
            "draft_overhead_s": spec["draft_overhead_s"],
            "analytic_bytes_per_token":
                rep["bandwidth"]["analytic_bytes_per_token"],
        })
        common.row(f"serve_spec_k{k}",
                   wall * 1e6 / rep["generated_tokens"],
                   f"tok/tick={spec['tokens_per_tick']:.2f} "
                   f"acc={spec['acceptance_rate']:.2f}")

    base, deep = points[0], points[-1]
    # speculation must never change output — only how it is scheduled
    for k in SPEC_SWEEP[1:]:
        assert outputs[k] == outputs[0], \
            f"spec k={k} output diverged from non-speculative decode"
    assert deep["tokens_per_tick"] > 1, points
    assert deep["decode_steps"] < base["decode_steps"], points
    assert deep["acceptance_rate"] > 0, points
    # analytic bandwidth: fewer dispatches per committed token must shrink
    # the modeled params traffic per token
    assert (deep["analytic_bytes_per_token"]
            < base["analytic_bytes_per_token"]), points
    speedup = deep["tok_per_s"] / base["tok_per_s"]
    return {"max_new_tokens": SPEC_MAX_NEW, "max_slots": SPEC_SLOTS,
            "sweep": points, "speedup_k4": speedup}


POLICY_SLOTS = 2
POLICY_CHUNK = 16
POLICY_MAX_SEQ = 160
LO_N, HI_N = 4, 4
LO_NEW, HI_NEW = 96, 8
HI_DELAY_TICKS = 8          # hi tenant bursts in once lo is decoding


def _two_tenant_prompts(cfg, seed=2):
    """Shared-prefix prompt pools for the batch (lo) and latency (hi)
    tenants; deterministic in the seed."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)

    def pool(n, lens):
        return [np.concatenate([prefix,
                                rng.integers(0, cfg.vocab_size, (int(k),),
                                             dtype=np.int32)])
                for k in lens]

    return (pool(LO_N, rng.integers(16, 25, LO_N)),
            pool(HI_N, rng.integers(8, 17, HI_N)))


def policy_sweep(arch: str = "paper-gpt2") -> dict:
    """FCFS vs priority vs EDF on the two-tenant burst: byte-identical
    outputs, hi-tenant SLO attainment up, goodput within 10% of FCFS."""
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine, SLOSpec

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    lo_prompts, hi_prompts = _two_tenant_prompts(cfg)

    def one(policy, hi_ttft_s):
        lo_slo = SLOSpec(ttft_target_s=60.0, tenant="lo", priority=0)
        hi_slo = SLOSpec(ttft_target_s=hi_ttft_s, tenant="hi", priority=5)
        with pasta.Session(tools="serving", name=f"bench/{policy}") as sess:
            eng = ServeEngine(cfg, params, max_seq=POLICY_MAX_SEQ,
                              max_slots=POLICY_SLOTS, session=sess,
                              prefix_block=PREFIX_BLOCK,
                              prefill_chunk=POLICY_CHUNK, policy=policy)
            # warm every pow2 prefill bucket a chunk or a resumed suffix
            # can hit, so no XLA compile lands inside the measured span
            # (compile stalls would swamp the policy-to-policy goodput
            # comparison at this reduced scale)
            lens = {len(p) for p in lo_prompts + hi_prompts}
            eng.warmup(sorted(lens | {1 << i for i in range(7)}))
            for p in lo_prompts:
                eng.submit(p, SamplingParams(max_new_tokens=LO_NEW),
                           slo=lo_slo)
            for _ in range(HI_DELAY_TICKS):
                eng.step()
            for p in hi_prompts:
                eng.submit(p, SamplingParams(max_new_tokens=HI_NEW),
                           slo=hi_slo)
            while eng.sched.has_work:
                eng.step()
        rep = sess.reports()["serving"].data
        outs = {rid: list(eng.requests[rid].tokens) for rid in eng.requests}
        eng.pool.scrub()
        st = eng.pool.stats()
        assert (st["blocks_live"] + st["blocks_evictable"]
                + st["blocks_free"] == st["n_blocks"]), st
        return rep, outs

    # calibration: the hi TTFT target is half what FCFS delivers, so FCFS
    # misses it and any policy that actually reorders can meet it
    cal, _ = one("fcfs", None)
    hi_ttft_s = cal["tenants"]["hi"]["ttft_s"]["p50"] * 0.5

    points, outputs = [], {}
    for policy in ("fcfs", "priority", "edf"):
        # best-of-2: the measured span is fractions of a second, so a
        # single scheduler hiccup skews goodput by 20%+ — and the repeat
        # doubles as a determinism check on the sampled tokens
        rep, outs = None, None
        for _ in range(2):
            r, o = one(policy, hi_ttft_s)
            assert outs is None or o == outs, \
                f"{policy} outputs changed across repeats"
            outs = o
            if (rep is None or r["slo"]["goodput_tok_per_s"]
                    > rep["slo"]["goodput_tok_per_s"]):
                rep = r
        outputs[policy] = outs
        hi, lo = rep["tenants"]["hi"], rep["tenants"]["lo"]
        points.append({
            "policy": policy,
            "good_tokens": rep["slo"]["good_tokens"],
            "goodput_tok_per_s": rep["slo"]["goodput_tok_per_s"],
            "slo_attainment": rep["slo"]["attainment"],
            "jain_fairness": rep["slo"]["jain_fairness"],
            "hi_attainment": hi["slo_attainment"],
            "lo_attainment": lo["slo_attainment"],
            "hi_ttft_p50_s": hi["ttft_s"]["p50"],
            "hi_ttft_p90_s": hi["ttft_s"]["p90"],
            "lo_ttft_p90_s": lo["ttft_s"]["p90"],
            "preemptions": rep["preemption"]["count"],
            "recovered_blocks": rep["preemption"]["recovered_blocks"],
            "duplicate_copy_bytes": rep["pool"]["duplicate_copy_bytes"],
            "decode_steps": rep["decode_steps"],
        })
        common.row(f"serve_policy_{policy}",
                   points[-1]["hi_ttft_p90_s"] * 1e6,
                   f"hi_attain={hi['slo_attainment']:.2f} "
                   f"goodput={points[-1]['goodput_tok_per_s']:.0f}tok/s")

    by = {p["policy"]: p for p in points}
    fcfs, pri, edf = by["fcfs"], by["priority"], by["edf"]
    # scheduling must never change what is sampled, only when
    for policy in ("priority", "edf"):
        assert outputs[policy] == outputs["fcfs"], \
            f"{policy} outputs diverged from fcfs"
    # priority preempts, parks KV in the prefix store, aliases it back
    assert pri["preemptions"] > 0 and pri["recovered_blocks"] > 0, pri
    assert all(p["duplicate_copy_bytes"] == 0 for p in points), points
    # the calibrated target: FCFS misses it, priority meets it
    assert fcfs["hi_attainment"] <= 0.5, fcfs
    assert pri["hi_attainment"] >= 0.75, pri
    assert pri["hi_attainment"] > fcfs["hi_attainment"], (pri, fcfs)
    assert edf["hi_attainment"] >= fcfs["hi_attainment"], (edf, fcfs)
    assert pri["hi_ttft_p90_s"] < fcfs["hi_ttft_p90_s"], (pri, fcfs)
    assert edf["hi_ttft_p90_s"] < fcfs["hi_ttft_p90_s"], (edf, fcfs)
    # reordering serves the same tokens, so SLO-good tokens can only grow
    # (deterministic) and wall goodput must hold within 10% (timing)
    for p in (pri, edf):
        assert p["good_tokens"] >= fcfs["good_tokens"], (p, fcfs)
        assert (p["goodput_tok_per_s"]
                >= 0.9 * fcfs["goodput_tok_per_s"]), (p, fcfs)
    return {"hi_ttft_target_s": hi_ttft_s, "max_slots": POLICY_SLOTS,
            "lo_new": LO_NEW, "hi_new": HI_NEW, "sweep": points}


CHAOS_MAX_NEW = 16
CHAOS_SLOTS = 4
CHAOS_SEED = 0
CHAOS_PRESETS = ("transient", "storm", "one-poison")


def chaos_sweep(arch: str = "paper-gpt2") -> dict:
    """Fault-free twin vs seeded chaos presets on the identical trace.

    Asserted: under ``transient``/``storm`` zero requests are lost and
    every output is byte-identical to the fault-free twin; under
    ``one-poison`` exactly the poisoned request ends ``failed`` while
    every innocent finishes byte-identically; pool block accounting
    balances after every run and no recovery path copies KV bytes.  The
    chaos/fault-free goodput ratio is snapshotted (timing, not asserted:
    stall windows are real wall time)."""
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _trace(cfg, seed=3)
    sp = SamplingParams(max_new_tokens=CHAOS_MAX_NEW)

    def one(preset):
        with pasta.Session(tools="serving",
                           name=f"bench/chaos-{preset or 'off'}") as sess:
            eng = ServeEngine(cfg, params, max_seq=64, max_slots=CHAOS_SLOTS,
                              session=sess, prefix_block=PREFIX_BLOCK,
                              faults=preset, fault_seed=CHAOS_SEED)
            eng.warmup(sorted({len(p) for p in prompts}))
            t0 = time.perf_counter()
            for p in prompts[:5]:
                eng.submit(p, sp)
            eng.step()
            for p in prompts[5:]:
                eng.submit(p, sp)
            while eng.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        outs = {rid: list(eng.requests[rid].tokens) for rid in eng.requests}
        states = {rid: eng.requests[rid].state.value
                  for rid in eng.requests}
        eng.pool.scrub()
        st = eng.pool.stats()
        assert (st["blocks_live"] + st["blocks_evictable"]
                + st["blocks_free"] == st["n_blocks"]), st
        assert rep["pool"]["duplicate_copy_bytes"] == 0, rep["pool"]
        return wall, rep, outs, states, eng.health()

    base_wall, base_rep, base_outs, base_states, _ = one(None)
    assert all(s == "finished" for s in base_states.values()), base_states
    base_tok_s = base_rep["generated_tokens"] / base_wall

    points = []
    for preset in CHAOS_PRESETS:
        wall, rep, outs, states, health = one(preset)
        failed = sorted(r for r, s in states.items() if s == "failed")
        innocents = [r for r in states if r not in failed]
        # recovery contract: innocents are never lost and never perturbed
        assert all(states[r] == "finished" for r in innocents), states
        assert all(outs[r] == base_outs[r] for r in innocents), \
            f"{preset}: innocent outputs diverged from fault-free twin"
        if preset == "one-poison":
            assert len(failed) == 1, states    # exactly the poisoned rid
        else:
            assert not failed, states          # zero loss
        assert health["faults_fired"] > 0, health
        good_tokens = sum(len(outs[r]) for r in innocents)
        points.append({
            "preset": preset,
            "wall_s": wall,
            "goodput_ratio": (good_tokens / wall) / base_tok_s,
            "failed": failed,
            "fault_ticks": health["fault_ticks"],
            "tick_retries": health["tick_retries"],
            "request_retries": health["request_retries"],
            "isolated_innocents": health["isolated_innocents"],
            "probes": health["probes"],
            "recovered_tokens": health["recovered_tokens"],
            "recomputed_tokens": health["recomputed_tokens"],
            "faults_fired": health["faults_fired"],
        })
        common.row(f"serve_chaos_{preset}",
                   wall * 1e6 / max(good_tokens, 1),
                   f"goodput_ratio={points[-1]['goodput_ratio']:.2f} "
                   f"failed={len(failed)}")

    return {"fault_free_tok_per_s": base_tok_s, "wall_s": base_wall,
            "seed": CHAOS_SEED, "sweep": points}


def main(**kw) -> dict:
    payload = occupancy_sweep(**kw)
    payload["chunked_prefill"] = chunked_prefill(**kw)
    payload["spec_sweep"] = spec_sweep(**kw)
    payload["policy_sweep"] = policy_sweep(**kw)
    payload["chaos_sweep"] = chaos_sweep(**kw)
    common.save("fig_serve", payload)
    return payload


if __name__ == "__main__":
    main()
    from . import run
    run.snapshot()        # refresh the repo-root BENCH_serve.json snapshot
