"""Serving occupancy sweep + chunked-prefill stall bound.

The simulation-first xPU-analysis argument (Fake Runs, Real Fixes): batch
occupancy and goodput are THE serving quantities, so measure them under a
controlled trace instead of eyeballing throughput.  Two sections:

* **Occupancy sweep** — a fixed staggered shared-prefix trace (ragged
  prompts, one mid-flight arrival wave) runs against ``max_slots ∈ {1, 2,
  4}``; for each point the fleet ``serving`` tool reports mean decode
  occupancy, token throughput, TTFT/TPOT, prefix-cache hit rate, and the
  paged pool's duplicate-copy bytes (asserted zero: the prefix store
  aliases pool blocks).  More slots must monotonically raise mean occupancy
  (that's the continuous-batching contract — asserted), and the
  shared-prefix workload must produce nonzero prefix reuse.

* **Chunked prefill** — one long cold prompt lands next to short decoding
  requests, chunked vs unchunked.  Chunking must bound the prefill work any
  single decode tick absorbs to one chunk (token bound asserted — it is
  deterministic), and the measured per-tick stall seconds are recorded so
  the snapshot shows the longest decode-tick stall staying below one
  whole-prompt prefill.

* **Speculative sweep** — the same shared-prefix trace with long
  generations (greedy decode settles into repetitive continuations the
  n-gram proposer exploits) runs at draft depth ``k ∈ {0, 2, 4}``.
  Asserted: outputs byte-identical across every ``k`` (speculation is a
  scheduling change, never a sampling change), tokens-per-decode-tick > 1
  at ``k=4`` (the whole point of multi-token verify), fewer decode
  dispatches than ``k=0``, and the pool's block accounting balanced after
  the rollback-heavy run.  Wall-clock tok/s per point is snapshotted; the
  ``k=4`` speedup is reported rather than asserted (CI machines vary).

Part of ``benchmarks.run --smoke``; payload snapshotted to
``BENCH_serve.json`` at the repo root for the per-PR perf trajectory.
"""

from __future__ import annotations

import time

import numpy as np

from . import common

SLOT_SWEEP = (1, 2, 4)
N_REQUESTS = 8
MAX_NEW = 8
SHARED_PREFIX = 24
PREFIX_BLOCK = 8


def _trace(cfg, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (SHARED_PREFIX,),
                          dtype=np.int32)
    lens = rng.integers(4, 17, N_REQUESTS)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, (int(n),),
                                         dtype=np.int32)])
            for n in lens]


def occupancy_sweep(arch: str = "paper-gpt2") -> dict:
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _trace(cfg)
    sp = SamplingParams(max_new_tokens=MAX_NEW)

    points = []
    for slots in SLOT_SWEEP:
        with pasta.Session(tools="serving", name=f"bench/slots{slots}") \
                as sess:
            eng = ServeEngine(cfg, params, max_seq=64, max_slots=slots,
                              session=sess, prefix_block=PREFIX_BLOCK)
            t0 = time.perf_counter()
            for p in prompts[:5]:
                eng.submit(p, sp)
            eng.step()
            for p in prompts[5:]:
                eng.submit(p, sp)
            while eng.sched.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        point = {
            "max_slots": slots,
            "wall_s": wall,
            "tok_per_s": rep["generated_tokens"] / wall,
            "occupancy_mean": rep["occupancy"]["mean"],
            "decode_steps": rep["decode_steps"],
            "ttft_p50_s": rep["ttft_s"]["p50"],
            "tpot_p50_s": rep["tpot_s"]["p50"],
            "prefix_hit_rate": rep["prefix_cache"]["hit_rate"],
            "prefix_reused_frac": rep["prefix_cache"]["reused_frac"],
            "pool_utilization_max": rep["pool"]["utilization_max"],
            "duplicate_copy_bytes": rep["pool"]["duplicate_copy_bytes"],
        }
        points.append(point)
        common.row(f"serve_slots{slots}",
                   wall * 1e6 / rep["generated_tokens"],
                   f"occ={point['occupancy_mean']:.2f} "
                   f"hit={point['prefix_hit_rate']:.2f}")

    occ = [p["occupancy_mean"] for p in points]
    assert occ == sorted(occ), f"occupancy must rise with slots: {occ}"
    assert occ[-1] > 1, occ
    assert any(p["prefix_hit_rate"] > 0 for p in points), points
    assert all(p["duplicate_copy_bytes"] == 0 for p in points), points
    return {
        "arch": arch, "n_requests": N_REQUESTS, "max_new_tokens": MAX_NEW,
        "shared_prefix": SHARED_PREFIX, "sweep": points,
    }


LONG_PROMPT = 96
CHUNK = 16


def chunked_prefill(arch: str = "paper-gpt2") -> dict:
    """One long cold prompt beside short decoders, chunked vs unchunked."""
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    long_p = rng.integers(0, cfg.vocab_size, (LONG_PROMPT,), dtype=np.int32)
    shorts = [rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
              for _ in range(2)]

    points = {}
    for label, chunk in (("unchunked", None), ("chunked", CHUNK)):
        with pasta.Session(tools="serving", name=f"bench/{label}") as sess:
            eng = ServeEngine(cfg, params, max_seq=128, max_slots=3,
                              session=sess, prefix_block=8,
                              prefill_chunk=chunk)
            t0 = time.perf_counter()
            for p in shorts:
                eng.submit(p, SamplingParams(max_new_tokens=16))
            eng.step()                 # shorts admit + start decoding first
            eng.submit(long_p, SamplingParams(max_new_tokens=8))
            while eng.sched.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        points[label] = {
            "prefill_chunk": chunk,
            "wall_s": wall,
            "max_prefill_tokens_per_tick":
                rep["prefill"]["max_tokens_per_tick"],
            "max_prefill_stall_s": rep["prefill"]["max_stall_s"],
            "chunked_events": rep["prefill"]["chunked_events"],
            "occupancy_mean": rep["occupancy"]["mean"],
        }
        common.row(f"serve_prefill_{label}",
                   points[label]["max_prefill_stall_s"] * 1e6,
                   f"max_tokens/tick={points[label]['max_prefill_tokens_per_tick']}")

    # the token bound is deterministic: chunking caps per-tick prefill work
    # at one chunk, the unchunked run absorbs the whole prompt in one tick
    assert points["chunked"]["max_prefill_tokens_per_tick"] <= CHUNK
    assert points["unchunked"]["max_prefill_tokens_per_tick"] >= LONG_PROMPT
    # stall seconds are recorded (timing, not asserted: CI machines vary)
    return points


SPEC_SWEEP = (0, 2, 4)
SPEC_MAX_NEW = 256          # long tails: greedy decode goes repetitive and
SPEC_MAX_SEQ = 320          # prompt-lookup acceptance climbs with position
SPEC_SLOTS = 4


def spec_sweep(arch: str = "paper-gpt2") -> dict:
    """Draft depth ``k ∈ {0, 2, 4}`` on the shared-prefix trace: byte-
    identical outputs, >1 committed token per decode tick, balanced pool."""
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _trace(cfg)
    sp = SamplingParams(max_new_tokens=SPEC_MAX_NEW)

    def one(k):
        with pasta.Session(tools="serving", name=f"bench/spec{k}") as sess:
            eng = ServeEngine(cfg, params, max_seq=SPEC_MAX_SEQ,
                              max_slots=SPEC_SLOTS, session=sess,
                              prefix_block=PREFIX_BLOCK, spec_decode=k)
            eng.warmup(sorted({len(p) for p in prompts}))
            t0 = time.perf_counter()
            for p in prompts[:5]:
                eng.submit(p, sp)
            eng.step()
            for p in prompts[5:]:
                eng.submit(p, sp)
            while eng.sched.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        outs = {rid: list(eng.requests[rid].tokens) for rid in eng.requests}
        eng.pool.scrub()
        st = eng.pool.stats()
        assert (st["blocks_live"] + st["blocks_evictable"]
                + st["blocks_free"] == st["n_blocks"]), st
        return wall, rep, outs

    points, outputs = [], {}
    for k in SPEC_SWEEP:
        one(k)                              # warm timing run
        wall, rep, outs = one(k)
        outputs[k] = outs
        spec = rep["speculative"]
        points.append({
            "spec_k": k,
            "wall_s": wall,
            "tok_per_s": rep["generated_tokens"] / wall,
            "decode_steps": rep["decode_steps"],
            "tokens_per_tick": spec["tokens_per_tick"],
            "acceptance_rate": spec["acceptance_rate"],
            "drafted_tokens": spec["drafted_tokens"],
            "accepted_tokens": spec["accepted_tokens"],
            "draft_overhead_s": spec["draft_overhead_s"],
            "analytic_bytes_per_token":
                rep["bandwidth"]["analytic_bytes_per_token"],
        })
        common.row(f"serve_spec_k{k}",
                   wall * 1e6 / rep["generated_tokens"],
                   f"tok/tick={spec['tokens_per_tick']:.2f} "
                   f"acc={spec['acceptance_rate']:.2f}")

    base, deep = points[0], points[-1]
    # speculation must never change output — only how it is scheduled
    for k in SPEC_SWEEP[1:]:
        assert outputs[k] == outputs[0], \
            f"spec k={k} output diverged from non-speculative decode"
    assert deep["tokens_per_tick"] > 1, points
    assert deep["decode_steps"] < base["decode_steps"], points
    assert deep["acceptance_rate"] > 0, points
    # analytic bandwidth: fewer dispatches per committed token must shrink
    # the modeled params traffic per token
    assert (deep["analytic_bytes_per_token"]
            < base["analytic_bytes_per_token"]), points
    speedup = deep["tok_per_s"] / base["tok_per_s"]
    return {"max_new_tokens": SPEC_MAX_NEW, "max_slots": SPEC_SLOTS,
            "sweep": points, "speedup_k4": speedup}


def main(**kw) -> dict:
    payload = occupancy_sweep(**kw)
    payload["chunked_prefill"] = chunked_prefill(**kw)
    payload["spec_sweep"] = spec_sweep(**kw)
    common.save("fig_serve", payload)
    return payload


if __name__ == "__main__":
    main()
    from . import run
    run.snapshot()        # refresh the repo-root BENCH_serve.json snapshot
