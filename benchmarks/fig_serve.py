"""Serving occupancy sweep: continuous batching vs slot budget.

The simulation-first xPU-analysis argument (Fake Runs, Real Fixes): batch
occupancy and goodput are THE serving quantities, so measure them under a
controlled trace instead of eyeballing throughput.  A fixed staggered
shared-prefix trace (ragged prompts, one mid-flight arrival wave) runs
against ``max_slots ∈ {1, 2, 4}``; for each point the fleet ``serving``
tool reports mean decode occupancy, token throughput, TTFT/TPOT, and the
prefix-cache hit rate.  More slots must monotonically raise mean occupancy
(that's the continuous-batching contract — asserted), and the shared-prefix
workload must produce nonzero prefix reuse.

Part of ``benchmarks.run --smoke``; payload snapshotted to
``BENCH_serve.json`` at the repo root for the per-PR perf trajectory.
"""

from __future__ import annotations

import time

import numpy as np

from . import common

SLOT_SWEEP = (1, 2, 4)
N_REQUESTS = 8
MAX_NEW = 8
SHARED_PREFIX = 24
PREFIX_BLOCK = 8


def _trace(cfg, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (SHARED_PREFIX,),
                          dtype=np.int32)
    lens = rng.integers(4, 17, N_REQUESTS)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, (int(n),),
                                         dtype=np.int32)])
            for n in lens]


def occupancy_sweep(arch: str = "paper-gpt2") -> dict:
    import jax

    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _trace(cfg)
    sp = SamplingParams(max_new_tokens=MAX_NEW)

    points = []
    for slots in SLOT_SWEEP:
        with pasta.Session(tools="serving", name=f"bench/slots{slots}") \
                as sess:
            eng = ServeEngine(cfg, params, max_seq=64, max_slots=slots,
                              session=sess, prefix_block=PREFIX_BLOCK)
            t0 = time.perf_counter()
            for p in prompts[:5]:
                eng.submit(p, sp)
            eng.step()
            for p in prompts[5:]:
                eng.submit(p, sp)
            while eng.sched.has_work:
                eng.step()
            wall = time.perf_counter() - t0
        rep = sess.reports()["serving"].data
        point = {
            "max_slots": slots,
            "wall_s": wall,
            "tok_per_s": rep["generated_tokens"] / wall,
            "occupancy_mean": rep["occupancy"]["mean"],
            "decode_steps": rep["decode_steps"],
            "ttft_p50_s": rep["ttft_s"]["p50"],
            "tpot_p50_s": rep["tpot_s"]["p50"],
            "prefix_hit_rate": rep["prefix_cache"]["hit_rate"],
            "prefix_reused_frac": rep["prefix_cache"]["reused_frac"],
        }
        points.append(point)
        common.row(f"serve_slots{slots}",
                   wall * 1e6 / rep["generated_tokens"],
                   f"occ={point['occupancy_mean']:.2f} "
                   f"hit={point['prefix_hit_rate']:.2f}")

    occ = [p["occupancy_mean"] for p in points]
    assert occ == sorted(occ), f"occupancy must rise with slots: {occ}"
    assert occ[-1] > 1, occ
    assert any(p["prefix_hit_rate"] > 0 for p in points), points
    payload = {
        "arch": arch, "n_requests": N_REQUESTS, "max_new_tokens": MAX_NEW,
        "shared_prefix": SHARED_PREFIX, "sweep": points,
    }
    common.save("fig_serve", payload)
    return payload


def main(**kw) -> dict:
    return occupancy_sweep(**kw)


if __name__ == "__main__":
    main()
