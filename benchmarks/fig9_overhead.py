"""Paper Fig. 9 — analysis overhead: device-resident vs host-resident, plus
the coarse-grained dispatch sweep for the columnar event backbone.

The paper's headline result: GPU-resident collect-and-analyze is 627×–13006×
faster than conventional trace-to-CPU single-thread analysis.  Here the same
working-set analysis runs over identical access-record buffers through:

  * ``host``   — Fig. 2a model: one Python thread folds records one by one
    (the Compute-Sanitizer-/NVBit-CPU analysis model);
  * ``device`` — Fig. 2b model: the vectorized on-device reduction
    (XLA-compiled oracle on CPU here; the Pallas TPU kernel is the
    hardware-target form, validated in interpret mode by the tests).

``coarse_dispatch`` applies the same comparison to the coarse-grained tier
itself: one Python ``Event`` per occurrence through per-callback dispatch
(scalar ``emit``, the Compute-Sanitizer-style host-resident model) vs SoA
``EventBatch`` emission through the vectorized normalize/dispatch spine.
Reports events/sec for both and asserts the ≥10× acceptance bar at 10⁶
events (reports must also be byte-identical — checked every run).

``session_overhead`` prices the ``pasta.Session`` facade: the same batched
emission through a Session-owned pipeline vs a hand-wired
handler+processor+tool stack, at 10⁶ events.  The facade resolves nothing
on the emit path, so its dispatch overhead must stay < 5% (asserted), and
the reports must match the hand-wired pipeline exactly.

Sweeps trace volume; reports per-record cost and the speedup.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.processor import analyze_access_trace
from .common import row, save, timeit

SIZES = (100_000, 300_000, 1_000_000, 3_000_000, 10_000_000)
SMOKE_SIZES = (100_000,)
DISPATCH_SIZES = (1_000, 10_000, 100_000, 1_000_000)
# smoke keeps the expensive host-trace sweep at 100k, but the dispatch sweep
# still includes 1e6 so the ≥10× acceptance assert actually executes in CI
SMOKE_DISPATCH_SIZES = (1_000, 1_000_000)
N_OBJECTS = 512
N_KERNELS = 64
EMIT_CHUNK = 65_536


def _mk(rng, n):
    sizes = rng.integers(512, 4 << 20, size=N_OBJECTS) // 512 * 512
    starts = np.cumsum(np.concatenate([[2 << 20], sizes[:-1] + (2 << 20)]))
    ends = starts + sizes
    pick = rng.integers(0, N_OBJECTS, size=n)
    addrs = starts[pick] + rng.integers(0, sizes[pick])
    return addrs, list(zip(starts, ends))


def trace_analysis(sizes=SIZES) -> tuple:
    rng = np.random.default_rng(0)
    rows = []
    report = {}
    for n in sizes:
        addrs, objs = _mk(rng, n)
        (c_dev, _), t_dev = timeit(analyze_access_trace, addrs, objs,
                                   mode="device", repeat=3)
        reps = 1 if n > 500_000 else 2
        (c_host, _), t_host = timeit(analyze_access_trace, addrs, objs,
                                     mode="host", repeat=reps)
        assert (c_dev == c_host).all()
        speedup = t_host / t_dev
        report[n] = {"host_s": t_host, "device_s": t_dev,
                     "speedup": speedup}
        rows.append(row(f"fig9_overhead[n={n}]", t_dev / n * 1e6,
                        f"host_s={t_host:.3f};device_s={t_dev:.4f};"
                        f"speedup={speedup:.0f}x"))
    return rows, report


def coarse_dispatch(sizes=DISPATCH_SIZES) -> tuple:
    """Events/sec: scalar ``emit`` vs columnar ``emit_batch`` feeding the
    same vectorized tool stack; finalize() reports must match exactly."""
    import repro.core as pasta
    from repro.core.events import Event, EventBatch, EventKind, reset_seq

    names = [f"fusion.{i}" for i in range(N_KERNELS)]
    rows = []
    report = {}
    for n in sizes:
        name_ids = (np.arange(n, dtype=np.int32) % N_KERNELS).astype(np.int32)
        # --- scalar: one Event object + per-callback dispatch per launch --
        reset_seq()
        handler = pasta.EventHandler()
        with pasta.EventProcessor(
                handler, tools=[pasta.KernelFrequencyTool()]) as proc:
            t0 = time.perf_counter()
            for i in range(n):
                handler.emit(Event(EventKind.KERNEL_LAUNCH,
                                   name=names[i % N_KERNELS]))
            t_scalar = time.perf_counter() - t0
            rep_scalar = proc.finalize()
        # --- batched: SoA chunks through the columnar spine ---------------
        reset_seq()
        handler = pasta.EventHandler()
        with pasta.EventProcessor(
                handler, tools=[pasta.KernelFrequencyTool()]) as proc:
            t0 = time.perf_counter()
            for lo in range(0, n, EMIT_CHUNK):
                ids = name_ids[lo:lo + EMIT_CHUNK]
                handler.emit_batch(EventBatch.of(
                    EventKind.KERNEL_LAUNCH, name_ids=ids,
                    name_table=names))
            t_batch = time.perf_counter() - t0
            rep_batch = proc.finalize()
        assert rep_batch == rep_scalar, "batched report diverged from scalar"
        speedup = t_scalar / t_batch
        report[n] = {
            "scalar_s": t_scalar, "batched_s": t_batch,
            "scalar_events_per_s": n / t_scalar,
            "batched_events_per_s": n / t_batch,
            "speedup": speedup,
        }
        rows.append(row(
            f"fig9_coarse_dispatch[n={n}]", t_batch / n * 1e6,
            f"scalar_evps={n / t_scalar:.0f};"
            f"batched_evps={n / t_batch:.0f};speedup={speedup:.1f}x"))
        if n >= 1_000_000:
            assert speedup >= 10.0, (
                f"batched dispatch only {speedup:.1f}x at n={n}")
    return rows, report


def session_overhead(n: int = 1_000_000, repeats: int = 5) -> tuple:
    """Facade overhead: Session-wrapped vs hand-wired pipeline at ``n``
    events.  Both drive identical SoA chunks through an identical
    handler→processor→KernelFrequencyTool stack; the only difference is who
    wired it.  Asserts < 5% dispatch overhead and byte-identical reports."""
    import repro.core as pasta
    from repro.core.events import EventBatch, EventKind, reset_seq

    names = [f"fusion.{i}" for i in range(N_KERNELS)]
    name_ids = (np.arange(n, dtype=np.int32) % N_KERNELS).astype(np.int32)

    def drive(handler):
        t0 = time.perf_counter()
        for lo in range(0, n, EMIT_CHUNK):
            handler.emit_batch(EventBatch.of(
                EventKind.KERNEL_LAUNCH,
                name_ids=name_ids[lo:lo + EMIT_CHUNK], name_table=names))
        return time.perf_counter() - t0

    def run_handwired():
        reset_seq()
        handler = pasta.EventHandler()
        with pasta.EventProcessor(
                handler, tools=[pasta.KernelFrequencyTool()]) as proc:
            t = drive(handler)
            return t, proc.finalize()["KernelFrequencyTool"]

    def run_session():
        reset_seq()
        with pasta.Session(tools="kernel_freq") as sess:
            t = drive(sess.handler)
        rep = sess.reports()["kernel_freq"].data
        sess.close()
        return t, rep

    best_hand = best_sess = float("inf")
    rep_hand = rep_sess = None
    for attempt in range(3):        # widen repeats if a noisy run trips 5%
        for _ in range(repeats):    # interleave to decorrelate noise
            t_h, rep_hand = run_handwired()
            t_s, rep_sess = run_session()
            best_hand = min(best_hand, t_h)
            best_sess = min(best_sess, t_s)
        if best_sess / best_hand - 1.0 < 0.05:
            break
    assert rep_sess == rep_hand, "session report diverged from hand-wired"
    overhead = best_sess / best_hand - 1.0
    assert overhead < 0.05, (
        f"Session facade overhead {overhead * 100:.1f}% >= 5% at n={n}")
    report = {n: {"handwired_s": best_hand, "session_s": best_sess,
                  "handwired_events_per_s": n / best_hand,
                  "session_events_per_s": n / best_sess,
                  "overhead_frac": overhead}}
    rows = [row(f"fig9_session_overhead[n={n}]", best_sess / n * 1e6,
                f"handwired_evps={n / best_hand:.0f};"
                f"session_evps={n / best_sess:.0f};"
                f"overhead={overhead * 100:.2f}%")]
    return rows, report


def main(sizes=SIZES, dispatch_sizes=DISPATCH_SIZES) -> list:
    rows, trace_report = trace_analysis(sizes)
    d_rows, dispatch_report = coarse_dispatch(dispatch_sizes)
    rows += d_rows
    s_rows, session_report = session_overhead()
    rows += s_rows
    payload = dict(trace_report)
    payload["coarse_dispatch"] = dispatch_report
    payload["session_overhead"] = session_report
    save("fig9_overhead", payload)
    return rows


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    main(sizes=SMOKE_SIZES if smoke else SIZES,
         dispatch_sizes=SMOKE_DISPATCH_SIZES if smoke else DISPATCH_SIZES)
