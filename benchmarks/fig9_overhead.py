"""Paper Fig. 9 — analysis overhead: device-resident vs host-resident.

The paper's headline result: GPU-resident collect-and-analyze is 627×–13006×
faster than conventional trace-to-CPU single-thread analysis.  Here the same
working-set analysis runs over identical access-record buffers through:

  * ``host``   — Fig. 2a model: one Python thread folds records one by one
    (the Compute-Sanitizer-/NVBit-CPU analysis model);
  * ``device`` — Fig. 2b model: the vectorized on-device reduction
    (XLA-compiled oracle on CPU here; the Pallas TPU kernel is the
    hardware-target form, validated in interpret mode by the tests).

Sweeps trace volume; reports per-record cost and the speedup.
"""

from __future__ import annotations

import numpy as np

from repro.core.processor import analyze_access_trace
from .common import row, save, timeit

SIZES = (100_000, 300_000, 1_000_000, 3_000_000, 10_000_000)
N_OBJECTS = 512


def _mk(rng, n):
    sizes = rng.integers(512, 4 << 20, size=N_OBJECTS) // 512 * 512
    starts = np.cumsum(np.concatenate([[2 << 20], sizes[:-1] + (2 << 20)]))
    ends = starts + sizes
    pick = rng.integers(0, N_OBJECTS, size=n)
    addrs = starts[pick] + rng.integers(0, sizes[pick])
    return addrs, list(zip(starts, ends))


def main() -> list:
    rng = np.random.default_rng(0)
    rows = []
    report = {}
    for n in SIZES:
        addrs, objs = _mk(rng, n)
        (c_dev, _), t_dev = timeit(analyze_access_trace, addrs, objs,
                                   mode="device", repeat=3)
        reps = 1 if n > 500_000 else 2
        (c_host, _), t_host = timeit(analyze_access_trace, addrs, objs,
                                     mode="host", repeat=reps)
        assert (c_dev == c_host).all()
        speedup = t_host / t_dev
        report[n] = {"host_s": t_host, "device_s": t_dev,
                     "speedup": speedup}
        rows.append(row(f"fig9_overhead[n={n}]", t_dev / n * 1e6,
                        f"host_s={t_host:.3f};device_s={t_dev:.4f};"
                        f"speedup={speedup:.0f}x"))
    save("fig9_overhead", report)
    return rows


if __name__ == "__main__":
    main()
