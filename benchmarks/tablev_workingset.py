"""Paper Table V — memory footprint vs working set across models.

Instrumented eager inference of six models; per-operator accessed bytes are
access-verified (fine-grained trace aggregated on device, so operands that
are never read don't count).  Reports footprint, WS max/min/avg/median/p90 —
the paper's headline: working sets are far smaller than footprints.
"""

from __future__ import annotations

from .common import instrumented_inference, row, save

MODELS = ("paper-gpt2", "paper-bert", "mamba2-2.7b", "glm4-9b",
          "dbrx-132b", "musicgen-large")


def main() -> list:
    rows = []
    table = {}
    for arch in MODELS:
        _session, reports = instrumented_inference(arch, tools="workingset")
        ws = reports["workingset"].data
        table[arch] = ws
        ratio = ws["footprint_mb"] / max(ws["working_set_mb"], 1e-9)
        rows.append(row(
            f"tablev_workingset[{arch}]", 0.0,
            f"footprint={ws['footprint_mb']:.1f}MB;"
            f"ws={ws['working_set_mb']:.1f}MB;ratio={ratio:.2f};"
            f"median={ws['median_ws_mb']:.2f};p90={ws['p90_ws_mb']:.2f}"))
    avg_ratio = sum(t["footprint_mb"] / max(t["working_set_mb"], 1e-9)
                    for t in table.values()) / len(table)
    rows.append(row("tablev_workingset[avg]", 0.0,
                    f"avg_footprint_to_ws={avg_ratio:.2f}"))
    save("tablev_workingset", table)
    return rows


if __name__ == "__main__":
    main()
