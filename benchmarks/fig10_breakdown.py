"""Paper Fig. 10 — profiling-time breakdown: workload / collection /
transfer / analysis, for the device-resident vs host-resident models.

In the device path collection+analysis fuse (the paper notes the same);
the host path pays a trace-transfer phase plus the dominant single-thread
analysis phase.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.processor import _host_analyze
from repro.kernels import ops
from .common import row, save
from .fig9_overhead import _mk

N = 1_000_000


def main() -> list:
    rng = np.random.default_rng(0)
    rows = []
    t0 = time.perf_counter()
    addrs, objs = _mk(rng, N)           # stand-in for workload + collection
    t_collect = time.perf_counter() - t0
    starts = np.array([o[0] for o in objs])
    ends = np.array([o[1] for o in objs])

    # --- host path: transfer (copy out of the 'device' buffer) + analysis
    t0 = time.perf_counter()
    host_copy = np.array(addrs, copy=True)
    t_transfer = time.perf_counter() - t0
    t0 = time.perf_counter()
    _host_analyze(host_copy, starts, ends)
    t_analysis_host = time.perf_counter() - t0

    # --- device path: collection+analysis fused; only aggregates transfer
    t0 = time.perf_counter()
    counts = ops.object_histogram(addrs, starts, ends)
    t_device = time.perf_counter() - t0
    t_aggr_transfer = counts.nbytes / 16e9          # O(#objects), negligible

    report = {
        "host": {"collection_s": t_collect, "transfer_s": t_transfer,
                 "analysis_s": t_analysis_host,
                 "total_s": t_collect + t_transfer + t_analysis_host},
        "device": {"collect_and_analyze_s": t_device,
                   "aggregate_transfer_s": t_aggr_transfer,
                   "total_s": t_collect + t_device},
    }
    frac = t_analysis_host / report["host"]["total_s"]
    rows.append(row("fig10_breakdown[host]",
                    report["host"]["total_s"] * 1e6 / N,
                    f"analysis_frac={frac:.2f};"
                    f"transfer_s={t_transfer:.4f};"
                    f"analysis_s={t_analysis_host:.2f}"))
    rows.append(row("fig10_breakdown[device]",
                    report["device"]["total_s"] * 1e6 / N,
                    f"collect+analyze_s={t_device:.4f};"
                    f"aggregate_bytes={int(counts.nbytes)}"))
    save("fig10_breakdown", report)
    return rows


if __name__ == "__main__":
    main()
