"""Paper Fig. 15 — per-device memory under DP / TP / PP.

Runs in a subprocess with 8 virtual devices (flags must precede jax import).
For one transformer config, computes the exact per-device parameter +
optimizer-state bytes under

  * DP  — params replicated (identical across devices),
  * TP  — params model-sharded (identical, ~1/8 of DP),
  * PP  — 4 pipeline stages × 2-way DP: stage shards are *asymmetric*
    (the embedding stage and the lm-head stage carry extra weight),

reproducing the paper's observations: DP/TP symmetric, TP ≈ DP / mesh,
PP asymmetric with the logits stage heaviest.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import row, save

_SUB = """
import jax, jax.numpy as jnp, json
import numpy as np
import repro.configs as C
from repro.dist.sharding import set_mesh, ShardingRules, DEFAULT_RULES
from repro.models import param_axes
from repro.train import OptConfig
from repro.train.trainer import abstract_state, tree_shardings
from repro.launch.dryrun import _sharded_bytes

cfg = C.get("paper-gpt2")
opt_cfg = OptConfig()
p_shapes, o_shapes = abstract_state(cfg, opt_cfg)
out = {}

def bytes_per_device(mesh, rules):
    set_mesh(mesh, rules)
    p_sh = tree_shardings(mesh, param_axes(cfg), p_shapes)
    return _sharded_bytes(p_shapes, p_sh)

# DP: 8-way data, no model sharding -> params replicated
mesh = jax.make_mesh((8, 1), ("data", "model"))
rules = ShardingRules({**DEFAULT_RULES, "p_embed": None, "p_vocab": None,
                       "p_heads": None, "p_ff": None, "p_kv_heads": None})
out["DP"] = [bytes_per_device(mesh, rules)] * 8

# TP: 8-way model sharding (ZeRO off to isolate TP)
mesh = jax.make_mesh((1, 8), ("data", "model"))
rules = ShardingRules({**DEFAULT_RULES, "p_embed": None})
out["TP"] = [bytes_per_device(mesh, rules)] * 8

# PP: 4 stages x 2-way DP; stage = contiguous layer group; embed on stage 0,
# lm_head/final_norm on stage 3 (tied embeddings count on stage 0)
n_stages = 4
per_stage_layers = cfg.n_layers // n_stages
layer_bytes = (cfg.attn_params_per_layer() + cfg.mlp_params_per_layer()) * 4
stage_bytes = []
for s in range(n_stages):
    b = per_stage_layers * layer_bytes
    if s == 0:
        b += cfg.vocab_size * cfg.d_model * 4      # embedding
    if s == n_stages - 1:
        b += cfg.d_model * 4                       # final norm
        if not cfg.tie_embeddings:
            b += cfg.vocab_size * cfg.d_model * 4  # lm head
        else:
            b += cfg.vocab_size * cfg.d_model * 4  # tied table re-read
    stage_bytes.append(b)
out["PP"] = [stage_bytes[i // 2] for i in range(8)]
# optimizer multiplier (AdamW f32: m+v — params already counted)
out["opt_multiplier"] = 3.0
print(json.dumps(out))
"""


def main() -> list:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SUB)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    save("fig15_parallelism", out)
    rows = []
    for mode in ("DP", "TP", "PP"):
        b = out[mode]
        sym = max(b) / max(min(b), 1)
        rows.append(row(f"fig15_parallelism[{mode}]", 0.0,
                        f"per_device_MB={[x >> 20 for x in b]};"
                        f"max_over_min={sym:.2f}"))
    return rows


if __name__ == "__main__":
    main()
