"""Paper Fig. 15 — per-device memory under DP / TP / PP, plus the
exposed-cross-pod-comm sweep for the overlapped gradient sync.

Runs in subprocesses with 8 virtual devices (flags must precede jax import).

``main`` part 1 (memory): for one transformer config, computes the exact
per-device parameter + optimizer-state bytes under

  * DP  — params replicated (identical across devices),
  * TP  — params model-sharded (identical, ~1/8 of DP),
  * PP  — 4 pipeline stages × 2-way DP: stage shards are *asymmetric*
    (the embedding stage and the lm-head stage carry extra weight),

reproducing the paper's observations: DP/TP symmetric, TP ≈ DP / mesh,
PP asymmetric with the logits stage heaviest.

``exposed_comm`` (part 2): compiles the train step on a 2×2×2
pod×data×model mesh with the *blocking* ``make_pod_sync`` baseline vs the
*bucketed-overlap* ``psum_start``/``psum_wait`` pipeline
(``overlap_sync=``), walks both artifacts with the overlap-aware HLO
accounting (inter-pod collectives classified onto the DCI link,
alpha-beta message costs, async-runtime backfill model), and asserts

  * the overlap variant's exposed cross-pod comm time is measurably lower
    (bucketing aggregates many per-leaf messages into few per-bucket ones
    and pipelines them against retire compute + intra-pod traffic);
  * the walker's per-variant breakdown (message-latency aggregation +
    overlap credit) accounts for the measured exposed-comm delta;
  * ``compressed_psum``'s per-device wire bytes stay O(1) across pod
    counts 2→8 (the quantized reduce-scatter + all-gather layout — the old
    all-gather-everything layout grew linearly, (N-1)x).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import row, save

_SUB = """
import jax, jax.numpy as jnp, json
import numpy as np
import repro.configs as C
from repro.dist.sharding import set_mesh, ShardingRules, DEFAULT_RULES
from repro.models import param_axes
from repro.train import OptConfig
from repro.train.trainer import abstract_state, tree_shardings
from repro.launch.dryrun import _sharded_bytes

cfg = C.get("paper-gpt2")
opt_cfg = OptConfig()
p_shapes, o_shapes = abstract_state(cfg, opt_cfg)
out = {}

def bytes_per_device(mesh, rules):
    set_mesh(mesh, rules)
    p_sh = tree_shardings(mesh, param_axes(cfg), p_shapes)
    return _sharded_bytes(p_shapes, p_sh)

# DP: 8-way data, no model sharding -> params replicated
mesh = jax.make_mesh((8, 1), ("data", "model"))
rules = ShardingRules({**DEFAULT_RULES, "p_embed": None, "p_vocab": None,
                       "p_heads": None, "p_ff": None, "p_kv_heads": None})
out["DP"] = [bytes_per_device(mesh, rules)] * 8

# TP: 8-way model sharding (ZeRO off to isolate TP)
mesh = jax.make_mesh((1, 8), ("data", "model"))
rules = ShardingRules({**DEFAULT_RULES, "p_embed": None})
out["TP"] = [bytes_per_device(mesh, rules)] * 8

# PP: 4 stages x 2-way DP; stage = contiguous layer group; embed on stage 0,
# lm_head/final_norm on stage 3 (tied embeddings count on stage 0)
n_stages = 4
per_stage_layers = cfg.n_layers // n_stages
layer_bytes = (cfg.attn_params_per_layer() + cfg.mlp_params_per_layer()) * 4
stage_bytes = []
for s in range(n_stages):
    b = per_stage_layers * layer_bytes
    if s == 0:
        b += cfg.vocab_size * cfg.d_model * 4      # embedding
    if s == n_stages - 1:
        b += cfg.d_model * 4                       # final norm
        if not cfg.tie_embeddings:
            b += cfg.vocab_size * cfg.d_model * 4  # lm head
        else:
            b += cfg.vocab_size * cfg.d_model * 4  # tied table re-read
    stage_bytes.append(b)
out["PP"] = [stage_bytes[i // 2] for i in range(8)]
# optimizer multiplier (AdamW f32: m+v — params already counted)
out["opt_multiplier"] = 3.0
print(json.dumps(out))
"""


_EXPOSED_SUB = """
import jax, jax.numpy as jnp, json
import repro.configs as C
from repro.dist.sharding import set_mesh
from repro.dist.collectives import GROUP, make_pod_sync
from repro.train import OptConfig, trainer
from repro.core.hlo import analyze_text

cfg = C.reduced(C.get("paper-gpt2"))
opt_cfg = OptConfig()
out = {}

# ---- blocking vs bucketed-overlap train step on a pod x data x model mesh
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
set_mesh(mesh)
p_sh, o_sh, p_shapes, o_shapes = trainer.train_shardings(mesh, cfg, opt_cfg)
specs = {"inputs": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
b_sh = trainer.batch_shardings(mesh, specs, include_pod=False)

def cell(overlap, compressed):
    step = trainer.make_train_step(cfg, opt_cfg, overlap_sync=overlap,
                                   sync_compressed=compressed,
                                   sync_buckets=4)
    jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, None))
    text = jf.lower(p_shapes, o_shapes, specs).compile().as_text()
    stats = analyze_text(text, default_trip=cfg.n_layers, pods=2,
                         n_devices=8)
    pod = [i for i in stats.collective_instances if i.get("link") == "dci"]
    return {
        "pod_wire_bytes": sum(i["wire_bytes"] * i["mult"] for i in pod),
        "pod_comm_s": sum(i["comm_s"] * i["mult"] for i in pod),
        "pod_hidden_s": sum(i["hidden_s"] * i["mult"] for i in pod),
        "pod_exposed_s": sum(max(i["comm_s"] - i["hidden_s"], 0.0)
                             * i["mult"] for i in pod),
        "n_pod_collectives": len(pod),
        "n_overlapped": sum(1 for i in pod if i["overlapped"]),
        "total_exposed_s": stats.exposed_collective_s,
    }

for compressed in (False, True):
    key = "compressed" if compressed else "plain"
    out[key] = {"blocking": cell(False, compressed),
                "overlap": cell(True, compressed)}

# ---- compressed_psum wire bytes across pod counts (O(1) claim) ----------
wire = {}
tree = {"a": jax.ShapeDtypeStruct((64, 64), jnp.float32),
        "b": jax.ShapeDtypeStruct((128,), jnp.float32)}
n_el = 64 * 64 + 128
for npods, mesh_spec in [(2, ((2, 4), ("pod", "data"))),
                         (4, ((4, 2), ("pod", "data"))),
                         (8, ((8,), ("pod",)))]:
    m = jax.make_mesh(*mesh_spec)
    sync = make_pod_sync(m, compressed=True)
    text = jax.jit(sync).lower(tree).compile().as_text()
    stats = analyze_text(text)
    # quantized payload incl. per-leaf padding to npods*GROUP
    pad = sum((-n) % (npods * GROUP) for n in (64 * 64, 128))
    q_payload = (n_el + pad) * (1 + 4 / GROUP)
    wire[npods] = {"wire_bytes": stats.total_wire_bytes,
                   "q_payload_bytes": q_payload,
                   "old_layout_bytes": (npods - 1) * q_payload}
out["wire_sweep"] = wire
print(json.dumps(out))
"""


def exposed_comm() -> list:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_EXPOSED_SUB)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    rows = []
    for key in ("plain", "compressed"):
        b, o = out[key]["blocking"], out[key]["overlap"]
        # exposed = comm - hidden per instance; the delta decomposes into
        # the walker-reported aggregation (fewer alpha latencies) and
        # overlap-credit terms — assert the books balance
        delta = b["pod_exposed_s"] - o["pod_exposed_s"]
        aggregation = b["pod_comm_s"] - o["pod_comm_s"]
        credit = o["pod_hidden_s"] - b["pod_hidden_s"]
        assert abs(delta - (aggregation + credit)) < 1e-12, (
            delta, aggregation, credit)
        assert o["pod_exposed_s"] < b["pod_exposed_s"], (key, b, o)
        hf_b = b["pod_hidden_s"] / max(b["pod_comm_s"], 1e-30)
        hf_o = o["pod_hidden_s"] / max(o["pod_comm_s"], 1e-30)
        if key == "compressed":
            # production cross-pod config: the pipeline must also hide a
            # larger *fraction* of its wire time, not just send fewer
            # messages (plain is within noise of blocking here — the
            # quant/dequant retire compute is what feeds the windows)
            assert hf_o > hf_b, (key, hf_o, hf_b)
        out[key]["delta_s"] = delta
        out[key]["aggregation_s"] = aggregation
        out[key]["overlap_credit_s"] = credit
        rows.append(row(
            f"fig15_exposed_comm[{key}]", o["pod_exposed_s"] * 1e6,
            f"blocking_exposed_us={b['pod_exposed_s'] * 1e6:.2f};"
            f"overlap_exposed_us={o['pod_exposed_s'] * 1e6:.2f};"
            f"ratio={o['pod_exposed_s'] / b['pod_exposed_s']:.3f};"
            f"msgs={b['n_pod_collectives']}->{o['n_pod_collectives']}"))
    # the compressed comparison is the production cross-pod config: the
    # overlap win there must be substantial, not marginal
    c = out["compressed"]
    assert (c["overlap"]["pod_exposed_s"]
            < 0.8 * c["blocking"]["pod_exposed_s"]), c

    ws = out["wire_sweep"]
    ratio = ws["8"]["wire_bytes"] / ws["2"]["wire_bytes"]
    for npods, cell_ in ws.items():
        # O(1): bounded by ~2x the quantized payload at every pod count
        # (all-to-all + all-gather each move < 1x payload); the old
        # all-gather-everything layout grew as (N-1) x payload
        assert cell_["wire_bytes"] <= 2.1 * cell_["q_payload_bytes"], (
            npods, cell_)
        rows.append(row(
            f"fig15_wire_bytes[pods={npods}]", 0.0,
            f"wire={cell_['wire_bytes']:.0f};"
            f"bound=2x{cell_['q_payload_bytes']:.0f};"
            f"old_layout={cell_['old_layout_bytes']:.0f}"))
    assert ratio < 2.0, ratio          # vs 7x growth for the old layout
    save("fig15_exposed_comm", out)
    return rows


def memory_modes() -> list:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SUB)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    save("fig15_parallelism", out)
    rows = []
    for mode in ("DP", "TP", "PP"):
        b = out[mode]
        sym = max(b) / max(min(b), 1)
        rows.append(row(f"fig15_parallelism[{mode}]", 0.0,
                        f"per_device_MB={[x >> 20 for x in b]};"
                        f"max_over_min={sym:.2f}"))
    return rows


def main() -> list:
    return memory_modes() + exposed_comm()


if __name__ == "__main__":
    main()
