"""Emit the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json.  Run:  PYTHONPATH=src python -m benchmarks.roofline_report
"""

from __future__ import annotations

import glob
import json
import os

import repro.configs as configs

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load(mesh: str, tag: str = "") -> dict:
    out = {}
    suffix = f"__{tag}" if tag else ""
    for arch in configs.ASSIGNED:
        for shape in SHAPES:
            for m in (mesh, "skip"):
                p = os.path.join(DRY, f"{arch}__{shape}__{m}{suffix}.json")
                if os.path.exists(p):
                    with open(p) as f:
                        out[(arch, shape)] = json.load(f)
                    break
    return out


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def dryrun_table(cells: dict, multi: dict) -> str:
    lines = ["| arch | shape | status | compile 1-pod / 2-pod (s) | "
             "state GiB/dev | temp GiB/dev | HLO GFLOPs/dev | "
             "coll GiB/dev | #coll |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), d in cells.items():
        if d["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | SKIP (full-attention; "
                         f"see DESIGN.md §4) | - | - | - | - | - | - |")
            continue
        m = multi.get((arch, shape), {})
        temp = d.get("memory_analysis", {}).get("temp_size_in_bytes")
        lines.append(
            f"| {arch} | {shape} | OK | {d['compile_s']} / "
            f"{m.get('compile_s', '-')} | "
            f"{fmt_bytes(d.get('state_bytes_per_device'))} | "
            f"{fmt_bytes(temp)} | "
            f"{d['hlo']['flops_per_device'] / 1e9:.0f} | "
            f"{fmt_bytes(d['hlo']['collective_total_bytes'])} | "
            f"{d['hlo']['n_collectives']} |")
    return "\n".join(lines)


def roofline_table(cells: dict) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | MODEL_FLOPS | useful-FLOPs ratio | roofline "
             "frac | move the bound by |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    hints = {
        "train_4k": "fusing the attention score chain (Pallas flash) / "
                    "bf16 wire+score dtypes",
        "prefill_32k": "larger KV chunks to cut online-softmax accumulator "
                       "rewrites",
        "decode_32k": "two-tier KV buffer to avoid the per-layer "
                      "masked-select cache rewrite",
        "long_500k": "state-sharded SSM update batching",
    }
    for (arch, shape), d in cells.items():
        if d["status"] != "ok":
            continue
        r = d["roofline"]
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {d['model_flops_total']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{hints.get(shape, '')} |")
    return "\n".join(lines)


def perf_variants() -> str:
    rows = []
    for p in sorted(glob.glob(os.path.join(DRY, "*__16x16__*.json"))):
        with open(p) as f:
            d = json.load(f)
        if d.get("status") != "ok":
            continue
        r = d["roofline"]
        temp = d.get("memory_analysis", {}).get("temp_size_in_bytes")
        rows.append(f"| {d['arch']} | {d['shape']} | {d['tag']} | "
                    f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                    f"{r['collective_s']:.3f} | {fmt_bytes(temp)} | "
                    f"{r['roofline_fraction']:.3f} |")
    return "\n".join(
        ["| arch | shape | variant | compute s | memory s | collective s | "
         "temp GiB/dev | frac |", "|---|---|---|---|---|---|---|---|"]
        + rows)


def main():
    single = load("16x16")
    multi = load("2x16x16")
    n_ok = sum(1 for d in single.values() if d["status"] == "ok")
    n_skip = sum(1 for d in single.values() if d["status"] == "skipped")
    print(f"<!-- {n_ok} compiled + {n_skip} recorded skips, single-pod; "
          f"{sum(1 for d in multi.values() if d.get('status') == 'ok')} "
          f"multi-pod -->\n")
    print("### Dry-run matrix\n")
    print(dryrun_table(single, multi))
    print("\n### Roofline (single-pod 16×16, per chip)\n")
    print(roofline_table(single))
    print("\n### Perf variants\n")
    print(perf_variants())


if __name__ == "__main__":
    main()
