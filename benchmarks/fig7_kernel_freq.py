"""Paper Fig. 7 — kernel invocation frequency distribution.

Compiles train + decode steps for representative archs (reduced configs),
captures the executed-kernel counts from the compiled artifacts (× loop trip
counts), and reports the skew: a small subset of kernels dominates
invocations — the paper's optimization-targeting insight.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.configs as C
import repro.core as pasta
from repro.models import init_params, init_cache, forward
from repro.train import OptConfig, make_train_step
from repro.train.optimizer import init_opt_state
from .common import row, save

ARCHS = ("paper-gpt2", "paper-bert", "qwen3-32b", "mamba2-2.7b", "dbrx-132b")


def main() -> list:
    rows = []
    report = {}
    for arch in ARCHS:
        cfg = C.reduced(C.get(arch))
        session = pasta.Session(tools="kernel_freq:top_k=10",
                                name=f"fig7/{arch}")
        handler = session.handler
        params = init_params(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        if cfg.frontend == "embed":
            x = jax.random.normal(key, (2, 64, cfg.d_model))
        else:
            x = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
        labels = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)

        opt_cfg = OptConfig()
        step = make_train_step(cfg, opt_cfg, microbatches=1)
        opt = init_opt_state(params, opt_cfg)
        t0 = time.perf_counter()
        c_train = jax.jit(step).lower(params, opt,
                                      {"inputs": x,
                                       "labels": labels}).compile()
        handler.capture_compiled(c_train, label=f"{arch}.train",
                                 default_trip=cfg.n_layers, steps=10)
        if cfg.causal:
            cache = init_cache(cfg, 2, 32)
            c_dec = jax.jit(
                lambda p, c, t: forward(p, t, cfg, cache=c,
                                        logits_mode="last")).lower(
                params, cache, x[:, :1]).compile()
            handler.capture_compiled(c_dec, label=f"{arch}.decode",
                                     default_trip=cfg.n_layers, steps=100)
        capture_us = (time.perf_counter() - t0) * 1e6
        rep = session.reports()["kernel_freq"].data
        session.close()
        total = rep["total_invocations"]
        top5 = sum(c for _n, c in rep["top"][:5])
        report[arch] = {"total": total, "distinct": rep["distinct_kernels"],
                        "top": rep["top"][:10],
                        "top5_share": top5 / max(total, 1)}
        rows.append(row(f"fig7_kernel_freq[{arch}]", capture_us,
                        f"total={total};distinct={rep['distinct_kernels']};"
                        f"top5_share={top5 / max(total, 1):.2f}"))
    save("fig7_kernel_freq", report)
    return rows


if __name__ == "__main__":
    main()
