"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; detailed payloads land in
``results/bench/*.json``.  Paper artifacts covered: Fig. 7, Table V,
Fig. 9, Fig. 10, Figs. 11-12, Fig. 13, Fig. 14, Fig. 15 (see DESIGN.md §5
for the artifact → reproduction mapping).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (fig7_kernel_freq, tablev_workingset, fig9_overhead,
                   fig10_breakdown, fig11_12_offload, fig13_hotness,
                   fig14_timeline, fig15_parallelism)
    benches = [
        ("fig7", fig7_kernel_freq.main),
        ("tablev", tablev_workingset.main),
        ("fig9", fig9_overhead.main),
        ("fig10", fig10_breakdown.main),
        ("fig11_12", fig11_12_offload.main),
        ("fig13", fig13_hotness.main),
        ("fig14", fig14_timeline.main),
        ("fig15", fig15_parallelism.main),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches:
        try:
            fn()
        except Exception:                                   # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
