"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; detailed payloads land in
``results/bench/*.json``.  Paper artifacts covered: Fig. 7, Table V,
Fig. 9, Fig. 10, Figs. 11-12, Fig. 13, Fig. 14, Fig. 15 (see DESIGN.md §5
for the artifact → reproduction mapping).

``--smoke`` runs the CI subset — the Fig. 9 overhead/dispatch sweep (with
its report-parity and ≥10× dispatch-speedup asserts) and the Fig. 15
exposed-cross-pod-comm sweep (overlapped vs blocking sync, O(1) wire
bytes) — and snapshots their payloads to ``BENCH_fig9.json`` /
``BENCH_fig15.json`` at the repo root, so the perf trajectory is recorded
per PR.  The full run refreshes the same snapshots.
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DIR = os.path.join(REPO, "results", "bench")

#: results/bench payload -> repo-root snapshot recording the perf trajectory
SNAPSHOTS = {
    "fig9_overhead.json": "BENCH_fig9.json",
    "fig15_exposed_comm.json": "BENCH_fig15.json",
    "fig_serve.json": "BENCH_serve.json",
}


def snapshot() -> list:
    out = []
    for src, dst in SNAPSHOTS.items():
        path = os.path.join(BENCH_DIR, src)
        if os.path.exists(path):
            with open(path) as f:
                json.load(f)                  # refuse to snapshot junk
            shutil.copyfile(path, os.path.join(REPO, dst))
            out.append(dst)
    return out


def main() -> None:
    smoke = "--smoke" in sys.argv
    from . import (fig7_kernel_freq, tablev_workingset, fig9_overhead,
                   fig10_breakdown, fig11_12_offload, fig13_hotness,
                   fig14_timeline, fig15_parallelism, fig_serve)
    if smoke:
        benches = [
            ("fig9", lambda: fig9_overhead.main(
                sizes=fig9_overhead.SMOKE_SIZES,
                dispatch_sizes=fig9_overhead.SMOKE_DISPATCH_SIZES)),
            ("fig15_exposed_comm", fig15_parallelism.exposed_comm),
            ("fig_serve", fig_serve.main),
        ]
    else:
        benches = [
            ("fig7", fig7_kernel_freq.main),
            ("tablev", tablev_workingset.main),
            ("fig9", fig9_overhead.main),
            ("fig10", fig10_breakdown.main),
            ("fig11_12", fig11_12_offload.main),
            ("fig13", fig13_hotness.main),
            ("fig14", fig14_timeline.main),
            ("fig15", fig15_parallelism.main),
            ("fig_serve", fig_serve.main),
        ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches:
        try:
            fn()
        except Exception:                                   # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    written = snapshot()
    print(f"snapshots: {written}", file=sys.stderr)
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
