"""Paper Figs. 11–12 — object- vs tensor-granularity prefetch, with and
without memory oversubscription.

The schedule comes from a real instrumented model run (per-operator
access-verified tensor sets + pool-object residence); the host-offload
planner (the TPU adaptation of the UVM prefetcher, DESIGN.md §2) simulates
on-demand / object-prefetch / tensor-prefetch under oversubscription 1× and
3×.  Expected shape of the result (paper): prefetch wins without pressure;
object-level thrashes at 3× while tensor-level holds.
"""

from __future__ import annotations

import numpy as np

import repro.core as pasta
from repro.core.events import EventKind
from repro.core.tools import offload
from .common import instrumented_inference, row, save

MODELS = ("paper-gpt2", "glm4-9b", "mamba2-2.7b")


class _ScheduleTool(pasta.PastaTool):
    EVENTS = (EventKind.OPERATOR_START, EventKind.TENSOR_ALLOC)

    def __init__(self):
        super().__init__()
        self.kernels = []
        self.addr2obj = {}

    def on_tensor_alloc(self, ev):
        self.addr2obj[ev.addr] = (ev.attrs["object_id"], ev.size,
                                  ev.attrs["tensor_id"])

    def on_operator_start(self, ev):
        tensors = []
        for addr, size in ev.attrs.get("tensors", ()):
            oid, _sz, tid = self.addr2obj.get(addr, (0, size, addr))
            tensors.append((tid, size, oid))
        if tensors:
            # compute estimate proportional to bytes touched (~20 GB/s core)
            nbytes = sum(sz for _t, sz, _o in tensors)
            self.kernels.append(offload.KernelAccess(
                name=ev.name, compute_s=max(nbytes / 20e9, 5e-5),
                tensors=tensors))


def main() -> list:
    rows = []
    report = {}
    for arch in MODELS:
        tool = _ScheduleTool()
        # small pool chunks (128 KiB, 4 KiB aligned): several tensors per
        # memory object, many objects — the paper's pool topology at toy scale
        session, _ = instrumented_inference(
            arch, fine=False, tools=[tool], steps=3,
            pool_chunk=128 << 10, pool_align=4 << 10)
        object_sizes = {o.oid: o.size
                        for o in session.pool.objects.values()}
        footprint = session.pool.footprint
        res = {}
        for ov in (1.0, 3.0):
            res[ov] = offload.plan(tool.kernels, object_sizes, footprint,
                                   oversubscription=ov)
            tag = "fig11" if ov == 1.0 else "fig12"
            o, t = res[ov]["object"], res[ov]["tensor"]
            rows.append(row(
                f"{tag}_offload[{arch},ov={ov}]", res[ov][
                    "none"]["time_s"] * 1e6 / max(len(tool.kernels), 1),
                f"object_speedup={o['speedup_vs_none']:.2f};"
                f"tensor_speedup={t['speedup_vs_none']:.2f};"
                f"object_migrated={o['migrated_bytes'] >> 20}MB;"
                f"tensor_migrated={t['migrated_bytes'] >> 20}MB"))
        report[arch] = {str(k): v for k, v in res.items()}
    save("fig11_12_offload", report)
    return rows


if __name__ == "__main__":
    main()
