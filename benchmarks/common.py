"""Shared benchmark helpers."""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def save(name: str, payload: dict) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def row(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line)
    return line


def timeit(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def instrumented_inference(arch: str, batch=2, seq=64, fine=True,
                           hotness=None, tools=None, steps: int = 1,
                           pool_chunk: int = 1 << 20,
                           pool_align: int | None = None):
    """Run a reduced ``arch`` forward eagerly under full PASTA
    instrumentation inside one scoped Session; returns
    ``(session, reports)`` — reports keyed by tool registry name."""
    import jax
    import repro.configs as C
    import repro.core as pasta
    from repro.models import init_params, forward

    cfg = C.reduced(C.get(arch))
    session = pasta.Session(
        tools=tools if tools is not None else "workingset,timeline",
        hotness=hotness, instrument=True, fine=fine,
        pool_chunk=pool_chunk, pool_align=pool_align,
        name=f"bench/{arch}")
    handler = session.handler
    session.instrumenter.time_source = \
        lambda: float(max(handler._step, 0))
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    if cfg.frontend == "embed":
        x = jax.random.normal(key, (batch, seq, cfg.d_model))
    else:
        x = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    with session:
        for s in range(steps):
            handler.step_start(s)
            with pasta.region(f"step{s}"):
                logits, _ = forward(params, x, cfg)
            handler.step_end(s)
    reports = session.reports()
    session.close()
    return session, reports
