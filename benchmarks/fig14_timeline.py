"""Paper Fig. 14 — memory-usage timeline under two backends.

The paper compares CUDA vs ROCm builds of the same training iteration (same
three-phase ramp, different allocation event counts / peaks from different
fusion choices).  The XLA analogue: the same model executed through two
backend compilation modes —

  * ``eager``   — op-by-op dispatch (framework-managed tensor lifetimes,
    many small alloc/free events), and
  * ``compiled``— whole-step XLA (buffer-assigned; few large arenas,
    lower peak via fusion) —

with the timeline tool capturing alloc/free counts, peak, and the ramp
shape per backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as C
import repro.core as pasta
from repro.models import init_params, forward, cross_entropy
from .common import row, save


def main() -> list:
    cfg = C.reduced(C.get("paper-gpt2"))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)

    # backend A: eager (instrumented lifetimes) — one scoped session
    session = pasta.Session(tools="timeline", instrument=True, fine=False,
                            name="fig14")
    with session:
        with pasta.region("iteration"):
            logits, _ = forward(params, x, cfg)
            loss, _ = cross_entropy(logits, labels)
    eager = session.reports()["timeline"].data
    session.close()
    dev = eager["devices"][0]
    e_series = [b for _s, b, _r in eager["series"][dev]]

    # backend B: compiled (XLA buffer assignment)
    c = jax.jit(lambda p, x, l: cross_entropy(forward(p, x, cfg)[0], l)[0]) \
        .lower(params, x, labels).compile()
    mem = c.memory_analysis()
    compiled = {
        "peak_temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "alloc_events": 1,        # one arena
    }
    report = {"eager": {"peak_bytes": eager["peak_bytes"][dev],
                        "alloc_events": eager["alloc_events"][dev],
                        "free_events": eager["free_events"][dev],
                        "ramp_max": max(e_series),
                        "ramp_end": e_series[-1]},
              "compiled": compiled}
    save("fig14_timeline", report)
    d = report["eager"]["peak_bytes"] - compiled["peak_temp_bytes"]
    return [row("fig14_timeline[eager-vs-compiled]", 0.0,
                f"eager_peak={report['eager']['peak_bytes']};"
                f"eager_allocs={report['eager']['alloc_events']};"
                f"compiled_temp={compiled['peak_temp_bytes']};"
                f"peak_delta={d}")]


if __name__ == "__main__":
    main()
