"""Quickstart — attach PASTA to a training workload in ~25 lines.

One ``pasta.Session`` owns the whole pipeline: tool selection by registry
spec, framework-level instrumentation (operator events, tensor lifetimes,
fine-grained access traces reduced on device), ring buffering, and the
compiled-artifact capture.  No handler/processor hand-wiring.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

import repro.configs as configs
import repro.core as pasta
from repro.models import init_params, forward
from repro.train import OptConfig, make_train_step
from repro.train.optimizer import init_opt_state


def main():
    cfg = configs.reduced(configs.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    x = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)

    # one Session = scoped attach + tools + instrumentation + buffering;
    # tools are registry specs (knobs would be "kernel_freq:top_k=5")
    with pasta.Session(tools="kernel_freq,workingset,timeline",
                       instrument=True, fine=True, buffered=True,
                       name="quickstart") as session:
        # 1) eager instrumented pass: framework-level events batched
        #    through the SoA ring (flushed at step edges / session exit)
        with pasta.region("forward"):               # paper Listing 1 style
            logits, _ = forward(params, x, cfg)

        # 2) compiled-artifact capture: kernel launches & collectives × steps
        opt_cfg = OptConfig()
        step = make_train_step(cfg, opt_cfg, microbatches=1)
        opt = init_opt_state(params, opt_cfg)
        compiled = jax.jit(step).lower(
            params, opt, {"inputs": x, "labels": labels}).compile()
        session.capture_compiled(compiled, label="train_step",
                                 default_trip=cfg.n_layers, steps=5)

    print("== PASTA tool reports ==")
    reports = session.reports()
    kf = reports["kernel_freq"]
    print(f"kernel_freq: total={kf['total_invocations']} "
          f"distinct={kf['distinct_kernels']} top3={kf['top'][:3]}")
    ws = reports["workingset"]
    print(f"workingset: footprint={ws['footprint_mb']:.1f}MB "
          f"ws={ws['working_set_mb']:.2f}MB "
          f"median={ws['median_ws_mb']:.2f}MB")
    tl = reports["timeline"]
    d = tl["devices"][0]
    print(f"timeline: peak={tl['peak_bytes'][d]}B "
          f"allocs={tl['alloc_events'][d]} frees={tl['free_events'][d]}")
    print(reports["kernel_freq"].to_json()[:120] + "...")


if __name__ == "__main__":
    main()
