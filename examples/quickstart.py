"""Quickstart — attach PASTA to a training workload in ~30 lines.

Runs a reduced GPT-2 for a few steps with the kernel-frequency, working-set
and memory-timeline tools attached, then prints their reports.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

import repro.configs as configs
import repro.core as pasta
from repro.core.instrument import EagerInstrumenter
from repro.models import init_params, forward, cross_entropy
from repro.train import OptConfig, make_train_step
from repro.train.optimizer import init_opt_state


def main():
    cfg = configs.reduced(configs.get("paper-gpt2"))
    handler = pasta.attach()                       # per-process injection
    tools = pasta.make_tools("kernel_freq,workingset,timeline")
    proc = pasta.EventProcessor(handler, tools=tools)

    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    x = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)

    # 1) eager instrumented pass: framework-level events (operators, tensor
    #    lifetimes, fine-grained access traces reduced on device); buffered=
    #    True batches them through the SoA ring (flushed at step edges)
    with EagerInstrumenter(handler, fine=True, buffered=True):
        with pasta.region("forward"):              # paper Listing 1 style
            logits, _ = forward(params, x, cfg)

    # 2) compiled-artifact capture: kernel launches & collectives × steps
    opt_cfg = OptConfig()
    step = make_train_step(cfg, opt_cfg, microbatches=1)
    opt = init_opt_state(params, opt_cfg)
    compiled = jax.jit(step).lower(params, opt,
                                   {"inputs": x, "labels": labels}).compile()
    handler.capture_compiled(compiled, label="train_step",
                             default_trip=cfg.n_layers, steps=5)

    print("== PASTA tool reports ==")
    for name, rep in proc.finalize().items():
        if name == "KernelFrequencyTool":
            print(f"{name}: total={rep['total_invocations']} "
                  f"distinct={rep['distinct_kernels']} top3={rep['top'][:3]}")
        elif name == "WorkingSetTool":
            print(f"{name}: footprint={rep['footprint_mb']:.1f}MB "
                  f"ws={rep['working_set_mb']:.2f}MB "
                  f"median={rep['median_ws_mb']:.2f}MB")
        elif name == "MemoryTimelineTool":
            d = rep["devices"][0]
            print(f"{name}: peak={rep['peak_bytes'][d]}B "
                  f"allocs={rep['alloc_events'][d]} "
                  f"frees={rep['free_events'][d]}")
    proc.close()              # detach from the process-global handler


if __name__ == "__main__":
    main()
