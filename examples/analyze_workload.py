"""Workload characterization + offload planning — the paper's §V case
studies end-to-end on one model.

  1. instrumented inference → per-operator working sets (Table V),
  2. time-series hotness → pin/evict candidates (Fig. 13),
  3. host-offload planner → object vs tensor granularity under
     oversubscription (Figs. 11–12),
  4. cross-level locator → most memory-referenced kernel with its HLO
     op_name and Python stack (Fig. 4).

    PYTHONPATH=src python examples/analyze_workload.py [--arch glm4-9b]
"""

import argparse

import jax

import repro.configs as configs
import repro.core as pasta
from repro.core.pool import CHUNK_ALIGN
from repro.core.tools import offload
from repro.models import init_params, forward


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch))
    hot_cfg = {"base": CHUNK_ALIGN, "n_blocks": 256,
               "n_tbins": args.steps, "t_max": float(args.steps),
               "block_shift": 5}
    # one Session owns tools + fine-grained instrumentation; knob-bearing
    # tools can mix spec strings and instances in the tools list
    session = pasta.Session(
        tools=["workingset",
               pasta.HotnessTool(n_tbins=args.steps, n_blocks=256,
                                 hot_frac=0.75),
               "locator"],
        hotness=hot_cfg, instrument=True, fine=True,
        pool_chunk=128 << 10, pool_align=4 << 10,
        name=f"analyze/{args.arch}")
    handler = session.handler
    session.instrumenter.time_source = \
        lambda: float(max(handler._step, 0))

    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                           max(cfg.vocab_size, 2))
    if cfg.frontend == "embed":
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))

    schedule = []
    addr2obj = {}
    handler.subscribe(
        lambda e: addr2obj.update({e.addr: (e.attrs["object_id"], e.size,
                                            e.attrs["tensor_id"])}),
        kinds=("tensor_alloc",))

    def grab(ev):
        tensors = [(addr2obj.get(a, (0, s, a))[2], s,
                    addr2obj.get(a, (0, s, a))[0])
                   for a, s in ev.attrs.get("tensors", ())]
        if tensors:
            schedule.append(offload.KernelAccess(
                ev.name, max(sum(s for _t, s, _o in tensors) / 20e9, 5e-5),
                tensors))
    handler.subscribe(grab, kinds=("operator_start",))

    with session:
        for s in range(args.steps):
            handler.step_start(s)
            forward(params, x, cfg)
            handler.step_end(s)

    reports = session.reports()
    print(f"== {args.arch} characterization ==")
    w = reports["workingset"]
    print(f"working set: max={w['working_set_mb']:.2f}MB "
          f"median={w['median_ws_mb']:.2f}MB "
          f"footprint={w['footprint_mb']:.1f}MB")
    h = reports["hotness"]
    print(f"hotness: persistent(pin)={len(h['persistent_blocks'])} "
          f"bursty(evict)={len(h['bursty_blocks'])} cold={h['cold_blocks']}")
    locr = reports["locator"]
    print(f"locator: hottest={locr.get('kernel')} "
          f"op={locr.get('hlo_op_name', '')[:60]}")
    objects = {o.oid: o.size for o in session.pool.objects.values()}
    for ov in (1.0, 3.0):
        plan = offload.plan(schedule, objects, session.pool.footprint, ov)
        print(f"offload @ oversubscription {ov}: "
              f"object={plan['object']['speedup_vs_none']:.2f}x "
              f"tensor={plan['tensor']['speedup_vs_none']:.2f}x vs on-demand")


if __name__ == "__main__":
    main()
