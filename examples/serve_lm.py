"""Serving example: an open-loop request trace through the continuous-
batching ServeEngine, with per-request + fleet PASTA reports.

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-7b]
"""

import argparse
import sys

from repro.launch import serve as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    args, rest = ap.parse_known_args()

    sys.argv = ["serve_lm", "--arch", args.arch, "--reduced",
                "--num-requests", str(args.num_requests),
                "--max-slots", "4", "--rate", "2",
                "--prompt-len", "32", "--shared-prefix", "16",
                "--prefix-block", "8",
                "--max-new-tokens", str(args.max_new_tokens),
                "--temperature", "0.8"] + rest
    return serve_driver.main()


if __name__ == "__main__":
    sys.exit(main())
