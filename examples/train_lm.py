"""End-to-end training driver example: a ~100M-param LM for a few hundred
steps with checkpointing, elastic restart, and PASTA instrumentation.

This wraps the production driver (repro.launch.train).  On CPU the full
124M-param paper-gpt2 config is compute-bound, so the default here trains a
reduced config for 300 steps; pass ``--full`` for the real 124M model (slow
on CPU; the config is the same one the dry-run compiles for the 256-chip
mesh).

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps 300]
"""

import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 124M paper-gpt2 (slow on CPU)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args, rest = ap.parse_known_args()

    argv = ["--arch", "paper-gpt2", "--steps", str(args.steps),
            "--seq-len", "128", "--global-batch", "8", "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
            "--pasta-tools", "kernel_freq,timeline"]
    if not args.full:
        argv.append("--reduced")
    sys.argv = ["train_lm"] + argv + rest
    return train_driver.main()


if __name__ == "__main__":
    sys.exit(main())
