"""Request-lifecycle serving: continuous batching, slotted KV cache with
prefix reuse, per-request PASTA reports.

The load-bearing guarantees:

  * ``engine.run()`` over staggered ragged requests produces byte-identical
    tokens to per-request solo runs at temperature 0 (right-padding and the
    fused ragged decode are exact, per family);
  * prefix-cache-hit decode matches cold-prefill decode token-for-token;
  * the ``serving`` tool reports occupancy > 1 and a nonzero prefix hit
    rate on a shared-prefix workload;
  * ``generate()`` survives as a shim under a DeprecationWarning;
  * ``Session.close()`` is idempotent and keeps reports readable (the
    engine closes request sessions that already exited their context).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

import repro.configs as C
import repro.core as pasta
from repro.core.events import Event, EventKind
from repro.models import init_params
from repro.serve import (PagedKVPool, PrefixCache, SamplingParams, Scheduler,
                         ServeEngine)
from repro.serve.scheduler import Request, RequestState, pad_group

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _ragged_prompts(cfg, lens, seed=0, shared_prefix=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (shared_prefix,),
                          dtype=np.int32)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, (n,),
                                         dtype=np.int32)])
            for n in lens]


def _solo(cfg, params, prompt, max_new, **engine_kw):
    eng = ServeEngine(cfg, params, **engine_kw)
    out = eng.run([(prompt, SamplingParams(max_new_tokens=max_new))])
    return list(out.values())[0]


# --------------------------------------------------------------- equivalence
@pytest.mark.parametrize("arch", ["paper-gpt2", "mamba2-2.7b", "zamba2-7b"])
def test_run_staggered_ragged_matches_solo_generate(arch):
    """≥8 staggered ragged requests on 4 slots == per-request solo runs,
    token-for-token at temperature 0 (dense, SSM, and hybrid families)."""
    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    lens = (9, 17, 5, 12, 23, 7, 14, 10)
    prompts = _ragged_prompts(cfg, lens)
    sp = SamplingParams(max_new_tokens=5)

    eng = ServeEngine(cfg, params, max_seq=48, max_slots=4)
    # staggered arrival: 5 up front, 3 mid-flight
    rids = [eng.submit(p, sp) for p in prompts[:5]]
    eng.step()
    rids += [eng.submit(p, sp) for p in prompts[5:]]
    while eng.sched.has_work:
        eng.step()

    for rid, prompt in zip(rids, prompts):
        got = np.asarray(eng.requests[rid].tokens, np.int32)
        want = _solo(cfg, params, prompt, 5, max_seq=48, max_slots=4)
        np.testing.assert_array_equal(got, want, err_msg=f"rid={rid}")
    assert eng.sched.n_active == 0 and eng.sched.n_free == 4


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-7b"])
def test_stateful_families_match_exact_length_reference(arch):
    """SSM/hybrid prefill must run at EXACT prompt length: pad tokens would
    update the carried recurrent state (unlike masked attention KV), so
    serving output is pinned to a direct forward() prefill+decode reference,
    not just to another engine run padded the same way."""
    import jax.numpy as jnp
    from repro.models import forward
    from repro.serve.engine import _pad_cache_to

    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    (prompt,) = _ragged_prompts(cfg, (9,))      # deliberately non-pow2
    logits, cache = forward(params, jnp.asarray(prompt[None, :]), cfg,
                            return_cache=True, logits_mode="last")
    cache = _pad_cache_to(cache, cfg, 48)
    want = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(4):
        logits, cache = forward(params, jnp.asarray([[want[-1]]]), cfg,
                                cache=cache, logits_mode="last")
        want.append(int(jnp.argmax(logits[0, -1])))
    got = _solo(cfg, params, prompt, 5, max_seq=48, max_slots=2)
    np.testing.assert_array_equal(got, np.asarray(want, np.int32))


def test_prefix_cache_hit_matches_cold_prefill():
    """A request whose prompt prefix matches a cached one skips those
    prefill tokens and still decodes byte-identically to a cold engine."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    p1 = np.concatenate([base, rng.integers(0, cfg.vocab_size, (6,),
                                            dtype=np.int32)])
    p2 = np.concatenate([base, rng.integers(0, cfg.vocab_size, (11,),
                                            dtype=np.int32)])

    warm = ServeEngine(cfg, params, max_seq=64, max_slots=2, prefix_block=16)
    warm.run([(p1, SamplingParams(max_new_tokens=5))])
    out_hit = list(warm.run([(p2, SamplingParams(max_new_tokens=5))])
                   .values())[0]
    stats = warm.prefix_cache.stats()
    assert stats["hits"] == 1 and stats["reused_tokens"] == 32, stats

    out_cold = _solo(cfg, params, p2, 5, max_seq=64, max_slots=2,
                     prefix_cache=False)
    np.testing.assert_array_equal(out_hit, out_cold)


def test_identical_prompt_reuses_all_but_last_block():
    """Re-serving the same prompt hits the longest stored proper prefix."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    (prompt,) = _ragged_prompts(cfg, (33,), seed=2)
    eng = ServeEngine(cfg, params, max_seq=64, max_slots=2, prefix_block=8)
    first = list(eng.run([(prompt, SamplingParams(max_new_tokens=4))])
                 .values())[0]
    again = list(eng.run([(prompt, SamplingParams(max_new_tokens=4))])
                 .values())[0]
    np.testing.assert_array_equal(first, again)
    assert eng.prefix_cache.stats()["reused_tokens"] == 32   # last block cold


# ----------------------------------------------------------------- scheduler
def test_scheduler_fcfs_admission_and_slot_reuse():
    sched = Scheduler(max_slots=2)
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32),
                    params=SamplingParams()) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0, 1]          # FCFS
    assert [r.slot for r in admitted] == [0, 1]
    assert sched.admit() == [] and sched.n_queued == 2  # slots exhausted
    sched.release(reqs[0])
    assert reqs[0].state is RequestState.FINISHED
    nxt = sched.admit()
    assert [r.rid for r in nxt] == [2] and nxt[0].slot == 0   # slot reused
    with pytest.raises(ValueError):
        sched.release(reqs[0])                          # double release


def test_pad_group_right_pads_to_pow2_bucket():
    toks, lens = pad_group([np.arange(5, dtype=np.int32),
                            np.arange(11, dtype=np.int32)])
    assert toks.shape == (2, 16) and lens.tolist() == [5, 11]
    assert toks[0, 5:].sum() == 0 and toks[1, :11].tolist() == list(range(11))


def test_prefix_cache_store_block_keys_and_lru():
    pc = PrefixCache(block=4, capacity=3)
    kv = {"k": np.arange(2 * 10 * 3).reshape(2, 10, 3, 1).astype(np.float32),
          "v": np.zeros((2, 10, 3, 1), np.float32)}
    toks = np.arange(10, dtype=np.int32)
    pc.insert(toks, kv)                      # keys at L=4, 8, 10 -> capacity 3
    hit_len, ent = pc.lookup(np.concatenate([toks[:8],
                                             np.asarray([99], np.int32)]))
    assert hit_len == 8
    np.testing.assert_array_equal(ent["k"], kv["k"][:, :8])
    miss_len, _ = pc.lookup(np.asarray([7, 7, 7, 7], np.int32))
    assert miss_len == 0
    pc.insert(np.asarray([5, 6, 7, 8], np.int32),
              {"k": kv["k"][:, :4], "v": kv["v"][:, :4]})   # evicts LRU
    assert len(pc) <= 3


def test_prefill_bucket_larger_than_max_seq_is_cropped():
    """A prompt whose pow2 pad bucket exceeds max_seq still inserts (the
    slot write crops right-pad junk to the pool's seq dim)."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    (prompt,) = _ragged_prompts(cfg, (33,))     # bucket(33)=64 > max_seq=40
    out = _solo(cfg, params, prompt, 4, max_seq=40, max_slots=2)
    big = _solo(cfg, params, prompt, 4, max_seq=64, max_slots=2)
    np.testing.assert_array_equal(out, big)


def test_submit_validation():
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_seq=16, max_slots=1)
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(np.zeros((2, 4), np.int32))
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(np.zeros(12, np.int32), SamplingParams(max_new_tokens=8))


def test_stop_token_ends_request_early():
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    (prompt,) = _ragged_prompts(cfg, (9,))
    ref = _solo(cfg, params, prompt, 8, max_seq=32, max_slots=1)
    stop = int(ref[2])
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=1)
    out = list(eng.run([(prompt, SamplingParams(max_new_tokens=8,
                                                stop_token=stop))])
               .values())[0]
    np.testing.assert_array_equal(out, ref[:3])


# ------------------------------------------------------------- observability
def test_serving_tool_occupancy_and_prefix_hits():
    """Fleet report on a shared-prefix staggered workload: occupancy > 1
    and a nonzero prefix-cache hit rate (the acceptance scenario)."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (3, 9, 5, 14, 7, 11, 4, 8),
                              shared_prefix=24)
    sp = SamplingParams(max_new_tokens=6)
    with pasta.Session(tools="serving", name="fleet") as sess:
        eng = ServeEngine(cfg, params, max_seq=64, max_slots=4, session=sess,
                          request_tools="serving")
        for p in prompts[:5]:
            eng.submit(p, sp)
        eng.step()
        for p in prompts[5:]:
            eng.submit(p, sp)
        while eng.sched.has_work:
            eng.step()
    rep = sess.reports()["serving"].data
    assert rep["requests"] == 8 and rep["finished"] == 8
    assert rep["generated_tokens"] == 8 * 6
    assert rep["occupancy"]["mean"] > 1 and rep["occupancy"]["slots"] == 4
    assert rep["prefix_cache"]["hit_rate"] > 0
    assert rep["ttft_s"]["p90"] >= rep["ttft_s"]["p50"] > 0
    assert rep["tpot_s"]["mean"] > 0
    # per-request child sessions: one isolated report per request, closed
    assert len(eng.request_reports) == 8
    assert sess.children == []
    one = list(eng.request_reports)[0]["serving"]
    assert one["requests"] == 1 and one["ttft_s"]["mean"] > 0


def test_request_session_spans_lifetime_across_steps():
    """A request's child session sees its submit AND its finish even though
    other requests' steps interleave in between."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (8, 8, 8))
    with pasta.Session(tools=(), name="eng") as sess:
        eng = ServeEngine(cfg, params, max_seq=32, max_slots=1,
                          request_tools="serving", session=sess)
        eng.run([(p, SamplingParams(max_new_tokens=3)) for p in prompts])
    assert len(eng.request_reports) == 3
    for rep in eng.request_reports:
        d = rep["serving"].data
        assert d["requests"] == 1 and d["finished"] == 1
        assert d["by_request"][next(iter(d["by_request"]))]["n_tokens"] == 3


# ------------------------------------------------------------ generate() shim
def test_generate_shim_deprecated_but_equivalent():
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.stack(_ragged_prompts(cfg, (12, 12, 12)))
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=4)
    with pytest.warns(DeprecationWarning, match="request-"):
        out = eng.generate(prompts, max_new_tokens=5)
    assert out.shape == (3, 5)
    eng2 = ServeEngine(cfg, params, max_seq=32, max_slots=4)
    want = eng2.run([(p, SamplingParams(max_new_tokens=5)) for p in prompts])
    np.testing.assert_array_equal(out, np.stack(list(want.values())))


# --------------------------------------------------- session close regression
def test_session_close_is_idempotent_and_reports_survive():
    """Regression for the engine's with-block + explicit-close pattern:
    closing an exited session (or closing twice) must be a no-op, and
    reports must stay readable after close."""
    with pasta.Session(tools="kernel_freq", name="s") as s:
        s.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="k",
                             attrs={"count": 3}))
    s.close()
    s.close()                                   # double close: no-op
    assert s.closed
    rep = s.reports()                           # readable after close
    assert rep["kernel_freq"]["total_invocations"] == 3
    with pytest.raises(RuntimeError):
        with s:                                 # closed sessions don't reopen
            pass


def test_buffered_session_close_flushes_pending_rows():
    """close() without exiting the context must not drop buffered rows."""
    s = pasta.Session(tools="kernel_freq", buffered=True,
                      buffer_capacity=64, name="buf")
    s.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="k",
                         attrs={"count": 5}))
    s.close()                                   # never entered / exited
    assert s.reports()["kernel_freq"]["total_invocations"] == 5


def test_close_inside_with_block_is_safe():
    with pasta.Session(tools="kernel_freq", name="inner") as s:
        s.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="k"))
        s.close()                               # close before __exit__
    assert s.reports()["kernel_freq"]["total_invocations"] == 1


# ------------------------------------------------------------------- streaming
def test_stream_yields_tokens_in_production_order():
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (6, 10))
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=2)
    seen = {0: [], 1: []}
    done = set()
    for rid, tok, fin in eng.stream(
            [(p, SamplingParams(max_new_tokens=4)) for p in prompts]):
        seen[rid].append(tok)
        if fin:
            done.add(rid)
    assert done == {0, 1}
    for rid, prompt in enumerate(prompts):
        want = _solo(cfg, params, prompt, 4, max_seq=32, max_slots=2)
        np.testing.assert_array_equal(np.asarray(seen[rid], np.int32), want)


def test_retired_request_pruning_does_not_lose_run_results():
    """run() larger than max_retained_requests must still return every
    request's tokens (snapshotted at retirement, before FIFO pruning)."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (8,) * 6)
    eng = ServeEngine(cfg, params, max_seq=16, max_slots=2,
                      max_retained_requests=2)
    out = eng.run([(p, SamplingParams(max_new_tokens=2)) for p in prompts])
    assert sorted(out) == list(range(6))
    assert all(len(t) == 2 for t in out.values())
    # host bookkeeping stays bounded: only the retained tail survives
    assert len(eng.requests) <= 2


def test_stream_done_flag_marks_only_last_token():
    """A request can land two tokens in one tick (prefill + fused decode);
    only the LAST one may carry done=True."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    (prompt,) = _ragged_prompts(cfg, (8,))
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=1)
    events = list(eng.stream([(prompt, SamplingParams(max_new_tokens=2))]))
    assert [fin for _, _, fin in events] == [False, True]


# ------------------------------------------------------- paged KV block pool
def test_paged_chunked_staggered_matches_solo():
    """The acceptance scenario: 8 staggered ragged requests on 4 slots with
    chunked prefill == per-request solo runs (unchunked), token-for-token at
    temperature 0."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    lens = (9, 17, 5, 12, 23, 7, 14, 10)
    prompts = _ragged_prompts(cfg, lens)
    sp = SamplingParams(max_new_tokens=5)

    eng = ServeEngine(cfg, params, max_seq=48, max_slots=4, prefix_block=8,
                      prefill_chunk=8)
    rids = [eng.submit(p, sp) for p in prompts[:5]]
    eng.step()
    rids += [eng.submit(p, sp) for p in prompts[5:]]
    while eng.sched.has_work:
        eng.step()

    for rid, prompt in zip(rids, prompts):
        got = np.asarray(eng.requests[rid].tokens, np.int32)
        want = _solo(cfg, params, prompt, 5, max_seq=48, max_slots=4)
        np.testing.assert_array_equal(got, want, err_msg=f"rid={rid}")
    assert eng.duplicate_copy_bytes == 0
    assert eng.pool.n_used == eng.pool.stats()["store_blocks"]   # only store


def test_prefix_hit_aliases_blocks_without_copy():
    """A paged prefix hit binds the STORED blocks into the new request's
    table (refcount >= 2: store + live) and never copies K/V through the
    host — while still decoding byte-identically to a cold engine."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    p2 = np.concatenate([base, rng.integers(0, cfg.vocab_size, (11,),
                                            dtype=np.int32)])
    sp = SamplingParams(max_new_tokens=5)

    warm = ServeEngine(cfg, params, max_seq=64, max_slots=2, prefix_block=8)
    warm.run([(base, sp)])
    assert warm.duplicate_copy_bytes == 0
    assert warm.pool.stats()["store_blocks"] == 4          # 32 tokens / 8

    rid = warm.submit(p2, sp)
    warm.step()
    req = warm.requests[rid]
    assert req.cached_tokens == 32
    shared = warm.pool.tables[req.slot][:4]
    # each aliased block: one store ref + this request's live ref
    for b in shared:
        assert warm.pool._refs[int(b)] >= 2
        assert warm.pool._store_refs[int(b)] >= 1
    while warm.sched.has_work:
        warm.step()
    assert warm.duplicate_copy_bytes == 0

    out_cold = _solo(cfg, params, p2, 5, max_seq=64, max_slots=2,
                     prefix_cache=False)
    np.testing.assert_array_equal(
        np.asarray(req.tokens, np.int32), out_cold)


def test_chunked_prefill_interleaves_with_decode():
    """A long cold prompt prefills in chunks across ticks while a
    co-resident short request keeps decoding — the per-tick prefill work is
    bounded by the chunk, and both outputs stay byte-identical to solo."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    long_p, short_p = _ragged_prompts(cfg, (64, 8), seed=3)
    with pasta.Session(tools="serving", name="fleet") as sess:
        eng = ServeEngine(cfg, params, max_seq=96, max_slots=2,
                          session=sess, prefix_block=8, prefill_chunk=16)
        rs = eng.submit(short_p, SamplingParams(max_new_tokens=8))
        eng.step()               # short admits + prefills alone
        rl = eng.submit(long_p, SamplingParams(max_new_tokens=4))
        overlap = 0
        while eng.sched.has_work:
            before = len(eng.requests[rs].tokens)
            eng.step()
            if not eng.requests[rl].prefilled \
                    and len(eng.requests[rs].tokens) > before:
                overlap += 1
    assert overlap >= 2          # short request decoded DURING the prefill
    rep = sess.reports()["serving"].data
    assert rep["prefill"]["chunked_events"] == 5           # 64/16 + the short
    assert 0 < rep["prefill"]["max_tokens_per_tick"] <= 16
    assert rep["prefill"]["max_stall_s"] > 0
    assert rep["pool"]["duplicate_copy_bytes"] == 0
    assert rep["pool"]["utilization_max"] > 0

    for rid, prompt, n in ((rl, long_p, 4), (rs, short_p, 8)):
        want = _solo(cfg, params, prompt, n, max_seq=96, max_slots=2)
        np.testing.assert_array_equal(
            np.asarray(eng.requests[rid].tokens, np.int32), want)


def test_block_exhaustion_queues_head_of_line():
    """Admission is gated on block availability, not just free slots: a
    request that does not fit waits (FCFS, no overtaking) and is admitted
    once retirement frees blocks — with correct output."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (16, 16), seed=4)
    sp = SamplingParams(max_new_tokens=8)
    # horizon 24 tokens -> 3 blocks of 8 each; 5 total blocks fit only one
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=2, prefix_block=8,
                      prefix_cache=False, n_blocks=5)
    rids = [eng.submit(p, sp) for p in prompts]
    eng.step()
    assert eng.sched.n_active == 1 and eng.sched.n_queued == 1
    assert eng.sched.n_free >= 1                 # a slot is free; blocks not
    while eng.sched.has_work:
        eng.step()
    for rid, prompt in zip(rids, prompts):
        want = _solo(cfg, params, prompt, 8, max_seq=32, max_slots=2)
        np.testing.assert_array_equal(
            np.asarray(eng.requests[rid].tokens, np.int32), want)


def test_paged_pool_allocator_refcounts_and_eviction():
    cfg = C.reduced(C.get("paper-gpt2"))
    pool = PagedKVPool(cfg, slots=2, max_seq=32, block_size=8)  # 16 blocks
    ids = pool.alloc(3)
    assert pool.n_used == 3 and pool.n_free == 13
    pool.retain(ids[:2], store=True)             # publish two blocks
    assert pool.n_evictable() == 0               # live ref still held
    pool.release(ids)                            # live refs dropped
    assert pool.n_used == 2 and pool.n_evictable() == 2
    assert pool.available() == 16
    # allocation under pressure drains the store via evict_cb
    store = [ids[:2]]
    pool.evict_cb = lambda: (bool(store)
                             and (pool.release(store.pop(), store=True)
                                  or True))
    big = pool.alloc(15)
    assert big is not None and store == [] and pool.n_used == 15
    assert pool.alloc(5) is None                 # truly exhausted
    # bind/free: a slot owns its alloc refs; free returns blocks and
    # resets the table row to the sentinel
    pool.bind_slot(0, [], big[:4])
    pool.free_slot(0)
    assert pool.n_used == 11
    assert (pool.tables[0] == pool.n_blocks).all()


def test_legacy_dense_pool_still_copies_and_matches():
    """paged=False keeps the dense (slots, max_seq) rows + host-copy prefix
    store: equivalent tokens, but nonzero duplicate_copy_bytes."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    p2 = np.concatenate([base, rng.integers(0, cfg.vocab_size, (11,),
                                            dtype=np.int32)])
    sp = SamplingParams(max_new_tokens=5)
    legacy = ServeEngine(cfg, params, max_seq=64, max_slots=2,
                         prefix_block=8, paged=False)
    paged = ServeEngine(cfg, params, max_seq=64, max_slots=2, prefix_block=8)
    for eng in (legacy, paged):
        eng.run([(base, sp)])
    assert legacy.duplicate_copy_bytes > 0 and paged.duplicate_copy_bytes == 0
    out_l = legacy.run([(p2, sp)])
    out_p = paged.run([(p2, sp)])
    np.testing.assert_array_equal(list(out_l.values())[0],
                                  list(out_p.values())[0])
    # traffic stats agree (entry counts differ by design: legacy also
    # publishes a full-length non-aligned key, paged keys stop at the
    # last block boundary)
    sl, sp_ = legacy.prefix_cache.stats(), paged.prefix_cache.stats()
    for k in ("lookups", "hits", "hit_rate", "reused_tokens", "reused_frac"):
        assert sl[k] == sp_[k], k


def test_paged_rejects_stateful_families_and_chunk_requires_paged():
    cfg = C.reduced(C.get("mamba2-2.7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="KV-only"):
        ServeEngine(cfg, params, max_seq=32, max_slots=1, paged=True)
    dense = C.reduced(C.get("paper-gpt2"))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(dense, init_params(jax.random.PRNGKey(0), dense),
                    max_seq=32, max_slots=1, paged=False, prefill_chunk=8)


# ------------------------------------------------------------ satellite fixes
def test_pad_group_caps_bucket_at_max_len():
    """The pow2 bucket must not outgrow the pool bound (and an oversized
    prompt is an error, not a silent crop of real tokens)."""
    toks, lens = pad_group([np.arange(33, dtype=np.int32)], max_len=40)
    assert toks.shape == (1, 40) and lens.tolist() == [33]   # not bucket=64
    with pytest.raises(ValueError, match="exceeds the pool bound"):
        pad_group([np.arange(50, dtype=np.int32)], max_len=40)


def test_prefix_cache_covers_is_pure_and_lru_is_recency_ordered():
    """covers() must not count as traffic or touch recency; lookup() does
    both; eviction drops the least-recently-USED entry and releases its
    blocks through on_evict."""
    evicted, retained = [], []
    pc = PrefixCache(block=4, capacity=2, on_evict=evicted.append)
    toks = np.arange(10, dtype=np.int32)
    pc.insert_blocks(toks, [1, 2, 9, 9], on_retain=retained.append)
    assert retained == [(1,), (1, 2)]            # keys at L=4 and L=8
    assert pc.covers(toks, 8) and not pc.covers(toks)      # full 10: no key
    assert pc.covers(toks, 0)                              # trivially covered
    assert pc.stats()["lookups"] == 0            # covers() left no trace
    hit, ent = pc.lookup(toks)                   # touches the L=8 entry
    assert (hit, ent) == (8, (1, 2))
    st = pc.stats()
    assert st["lookups"] == 1 and st["hits"] == 1 and st["hit_rate"] == 1.0
    # overflow evicts the LRU entry -- the UNtouched L=4 one
    pc.insert_blocks(np.asarray([7, 7, 7, 7], np.int32), [5, 9],
                     on_retain=retained.append)
    assert evicted == [(1,)] and retained[-1] == (5,)
    assert pc.covers(toks, 8) and not pc.covers(toks, 4)


def test_tool_and_cache_hit_rates_agree():
    """Satellite 3: the serving tool's per-admission hit rate and the
    PrefixCache's per-lookup hit rate share one denominator (the engine
    performs exactly one lookup per admission)."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (3, 9, 5, 14, 7, 11, 4, 8),
                              shared_prefix=24)
    with pasta.Session(tools="serving", name="fleet") as sess:
        eng = ServeEngine(cfg, params, max_seq=64, max_slots=4, session=sess,
                          prefix_block=8)
        eng.run([(p, SamplingParams(max_new_tokens=4)) for p in prompts])
    rep = sess.reports()["serving"].data["prefix_cache"]
    cs = eng.prefix_cache.stats()
    assert rep["admits"] == cs["lookups"] == 8
    assert rep["hits"] == cs["hits"] > 0
    assert rep["hit_rate"] == pytest.approx(cs["hit_rate"])
    assert rep["reused_tokens"] == cs["reused_tokens"]


def test_abort_releases_slot_blocks_and_session():
    """Satellite 4: abort() at any stage returns the slot and every pool
    block, closes the child session, and leaves the engine serving."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (8, 8, 8), seed=5)
    sp = SamplingParams(max_new_tokens=32)
    eng = ServeEngine(cfg, params, max_seq=48, max_slots=2, prefix_block=8)
    rids = [eng.submit(p, sp) for p in prompts]
    eng.step()                                   # 0, 1 running; 2 queued
    assert eng.abort(rids[2])                    # queued abort
    assert eng.requests[rids[2]].state is RequestState.ABORTED
    assert eng.sched.n_queued == 0
    victim = eng.requests[rids[1]]
    assert eng.abort(rids[1])                    # running abort
    assert victim.state is RequestState.ABORTED and victim.slot is None
    assert victim.session is None
    assert eng.sched.n_free == 1
    assert not eng.abort(rids[1])                # idempotent
    while eng.sched.has_work:
        eng.step()
    want = _solo(cfg, params, prompts[0], 32, max_seq=48, max_slots=2)
    np.testing.assert_array_equal(
        np.asarray(eng.requests[rids[0]].tokens, np.int32), want)
    # every block left in the pool is store-held; no live leaks
    assert eng.pool.n_used == eng.pool.stats()["store_blocks"]


def test_mid_drain_failure_aborts_all_and_engine_survives():
    """Satellite 4: an exception inside a tick (injected sampling failure)
    must not leak slots, blocks, or open sessions — and the engine must
    keep serving afterwards."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (8, 12, 6), seed=6)
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=2, prefix_block=8)

    calls = {"n": 0}
    real = eng._sample_one

    def flaky(req, logits_row):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected sampling failure")
        return real(req, logits_row)

    eng._sample_one = flaky
    with pytest.raises(RuntimeError, match="injected"):
        eng.run([(p, SamplingParams(max_new_tokens=4)) for p in prompts])
    assert not eng.sched.has_work                # everything aborted
    assert eng.sched.n_free == 2
    assert all(r.session is None for r in eng.requests.values())
    assert (eng.pool._refs == eng.pool._store_refs).all()    # no live refs

    eng._sample_one = real                       # fault cleared: still serves
    (prompt,) = _ragged_prompts(cfg, (9,), seed=7)
    out = list(eng.run([(prompt, SamplingParams(max_new_tokens=4))])
               .values())[0]
    want = _solo(cfg, params, prompt, 4, max_seq=32, max_slots=2)
    np.testing.assert_array_equal(out, want)


# ----------------------------------------------------------------- CLI driver
def test_serve_driver_cli_json(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    path = tmp_path / "serve.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--reduced",
         "--num-requests", "4", "--max-slots", "2", "--prompt-len", "16",
         "--shared-prefix", "12", "--prefix-block", "8",
         "--max-new-tokens", "4", "--json", str(path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    out = json.loads(path.read_text())
    assert out["status"] == "ok" and out["driver"] == "serve"
    assert out["summary"]["generated_tokens"] == 16
    assert out["summary"]["occupancy_mean"] > 1
    assert out["summary"]["prefix_hit_rate"] > 0
    assert out["summary"]["ttft_s"]["p50"] > 0
    assert len(out["requests"]) == 4            # per-request serving reports
    assert set(map(int, out["tokens"]))== {0, 1, 2, 3}
    assert "serving" in out["fleet"] and "kernel_freq" in out["fleet"]


# ---------------------------------------------------------- speculative decode
def _run_staggered(cfg, params, prompts, max_new=16, **kw):
    """Staggered shared-prefix trace; returns (engine, {rid: tokens})."""
    sp = SamplingParams(max_new_tokens=max_new,
                        temperature=kw.pop("temperature", 0.0),
                        seed=kw.pop("sampling_seed", None))
    eng = ServeEngine(cfg, params, **kw)
    out = {}
    for p in prompts[: len(prompts) // 2]:
        out[eng.submit(p, sp)] = None
    eng.step()
    for p in prompts[len(prompts) // 2:]:
        out[eng.submit(p, sp)] = None
    while eng.sched.has_work:
        for rid in eng.step()["finished"]:
            out[rid] = list(eng.requests[rid].tokens)
    return eng, out


def test_spec_decode_byte_identical_paged():
    """k=4 n-gram speculation over the paged pool: staggered ragged
    admission with prefix hits produces byte-identical tokens to the
    non-speculative engine AND to solo runs, and the rollback-heavy trace
    leaves the block pool balanced."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 17, 5, 12, 23, 7), shared_prefix=24)
    base_eng, base = _run_staggered(cfg, params, prompts,
                                    max_seq=128, max_slots=4)
    spec_eng, spec = _run_staggered(cfg, params, prompts,
                                    max_seq=128, max_slots=4, spec_decode=4)
    for rid in base:
        np.testing.assert_array_equal(base[rid], spec[rid],
                                      err_msg=f"rid={rid}")
    solo = _solo(cfg, params, prompts[0], 16, max_seq=128, max_slots=4,
                 spec_decode=4)
    np.testing.assert_array_equal(base[0], solo)
    assert spec_eng.drafted_tokens > 0
    assert 0 < spec_eng.accepted_tokens <= spec_eng.drafted_tokens
    assert spec_eng.decode_steps < base_eng.decode_steps
    spec_eng.pool.scrub()
    st = spec_eng.pool.stats()
    assert (st["blocks_live"] + st["blocks_evictable"]
            + st["blocks_free"] == st["n_blocks"]), st


def test_spec_decode_byte_identical_legacy_dense():
    """The legacy dense (slots, max_seq) pool supports speculation too:
    rollback is free (host lengths are authoritative), output unchanged."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 17, 5, 12), shared_prefix=16)
    _, base = _run_staggered(cfg, params, prompts,
                             max_seq=64, max_slots=2, paged=False)
    eng, spec = _run_staggered(cfg, params, prompts, max_seq=64,
                               max_slots=2, paged=False, spec_decode=3)
    assert not eng.paged and eng.spec_k == 3
    for rid in base:
        np.testing.assert_array_equal(base[rid], spec[rid])


def test_spec_decode_with_chunked_prefill():
    """Chunked prefill (pre-decode multi-token appends) composes with
    speculative verify on the same per-query-causal cache path."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (40, 9, 26, 7), shared_prefix=8)
    _, base = _run_staggered(cfg, params, prompts,
                             max_seq=96, max_slots=3, prefill_chunk=16)
    eng, spec = _run_staggered(cfg, params, prompts, max_seq=96,
                               max_slots=3, prefill_chunk=16, spec_decode=4)
    for rid in base:
        np.testing.assert_array_equal(base[rid], spec[rid])


def test_spec_draft_model_self_draft_accepts_nearly_all():
    """draft="model" defaults to the target itself — the degenerate
    self-draft must accept (almost) every token (only drafts past a
    request's stop go unconsumed) and slash decode dispatches."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 17, 5, 12), shared_prefix=16)
    base_eng, base = _run_staggered(cfg, params, prompts,
                                    max_seq=64, max_slots=4)
    eng, out = _run_staggered(cfg, params, prompts, max_seq=64,
                              max_slots=4, spec_decode=4, draft="model")
    for rid in base:
        np.testing.assert_array_equal(base[rid], out[rid])
    assert eng.accepted_tokens / eng.drafted_tokens > 0.8
    assert eng.decode_steps <= base_eng.decode_steps // 2


def test_spec_decode_rejects_stateful_families_and_bad_draft():
    params = None
    for arch in ("mamba2-2.7b", "zamba2-7b"):
        cfg = C.reduced(C.get(arch))
        params = init_params(jax.random.PRNGKey(0), cfg)
        with pytest.raises(NotImplementedError, match="[sS]peculative"):
            ServeEngine(cfg, params, max_seq=32, max_slots=2, spec_decode=2)
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="draft"):
        ServeEngine(cfg, params, max_seq=32, max_slots=2, spec_decode=2,
                    draft="telepathy")


def test_sampling_is_schedule_invariant_at_temperature():
    """temperature>0 keys derive from (seed-or-rid, position) only, so the
    sampled stream is identical across slot budgets AND across the
    speculative/sequential split (sample-and-match)."""
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 17, 5, 12, 23, 7), shared_prefix=16)
    _, narrow = _run_staggered(cfg, params, prompts, max_new=8,
                               temperature=0.8, max_seq=96, max_slots=2)
    _, wide = _run_staggered(cfg, params, prompts, max_new=8,
                             temperature=0.8, max_seq=96, max_slots=4)
    assert narrow == wide, "sampling depended on the admission schedule"
    _, spec = _run_staggered(cfg, params, prompts, max_new=8,
                             temperature=0.8, max_seq=96, max_slots=4,
                             spec_decode=3)
    assert spec == wide, "sampling depended on the speculative schedule"


def test_paged_pool_ensure_truncate_accounting():
    """Lazy grow / rollback bookkeeping at the pool level: ensure() draws
    blocks just-in-time, truncate() returns the spill, scrub() only zeroes
    blocks that are still free."""
    cfg = C.reduced(C.get("paper-gpt2"))
    pool = PagedKVPool(cfg, slots=2, max_seq=64, block_size=8)
    ids = pool.alloc(2)
    pool.bind_slot(0, [], ids)
    assert pool.ensure(0, 16) == 0               # already covered
    grew = pool.ensure(0, 35)                    # 5 blocks total
    assert grew == 3 and pool.n_used == 5
    freed = pool.truncate(0, 17)                 # back to 3 blocks
    assert freed == 2 and pool.n_used == 3
    assert pool.truncate(0, 17) == 0             # idempotent
    assert pool._dirty and pool._dirty <= set(pool._free)
    again = pool.alloc(2)                        # reuses the spill...
    assert not (set(again) & pool._dirty)        # ...and un-dirties it
    pool.scrub()
    assert not pool._dirty
    pool.release(again)
    pool.free_slot(0)
    st = pool.stats()
    assert st["blocks_free"] == st["n_blocks"], st
    with pytest.raises(RuntimeError, match="exhausted"):
        big = PagedKVPool(cfg, slots=1, max_seq=64, block_size=8,
                          n_blocks=2)
        big.bind_slot(0, [], big.alloc(2))
        big.ensure(0, 64)


def test_ngram_proposer_prompt_lookup():
    from repro.serve import NgramProposer
    prop = NgramProposer(max_ngram=3, min_ngram=1)
    # trailing trigram [5,6,7] recurred at the start; continuation follows
    (d,) = prop.propose([np.array([5, 6, 7, 8, 9, 5, 6, 7], np.int32)], 2)
    np.testing.assert_array_equal(d, [8, 9])
    # prefers the most recent occurrence with a FULL k continuation
    (d,) = prop.propose([np.array([1, 2, 9, 1, 2, 8, 1, 2], np.int32)], 2)
    np.testing.assert_array_equal(d, [8, 1])
    # no recurrence anywhere -> empty draft (verify still commits 1 token)
    (d,) = prop.propose([np.arange(8, dtype=np.int32)], 2)
    assert len(d) == 0


def test_warmup_compiles_decode_shapes_before_trace():
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_seq=64, max_slots=2, spec_decode=3)
    wu = eng.warmup(prompt_lens=[9, 17])
    assert wu["compile_s"] > 0 and len(wu["warmed"]) >= 2
    # warmup must not perturb serving: outputs still match the reference
    (prompt,) = _ragged_prompts(cfg, (9,), seed=3)
    out = list(eng.run([(prompt, SamplingParams(max_new_tokens=6))])
               .values())[0]
    want = _solo(cfg, params, prompt, 6, max_seq=64, max_slots=2)
    np.testing.assert_array_equal(out, want)


def test_serving_tool_speculative_and_bandwidth_sections():
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 17, 5, 12), shared_prefix=16)
    with pasta.Session(tools="serving", name="spec") as sess:
        _run_staggered(cfg, params, prompts, max_seq=64, max_slots=4,
                       spec_decode=4, session=sess)
    rep = sess.reports()["serving"].data
    spec = rep["speculative"]
    assert spec["spec_k"] == 4
    assert spec["drafted_tokens"] > 0
    assert spec["acceptance_rate"] == (spec["accepted_tokens"]
                                       / spec["drafted_tokens"])
    # each request's FIRST token is sampled at prefill, not on a decode
    # tick, so decode-committed tokens trail generated by one per request
    assert spec["committed_tokens"] == (rep["generated_tokens"]
                                        - rep["finished"])
    assert spec["tokens_per_tick"] > 1
    bw = rep["bandwidth"]
    assert bw["params_bytes"] > 0 and bw["kv_read_bytes"] > 0
    assert bw["analytic_bytes_per_token"] == (
        rep["decode_steps"] * bw["params_bytes"]
        + bw["kv_read_bytes"]) / spec["committed_tokens"]
    for row in rep["by_request"].values():
        assert row["accepted"] <= row["drafted"]


# ------------------------------------------------------------ fault tolerance
def _pool_whole(eng):
    eng.pool.scrub()
    st = eng.pool.stats()
    assert (st["blocks_live"] + st["blocks_evictable"]
            + st["blocks_free"] == st["n_blocks"]), st


def _drain(eng):
    out = {}
    while eng.has_work:
        for rid in eng.step()["finished"]:
            out[rid] = list(eng.requests[rid].tokens)
    return out


def test_persistent_poison_isolated_innocents_byte_identical():
    """THE recovery contract: one persistently poisoned request ends
    ``failed`` after its retries exhaust; every innocent co-scheduled
    request finishes byte-identically to a fault-free twin, with zero
    KV bytes copied during isolation, and the serving tool's health
    section accounts for every event."""
    from repro.serve import FaultPlan, FaultSpec

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (19, 33, 25, 40, 22, 28),
                              shared_prefix=16, seed=5)
    _, base = _run_staggered(cfg, params, prompts, max_new=8,
                             max_seq=96, max_slots=4)

    plan = FaultPlan([FaultSpec(kind="poison", rid=1, ttl=10 ** 6)])
    with pasta.Session(tools="serving", name="chaos") as sess:
        sp = SamplingParams(max_new_tokens=8)
        eng = ServeEngine(cfg, params, max_seq=96, max_slots=4,
                          session=sess, faults=plan,
                          retry_backoff_s=0.0)
        rids = [eng.submit(p, sp) for p in prompts[:3]]
        eng.step()
        rids += [eng.submit(p, sp) for p in prompts[3:]]
        _drain(eng)
    assert eng.requests[1].state is RequestState.FAILED
    for rid in rids:
        if rid == 1:
            continue
        assert eng.requests[rid].state is RequestState.FINISHED
        assert list(eng.requests[rid].tokens) == base[rid], f"rid={rid}"
    h = eng.health()
    assert h["failed"] == 1 and h["request_retries"] == 2
    assert h["fault_ticks"] >= 3 and h["probes"] > 0
    assert h["isolated_innocents"] > 0 and h["retry_backlog"] == 0
    _pool_whole(eng)
    rep = sess.reports()["serving"].data
    assert rep["pool"]["duplicate_copy_bytes"] == 0
    th = rep["health"]
    assert th["failed"] == 1 and th["retries"] == h["request_retries"]
    assert th["blamed_requests"] == 3          # one blame per fault tick
    assert th["isolated_innocents"] == h["isolated_innocents"]
    assert th["probes"] == h["probes"]
    assert th["recomputed_tokens"] == h["recomputed_tokens"] > 0
    assert rep["by_request"][1]["status"] == "failed"


def test_nan_logits_surgical_blame_no_tick_abandon():
    """A NaN logits row blames exactly its request (no bisection, no
    innocent preemption); the victim retries to a byte-identical finish."""
    from repro.serve import FaultPlan, FaultSpec

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 14, 11, 7), seed=6)
    _, base = _run_staggered(cfg, params, prompts, max_new=6,
                             max_seq=64, max_slots=4)
    plan = FaultPlan([FaultSpec(kind="nan_logits", rid=2, ttl=1)])
    eng, out = _run_staggered(cfg, params, prompts, max_new=6, max_seq=64,
                              max_slots=4, faults=plan, retry_backoff_s=0.0)
    assert out == base
    h = eng.health()
    assert h["request_retries"] == 1 and h["failed"] == 0
    assert h["isolated_innocents"] == 0 and h["probes"] == 0
    _pool_whole(eng)


def test_transient_tick_error_retries_tick():
    """An unattributable tick error retries the whole tick: nobody is
    blamed, nothing is lost, outputs are byte-identical."""
    from repro.serve import FaultPlan, FaultSpec

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 14, 11), seed=7)
    _, base = _run_staggered(cfg, params, prompts, max_new=6,
                             max_seq=64, max_slots=2)
    plan = FaultPlan([FaultSpec(kind="tick_error", tick=3)])
    eng, out = _run_staggered(cfg, params, prompts, max_new=6, max_seq=64,
                              max_slots=2, faults=plan)
    assert out == base
    h = eng.health()
    assert h["tick_retries"] == 1 and h["request_retries"] == 0
    assert h["fault_ticks"] == 1 and h["failed"] == 0


def test_host_preempt_signal_is_lossless():
    """A host-preemption signal parks a runner in the prefix store; it
    resumes byte-identically (zero-copy, like a policy preemption)."""
    from repro.serve import FaultPlan, FaultSpec

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (21, 35, 27), shared_prefix=16, seed=8)
    _, base = _run_staggered(cfg, params, prompts, max_new=8,
                             max_seq=96, max_slots=2)
    plan = FaultPlan([FaultSpec(kind="preempt", tick=4, count=1)])
    eng, out = _run_staggered(cfg, params, prompts, max_new=8, max_seq=96,
                              max_slots=2, faults=plan)
    assert out == base
    h = eng.health()
    assert h["host_preempt_signals"] == 1
    assert h["recovered_tokens"] > 0           # the park round-tripped KV
    _pool_whole(eng)


def test_deadline_s_is_hard_timeout():
    """``SLOSpec.deadline_s`` cancels the request (state ``timeout``) and
    releases every resource; co-running requests are untouched."""
    from repro.serve import SLOSpec

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    p_doomed, p_fine = _ragged_prompts(cfg, (9, 12), seed=9)
    eng = ServeEngine(cfg, params, max_seq=64, max_slots=2)
    sp = SamplingParams(max_new_tokens=48)
    doomed = eng.submit(p_doomed, sp, slo=SLOSpec(deadline_s=0.0))
    fine = eng.submit(p_fine, SamplingParams(max_new_tokens=4))
    _drain(eng)
    assert eng.requests[doomed].state is RequestState.TIMEOUT
    assert eng.requests[fine].state is RequestState.FINISHED
    assert eng.health()["timeouts"] == 1
    _pool_whole(eng)


def test_retry_exhaustion_and_abort_in_backoff():
    """max_request_retries bounds blame retries; a request waiting in the
    retry pen can still be aborted cleanly."""
    from repro.serve import FaultPlan, FaultSpec

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    (prompt,) = _ragged_prompts(cfg, (9,), seed=10)
    sp = SamplingParams(max_new_tokens=4)

    plan = FaultPlan([FaultSpec(kind="poison", rid=0, ttl=10 ** 6)])
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=1, faults=plan,
                      max_request_retries=1, retry_backoff_s=0.0)
    rid = eng.submit(prompt, sp)
    _drain(eng)
    assert eng.requests[rid].state is RequestState.FAILED
    assert eng.health()["request_retries"] == 1    # then the cap fails it
    _pool_whole(eng)

    plan2 = FaultPlan([FaultSpec(kind="poison", rid=0, ttl=10 ** 6)])
    eng2 = ServeEngine(cfg, params, max_seq=32, max_slots=1, faults=plan2,
                       retry_backoff_s=60.0)      # parks rid 0 in the pen
    rid2 = eng2.submit(prompt, sp)
    eng2.step()
    assert eng2.health()["retry_backlog"] == 1 and eng2.has_work
    assert eng2.abort(rid2) is True
    assert eng2.requests[rid2].state is RequestState.ABORTED
    assert not eng2.has_work and eng2.health()["retry_backlog"] == 0
    _pool_whole(eng2)


def test_degradation_ladder_sheds_and_restores():
    """Sustained slow ticks shed spec decode, then restore once calm; at
    level 3 admissions are rejected outright."""
    from repro.serve import FaultPlan, FaultSpec

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _ragged_prompts(cfg, (9, 14, 11, 7), seed=11)
    # the stall lands AFTER the 5-tick median warm-up window, so the
    # baseline the 3x-median detector compares against is the fast ticks
    plan = FaultPlan([FaultSpec(kind="stall", tick=8, duration=3,
                                stall_s=0.03)])
    eng = ServeEngine(cfg, params, max_seq=64, max_slots=4, faults=plan,
                      spec_decode=3, slow_tick_s=0.005)
    eng.warmup(sorted({len(p) for p in prompts}))
    _, base = _run_staggered(cfg, params, prompts, max_new=40,
                             max_seq=64, max_slots=4, spec_decode=3)
    sp = SamplingParams(max_new_tokens=40)
    rids = [eng.submit(p, sp) for p in prompts]
    out = {r: None for r in rids}
    out.update(_drain(eng))
    h = eng.health()
    assert h["degraded_ticks"] > 0, h          # the ladder shed load
    # shedding speculation is a scheduling change only: outputs unchanged
    assert out == base
    for _ in range(20):                        # idle ticks are calm ticks:
        eng.step()                             # the ladder must restore
    assert eng.degrade_level == 0, eng.health()

    eng.degrade_level = 3                      # white-box: saturated ladder
    rej = eng.submit(prompts[0], sp)
    assert eng.requests[rej].state is RequestState.REJECTED
    assert eng.health()["rejections"] == 1
    assert not eng.has_work                    # rejected work never queues


def test_fault_injection_requires_paged_mode():
    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, max_seq=32, max_slots=1, paged=False,
                    faults="storm")
    with pytest.raises(ValueError, match="preset"):
        ServeEngine(cfg, params, max_seq=32, max_slots=1, faults="kaboom")
