"""repro.dist.sharding rule-table tests — single device, no subprocess.

The 8-device behaviour (actual resharded execution) lives in test_dist.py;
these tests pin the *resolution* semantics: every logical axis name the
models emit resolves, rank mismatches are tolerated hints, divisibility and
duplicate-axis filtering work, and set_mesh/get_rules override semantics
match what trainer.tree_shardings relies on.
"""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.dist.collectives import dequantize_int8, quantize_int8
from repro.dist.sharding import (DEFAULT_RULES, ShardingRules, get_mesh,
                                 get_rules, logical, mesh_axis_size,
                                 set_mesh, shard)
from repro.models import cache_axes, param_axes

# the activation-annotation names used by models.{layers,lm,moe,mamba2}
ACTIVATION_AXES = [
    "batch", "seq", "seq_sp", "heads", "kv_heads", "head_dim", "embed",
    "ff", "vocab", "experts", "experts_ep", "expert_ff", "p_ssm_inner",
    "ssm_heads",
]

# duck-typed stand-in for a multi-device mesh (logical() only reads
# .shape/.axis_names, so rule resolution is testable on one CPU device)
FAKE_MESH = types.SimpleNamespace(shape={"data": 2, "model": 4},
                                  axis_names=("data", "model"))
FAKE_POD_MESH = types.SimpleNamespace(
    shape={"pod": 2, "data": 2, "model": 2},
    axis_names=("pod", "data", "model"))


@pytest.fixture(autouse=True)
def _reset_global_mesh():
    yield
    set_mesh(None)


def _axis_names(tree):
    is_ax = lambda x: isinstance(x, tuple)  # noqa: E731
    names = set()
    for leaf in jax.tree.leaves(tree, is_leaf=is_ax):
        names.update(a for a in leaf if a is not None)
    return names


def test_default_rules_cover_every_model_axis_name():
    names = set(ACTIVATION_AXES)
    import dataclasses
    for arch in C.ASSIGNED:
        cfg = C.reduced(C.get(arch))
        names |= _axis_names(param_axes(cfg))
        names |= _axis_names(cache_axes(cfg))
        if cfg.n_experts:                     # both MoE parallelism modes
            cfg_ep = dataclasses.replace(cfg, moe_parallelism="ep")
            names |= _axis_names(param_axes(cfg_ep))
    missing = {n for n in names if n not in DEFAULT_RULES}
    assert not missing, f"DEFAULT_RULES missing {sorted(missing)}"
    for n in sorted(names):                   # and each resolves standalone
        logical(n, mesh=FAKE_POD_MESH)


def test_logical_resolves_named_axes():
    assert logical("p_vocab", "p_embed", mesh=FAKE_MESH) == P("model", "data")
    assert logical("p_embed", "p_ff", mesh=FAKE_MESH) == P("data", "model")
    assert logical("seq_sp", mesh=FAKE_MESH) == P("model")
    assert logical("p_ssm_inner", mesh=FAKE_MESH) == P("model")
    assert logical("expert_ff", mesh=FAKE_MESH) == P("model")
    assert logical(None, "seq", "embed", mesh=FAKE_MESH) == P(None, None, None)


def test_logical_batch_composes_pod_and_data():
    assert logical("batch", mesh=FAKE_POD_MESH) == P(("pod", "data"))
    # pod axis absent -> silently drops to data only
    assert logical("batch", mesh=FAKE_MESH) == P("data")


def test_logical_drops_duplicate_physical_axes():
    # TP-MoE expert weights: p_experts claims "data" first, p_embed yields
    spec = logical("p_experts", "p_embed", "p_expert_ff", mesh=FAKE_MESH)
    assert spec == P("data", None, "model")


def test_logical_divisibility_filter():
    # 1 KV head can't shard 4 ways -> dropped; the rest shard normally
    spec = logical("p_embed", "p_kv_heads", None, dims=(64, 1, 16),
                   mesh=FAKE_MESH)
    assert spec == P("data", None, None)
    spec = logical("p_embed", "p_heads", None, dims=(64, 4, 16),
                   mesh=FAKE_MESH)
    assert spec == P("data", "model", None)


def test_logical_rank_mismatch_raises_with_dims():
    with pytest.raises(ValueError):
        logical("p_embed", "p_ff", dims=(64,), mesh=FAKE_MESH)


def test_logical_unknown_axis_raises():
    with pytest.raises(KeyError):
        logical("p_nonexistent", mesh=FAKE_MESH)


def test_shard_is_noop_without_mesh():
    set_mesh(None)
    x = jnp.ones((4, 8))
    assert shard(x, "batch", "embed") is x


def test_shard_rank_mismatch_is_tolerated_hint():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    set_mesh(mesh)
    x = jnp.ones((4, 8))
    assert shard(x, "batch") is x             # rank 1 hint on rank-2 tensor
    y = shard(x, "batch", "embed")            # matching rank constrains
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_set_mesh_rules_override_and_reset():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    set_mesh(mesh, ShardingRules({**DEFAULT_RULES, "p_embed": None}))
    assert get_mesh() is mesh
    assert get_rules()["p_embed"] is None
    assert logical("p_embed", mesh=FAKE_MESH) == P(None)
    # trainer.tree_shardings keeps custom rules alive explicitly:
    set_mesh(mesh, get_rules())
    assert get_rules()["p_embed"] is None
    # plain set_mesh resets to the defaults (dryrun.run_cell relies on it)
    set_mesh(mesh)
    assert get_rules() == DEFAULT_RULES
    assert logical("p_embed", mesh=FAKE_MESH) == P("data")


def test_mesh_axis_size_defaults_to_one():
    set_mesh(None)
    assert mesh_axis_size("data") == 1
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    set_mesh(mesh)
    assert mesh_axis_size("data") == 1
    assert mesh_axis_size("pod") == 1         # absent axis


def test_quantize_int8_roundtrip_bounds(rng):
    x = jnp.asarray(rng.standard_normal((16, 64)) * 3.0, jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8 and s.shape == (16, 1)
    back = dequantize_int8(q, s)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    # per-element error bounded by half a quantization step (slack for
    # rounding), and <1% relative error overall
    assert np.all(np.abs(np.asarray(back - x)) <= amax / 126.0 + 1e-12)
    rel = float(jnp.linalg.norm(back - x) / jnp.linalg.norm(x))
    assert rel < 0.01, rel


def test_quantize_int8_preserves_shapes_and_zeros(rng):
    x = jnp.zeros((4, 4), jnp.float32)
    q, s = quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)), 0.0)
    x3 = jnp.asarray(rng.standard_normal((2, 3, 5)), jnp.float32)
    q3, s3 = quantize_int8(x3)
    assert q3.shape == x3.shape and s3.shape == (2, 3, 1)
