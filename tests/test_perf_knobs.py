"""Correctness of the §Perf hillclimb levers (they must not change math)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import init_params, forward
from repro.models.layers import _sdpa_dense, _group
import dataclasses


def test_bf16_softmax_close_to_f32(rng):
    b, s, hkv, g, d = 2, 128, 2, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, hkv * g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    qg = _group(q, hkv)
    a32 = _sdpa_dense(qg, k, v, causal=True, softmax_dtype=jnp.float32)
    a16 = _sdpa_dense(qg.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                      v.astype(jnp.bfloat16), causal=True,
                      softmax_dtype=jnp.bfloat16)
    err = float(jnp.abs(a32 - a16.astype(jnp.float32)).max())
    assert err < 2e-2, err


@pytest.mark.parametrize("arch", ["dbrx-132b", "kimi-k2-1t-a32b"])
def test_ep_moe_matches_tp_moe(arch, rng):
    """moe_parallelism only changes sharding constraints, never values."""
    cfg_tp = C.reduced(C.get(arch))
    cfg_ep = dataclasses.replace(cfg_tp, moe_parallelism="ep")
    params = init_params(jax.random.PRNGKey(0), cfg_tp)
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                           cfg_tp.vocab_size)
    l_tp, _ = forward(params, x, cfg_tp)
    l_ep, _ = forward(params, x, cfg_ep)
    np.testing.assert_allclose(np.asarray(l_tp), np.asarray(l_ep),
                               atol=1e-5, rtol=1e-5)


def test_gather_once_step_matches_baseline(rng):
    """gather_params_once must be numerically identical (single device:
    constraint is a no-op; the multi-device path is covered by the
    sharding-only nature of the transform)."""
    from repro.train import OptConfig, make_train_step
    from repro.train.optimizer import init_opt_state
    cfg = C.reduced(C.get("qwen3-32b"))
    cfg_g = dataclasses.replace(cfg, gather_params_once=True)
    opt_cfg = OptConfig(lr=1e-3)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, opt_cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    batch = {"inputs": x, "labels": x}
    p1, _, m1 = jax.jit(make_train_step(cfg, opt_cfg, 2))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg_g, opt_cfg, 2))(params, opt,
                                                            batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-6)


def test_blocked_threshold_switch_consistent(rng):
    """Forcing the blocked path at short seq matches the dense path."""
    cfg = C.reduced(C.get("stablelm-1.6b"))
    cfg_b = dataclasses.replace(cfg, attn_blocked_threshold=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    l_d, _ = forward(params, x, cfg)
    l_b, _ = forward(params, x, cfg_b)
    np.testing.assert_allclose(np.asarray(l_d), np.asarray(l_b),
                               atol=2e-4, rtol=1e-3)


def test_two_tier_kv_decode_matches_baseline(rng):
    """Two-tier decode (frozen main + replicated recent buffer) must produce
    the same logits as the baseline in-place-update cache."""
    import jax
    import jax.numpy as jnp
    from repro.models import init_cache
    cfg = C.reduced(C.get("qwen3-32b"))
    cfg2 = dataclasses.replace(cfg, kv_two_tier=True, kv_recent_len=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0,
                              cfg.vocab_size)
    # teacher-forced reference
    full, _ = forward(params, toks, cfg)
    # prefill 32 into a baseline cache, then convert to two-tier
    _, cache = forward(params, toks[:, :32], cfg, return_cache=True,
                       logits_mode="last")
    kv = cache["kv"]
    n = kv["k"].shape[0]
    two = {"kv": {
        "k": kv["k"], "v": kv["v"], "length": kv["length"],
        "main_len": kv["length"],
        "rk": jnp.zeros((n, 2, 8, cfg.n_kv_heads, cfg.head_dim),
                        kv["k"].dtype),
        "rv": jnp.zeros((n, 2, 8, cfg.n_kv_heads, cfg.head_dim),
                        kv["k"].dtype),
    }}
    errs = []
    for t in range(32, 40):
        lg, two = forward(params, toks[:, t:t + 1], cfg2, cache=two,
                          logits_mode="last")
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
        # main cache must be bit-identical (frozen)
        assert two["kv"]["k"] is kv["k"] or float(
            jnp.abs(two["kv"]["k"] - kv["k"]).max()) == 0.0
    assert max(errs) < 2e-2, errs
