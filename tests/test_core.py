"""PASTA core: events, annotations, pool, processor, tools, HLO walker."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as pasta
from repro.core.events import Event, EventKind
from repro.core.tools import offload


# ------------------------------------------------------------- annotations
def test_region_stack_and_events(handler):
    seen = []
    handler.subscribe(lambda e: seen.append(e),
                      kinds=(EventKind.REGION_START, EventKind.REGION_END))
    with pasta.region("fwd"):
        assert pasta.current_region() == ("fwd",)
        with pasta.region("layer0"):
            assert pasta.current_region() == ("fwd", "layer0")
    assert pasta.current_region() == ()
    kinds = [e.kind for e in seen]
    assert kinds == [EventKind.REGION_START, EventKind.REGION_START,
                     EventKind.REGION_END, EventKind.REGION_END]


def test_mismatched_end_raises(handler):
    pasta.start("a")
    with pytest.raises(RuntimeError):
        pasta.end("b")
    pasta.end("a")


def test_grid_filter_env(monkeypatch):
    monkeypatch.setenv("START_GRID_ID", "5")
    monkeypatch.setenv("END_GRID_ID", "7")
    f = pasta.GridIdFilter()
    assert not f(4) and f(5) and f(7) and not f(8)


# -------------------------------------------------------------------- pool
def test_pool_suballocation_and_free(handler):
    pool = pasta.MemoryPool(handler, chunk_size=1 << 20)
    t1 = pool.alloc(1000, "a")
    t2 = pool.alloc(2000, "b")
    assert t1.object_id == t2.object_id          # same chunk
    assert t1.addr_range()[1] <= t2.addr_range()[0] or \
        t2.addr_range()[1] <= t1.addr_range()[0]
    pool.free(t1)
    t3 = pool.alloc(900, "c")
    assert t3.addr == t1.addr                    # best-fit reuse
    with pytest.raises(ValueError):
        pool.free(t1)                            # double free


def test_pool_free_event_sign_normalization(handler):
    """Raw TENSOR_FREE arrives negative (runtime quirk); processor fixes."""
    seen = []
    proc = pasta.EventProcessor(handler)
    handler.subscribe(lambda e: seen.append(e), kinds=(EventKind.TENSOR_FREE,))
    pool = pasta.MemoryPool(handler)
    t = pool.alloc(4096)
    pool.free(t)
    assert seen[0].normalized and seen[0].size == t.size > 0


# --------------------------------------------------------------- processor
def test_trace_analysis_host_vs_device_paths(handler, rng):
    starts = np.array([2 << 20, 16 << 20], dtype=np.int64)
    ends = starts + (1 << 20)
    addrs = np.concatenate([rng.integers(starts[0], ends[0], 500),
                            rng.integers(starts[1], ends[1], 250)])
    objs = list(zip(starts, ends))
    c_dev, _ = pasta.analyze_access_trace(addrs, objs, mode="device")
    c_host, _ = pasta.analyze_access_trace(addrs, objs, mode="host")
    np.testing.assert_array_equal(c_dev, c_host)
    np.testing.assert_array_equal(c_dev, [500, 250])


# ------------------------------------------------------------------- tools
def test_kernel_freq_tool(handler):
    proc = pasta.EventProcessor(handler, tools=[pasta.KernelFrequencyTool()])
    for i in range(3):
        handler.emit(Event(EventKind.KERNEL_LAUNCH, name="fusion.1",
                           attrs={"count": 10}))
    handler.emit(Event(EventKind.KERNEL_LAUNCH, name="dot.7",
                       attrs={"count": 5}))
    rep = proc.finalize()["KernelFrequencyTool"]
    assert rep["total_invocations"] == 35
    assert rep["top"][0] == ("fusion", 30)


def test_workingset_tool_and_locator(handler):
    tools = [pasta.WorkingSetTool(), pasta.LocatorTool()]
    proc = pasta.EventProcessor(handler, tools=tools)
    pool = pasta.MemoryPool(handler)
    t1 = pool.alloc(10 << 20, "w")
    t2 = pool.alloc(1 << 20, "x")
    handler.operator_start("big", tensors=[(t1.addr, t1.size),
                                           (t2.addr, t2.size)])
    handler.operator_start("small", tensors=[(t2.addr, t2.size)])
    handler.emit(Event(EventKind.KERNEL_LAUNCH, name="gemm.1",
                       attrs={"count": 2, "bytes": 1 << 30,
                              "op_name": "jit(step)/dot_general"}))
    rep = proc.finalize()
    ws = rep["WorkingSetTool"]
    assert ws["working_set_mb"] >= 10.9          # t1+t2
    assert ws["median_ws_mb"] <= ws["working_set_mb"]
    assert ws["max_mem_referenced_kernel"] == "big"
    loc = rep["LocatorTool"]
    assert loc["kernel"] == "gemm.1"
    assert "dot_general" in loc["hlo_op_name"]


def test_timeline_tool_ramp(handler):
    proc = pasta.EventProcessor(handler, tools=[pasta.MemoryTimelineTool()])
    pool = pasta.MemoryPool(handler)
    ts = [pool.alloc(1 << 20, f"t{i}") for i in range(4)]
    for t in ts:
        pool.free(t)
    rep = proc.finalize()["MemoryTimelineTool"]
    series = rep["series"][rep["devices"][0]]
    peaks = [b for _s, b, _r in series]
    assert max(peaks) == rep["peak_bytes"][rep["devices"][0]]
    assert peaks[-1] == 0                        # ramp-down to zero


# ----------------------------------------------------------------- offload
def _mk_stream_schedule(n=32, cold_per_object=0):
    """DL-like schedule: persistent weights + a stream of fresh activation
    tensors, 4 per 8 MiB pool object (optionally with never-accessed cold
    tensors sharing the objects — the paper's tensor-vs-object wedge)."""
    object_sizes = {0: 16 << 20}
    ks = []
    footprint = 16 << 20
    for i in range(n):
        oid = 10 + i // 4
        osz = (4 + cold_per_object) * (2 << 20)
        if oid not in object_sizes:
            object_sizes[oid] = osz
            footprint += osz
        ks.append(offload.KernelAccess(
            name=f"k{i}", compute_s=1e-3,
            tensors=[(0, 16 << 20, 0), (100 + i, 2 << 20, oid)]))
    return ks, object_sizes, footprint


def test_offload_no_pressure_prefetch_wins():
    """Paper Fig. 11: with memory headroom, both prefetch granularities beat
    on-demand migration, object-level at least as well as tensor-level."""
    ks, object_sizes, fp = _mk_stream_schedule()
    out = offload.plan(ks, object_sizes, footprint=fp, oversubscription=1.0)
    assert out["tensor"]["speedup_vs_none"] > 1.05
    assert out["object"]["speedup_vs_none"] > 1.05
    assert out["object"]["time_s"] <= out["tensor"]["time_s"] * 1.02


def test_offload_oversubscription_tensor_wins():
    """Paper Fig. 12: under 3× oversubscription object granularity migrates
    never-accessed co-located tensors and thrashes; tensor-level wins."""
    ks, object_sizes, fp = _mk_stream_schedule(cold_per_object=12)
    out = offload.plan(ks, object_sizes, footprint=fp, oversubscription=3.0)
    assert out["tensor"]["time_s"] < out["object"]["time_s"]
    assert out["object"]["migrated_bytes"] > out["tensor"]["migrated_bytes"]


# --------------------------------------------------------------------- hlo
def test_hlo_walker_counts_scan_trip(handler):
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    stats = handler.capture_compiled(compiled, label="scan7")
    # 7 iterations × 2·64³ flops
    assert stats.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.2)


def test_hlo_walker_collectives(handler):
    import jax.sharding as sh
    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("model",))
    spec = sh.NamedSharding(mesh, sh.PartitionSpec(None, "model"))

    def f(x):
        return jax.lax.with_sharding_constraint(x @ x.T, spec).sum()
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    stats = handler.capture_compiled(compiled)
    assert stats.flops > 0                        # parses without error


def test_shape_bytes():
    from repro.core.hlo import shape_bytes
    assert shape_bytes("f32[128,1024]{1,0}") == 128 * 1024 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[2,2]{1,0}, u8[16]{0})") == 32
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("token[]") == 0
