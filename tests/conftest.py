"""Test fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the real single CPU device; multi-device distribution tests
spawn subprocesses that set XLA_FLAGS themselves (see test_dist.py)."""

import importlib.util
import os
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401  (real package wins when installed)
except ImportError:
    _path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies

from repro.core import events as _events_mod
from repro.core import session as _session_mod


@pytest.fixture(autouse=True)
def pasta_root_session():
    """Open a fresh root Session per test (and reset the Event sequence
    counter), so outcomes never depend on collection order.  Tests get
    session-scoped isolation through the public session API instead of
    poking module globals; anything resolving the ambient PASTA pipeline
    (``pasta.region``, handler-less pools, the deprecation shims) lands in
    this per-test root session."""
    _events_mod.reset_seq()
    _session_mod.reset_state()
    yield _session_mod.root_session()
    _session_mod.reset_state()


@pytest.fixture()
def handler(pasta_root_session):
    """The per-test root session's handler (tools subscribe to it)."""
    return pasta_root_session.handler


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
