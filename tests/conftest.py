"""Test fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the real single CPU device; multi-device distribution tests
spawn subprocesses that set XLA_FLAGS themselves (see test_dist.py)."""

import numpy as np
import pytest

import repro.core as pasta


@pytest.fixture()
def handler():
    """Fresh process-global handler per test (tools subscribe to it)."""
    return pasta.attach()


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
