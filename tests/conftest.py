"""Test fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the real single CPU device; multi-device distribution tests
spawn subprocesses that set XLA_FLAGS themselves (see test_dist.py)."""

import importlib.util
import os
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401  (real package wins when installed)
except ImportError:
    _path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies

import repro.core as pasta
from repro.core import events as _events_mod
from repro.core import handler as _handler_mod


@pytest.fixture(autouse=True)
def _fresh_event_globals():
    """Reset the process-global default handler and the Event sequence
    counter before every test, so outcomes never depend on collection
    order (a leaked subscriber on the global handler — or a drifting seq
    counter — made tests order-sensitive before)."""
    _handler_mod._default = None
    _events_mod.reset_seq()
    yield


@pytest.fixture()
def handler():
    """Fresh process-global handler per test (tools subscribe to it)."""
    return pasta.attach()


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
