"""Distribution tests on 8 virtual devices — run in subprocesses so the
XLA device-count flag never leaks into the main test process."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.dist.sharding import set_mesh
        from repro.models import init_params
        from repro.train import OptConfig, make_train_step, train_shardings
        from repro.train.optimizer import init_opt_state

        cfg = C.reduced(C.get("qwen3-32b"))
        opt_cfg = OptConfig(lr=1e-3)
        step = make_train_step(cfg, opt_cfg, microbatches=2)
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        opt = init_opt_state(params, opt_cfg)
        x = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
        y = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
        batch = {"inputs": x, "labels": y}

        # single-device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)
        loss1 = float(m1["loss"])

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        set_mesh(mesh)
        p_sh, o_sh, _, _ = train_shardings(mesh, cfg, opt_cfg)
        step2 = make_train_step(cfg, opt_cfg, microbatches=2)
        params2 = jax.device_put(params, p_sh)
        opt2 = jax.device_put(opt, o_sh)
        p2, o2, m2 = jax.jit(step2, in_shardings=(p_sh, o_sh, None),
                             out_shardings=(p_sh, o_sh, None))(
            params2, opt2, batch)
        loss2 = float(m2["loss"])
        assert abs(loss1 - loss2) < 5e-3, (loss1, loss2)
        # updated params agree across the mesh
        d = max(float(jnp.abs(a - jnp.asarray(b)).max())
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-2, d
        print("OK", loss1, loss2, d)
    """)
    assert "OK" in out


def test_compressed_psum_matches_plain_within_quant_error():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.dist.collectives import (compressed_psum, plain_psum,
                                            make_pod_sync)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        g = jax.device_put(rng.standard_normal((8, 16)).astype(np.float32),
                           NamedSharding(mesh, P("data", "model")))
        sync_c = make_pod_sync(mesh, compressed=True)
        sync_p = make_pod_sync(mesh, compressed=False)
        a = jax.jit(lambda t: sync_c({"g": t}))(g)["g"]
        b = jax.jit(lambda t: sync_p({"g": t}))(g)["g"]
        rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))
        assert rel < 0.01, rel
        print("OK", rel)
    """)
    assert "OK" in out


def test_compressed_psum_flat_error_across_pod_counts():
    """The quantized reduce-scatter + all-gather layout holds the <1%
    bound at every pod count (2/4/8) and matches its numpy mirror."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.collectives import (compressed_psum, make_pod_sync,
                                            simulate_compressed_psum)
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((16, 48)).astype(np.float32))
        for npods, spec in [(2, ((2, 4), ("pod", "data"))),
                            (4, ((4, 2), ("pod", "data"))),
                            (8, ((8,), ("pod",)))]:
            mesh = jax.make_mesh(*spec)
            a = jax.jit(lambda t: make_pod_sync(mesh, compressed=True)(
                {"g": t}))(g)["g"]
            b = jax.jit(lambda t: make_pod_sync(mesh, compressed=False)(
                {"g": t}))(g)["g"]
            rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))
            assert rel < 0.01, (npods, rel)
            # the collective agrees with the host-side reference mirror
            ref = simulate_compressed_psum(np.stack([np.asarray(g)] * npods))
            fc = shard_map(lambda t: compressed_psum(t, "pod"), mesh=mesh,
                           in_specs=(P(),), out_specs=P(), check_rep=False)
            got = np.asarray(jax.jit(fc)(g))
            assert np.abs(got - ref).max() < 1e-5, npods
            print("OK", npods, rel)
        print("DONE")
    """)
    assert "DONE" in out


def test_psum_start_wait_roundtrip_exact():
    """Plain psum_start/psum_wait (reduce-scatter + all-gather with
    padding) is numerically exact; pipelined handles interleave safely."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.collectives import psum_start, psum_wait
        mesh = jax.make_mesh((8,), ("pod",))
        rng = np.random.default_rng(1)
        xs = [jnp.asarray(rng.standard_normal(s).astype(np.float32))
              for s in ((5, 7), (13,), (2, 3, 4))]   # none divide by 8

        def pipelined(*ts):
            outs = []
            prev = None
            for t in ts:
                h = psum_start(t, "pod")
                if prev is not None:
                    outs.append(psum_wait(prev, "pod"))
                prev = h
            outs.append(psum_wait(prev, "pod"))
            return tuple(outs)

        f = shard_map(pipelined, mesh=mesh, in_specs=(P(),) * 3,
                      out_specs=(P(),) * 3, check_rep=False)
        got = jax.jit(f)(*xs)
        for t, g in zip(xs, got):
            err = float(jnp.abs(g - t * 8).max())
            assert err < 1e-4, err
        print("OK")
    """)
    assert "OK" in out


def test_overlap_sync_train_step_matches_baseline():
    """The bucketed-overlap train step on a pod x data x model mesh matches
    the single-device step exactly (plain) / within quantization error
    (compressed) — the explicit pod-mean sync over a pod-replicated batch
    is numerically the identity."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        import repro.configs as C
        from repro.dist.sharding import set_mesh
        from repro.models import init_params
        from repro.train import OptConfig, make_train_step, train_shardings
        from repro.train.optimizer import init_opt_state
        from repro.train.trainer import batch_shardings

        cfg = C.reduced(C.get("paper-gpt2"))
        opt_cfg = OptConfig(lr=1e-3)
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        opt = init_opt_state(params, opt_cfg)
        x = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
        y = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
        batch = {"inputs": x, "labels": y}

        p0, _o0, m0 = jax.jit(make_train_step(cfg, opt_cfg))(
            params, opt, batch)
        loss0, gn0 = float(m0["loss"]), float(m0["grad_norm"])

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        set_mesh(mesh)
        p_sh, o_sh, _, _ = train_shardings(mesh, cfg, opt_cfg)
        b_sh = batch_shardings(mesh, batch, include_pod=False)
        params2 = jax.device_put(params, p_sh)
        opt2 = jax.device_put(opt, o_sh)
        for name, ov, comp, ptol in [("blocking", False, False, 1e-4),
                                     ("overlap", True, False, 1e-4),
                                     ("overlap_c", True, True, 5e-2)]:
            step = make_train_step(cfg, opt_cfg, overlap_sync=ov,
                                   sync_compressed=comp, sync_buckets=2)
            jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
            p2, _o2, m2 = jf(params2, opt2, batch)
            loss2, gn2 = float(m2["loss"]), float(m2["grad_norm"])
            d = max(float(jnp.abs(a - jnp.asarray(b)).max())
                    for a, b in zip(jax.tree.leaves(p0),
                                    jax.tree.leaves(p2)))
            assert abs(loss2 - loss0) < 2e-3, (name, loss2, loss0)
            assert abs(gn2 - gn0) < 2e-2 * max(gn0, 1), (name, gn2, gn0)
            assert d < ptol, (name, d)
            print("OK", name, loss2, gn2, d)
        print("DONE")
    """)
    assert "DONE" in out


def test_pipeline_forward_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import make_pipelined_fn

        n_stages, lps, M = 4, 2, 6
        L = n_stages * lps
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.standard_normal((L, 16, 16)) * 0.2, jnp.float32)

        def block(w, x):
            return jnp.tanh(x @ w)

        mesh = jax.make_mesh((4, 2), ("pipe", "data"))
        fn = make_pipelined_fn(mesh, block, n_stages, lps)
        xs = jnp.asarray(rng.standard_normal((M, 4, 16)), jnp.float32)
        got = jax.jit(fn)(Ws, xs)

        def seq(x):
            for i in range(L):
                x = block(Ws[i], x)
            return x
        want = jax.vmap(seq)(xs)
        err = float(jnp.abs(got - want).max())
        assert err < 1e-5, err

        # differentiability through the pipe (ppermute transpose rule)
        gfn = jax.grad(lambda W: jax.jit(fn)(W, xs).sum())
        gw = gfn(Ws)
        assert float(jnp.abs(gw).sum()) > 0
        print("OK", err)
    """)
    assert "OK" in out


def test_long_context_decode_seq_sharded_cache():
    """SP flash-decode: seq-sharded KV decode == replicated decode."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.configs as C
        from repro.dist.sharding import set_mesh
        from repro.models import init_params, init_cache, forward
        from repro.train.trainer import serve_shardings

        cfg = C.reduced(C.get("zamba2-7b"))
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
        _, cache = forward(params, toks[:, :16], cfg, return_cache=True,
                           logits_mode="last")
        from repro.serve.engine import _pad_cache_to
        cache = _pad_cache_to(cache, cfg, 32)
        lg_ref, _ = forward(params, toks[:, 16:17], cfg, cache=cache,
                            logits_mode="last")

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        set_mesh(mesh)
        p_sh, c_sh, _, _ = serve_shardings(mesh, cfg, 2, 32)
        params_s = jax.device_put(params, p_sh)
        cache_s = jax.device_put(cache, c_sh)
        lg, _ = jax.jit(lambda p, c, t: forward(p, t, cfg, cache=c,
                                                logits_mode="last"),
                        in_shardings=(p_sh, c_sh, None))(
            params_s, cache_s, toks[:, 16:17])
        err = float(jnp.abs(lg - lg_ref).max())
        assert err < 2e-3, err
        print("OK", err)
    """)
    assert "OK" in out


def test_production_mesh_shapes():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh, n_chips
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.shape == (16, 16) and m1.axis_names == ("data",
                                                                  "model")
        assert m2.devices.shape == (2, 16, 16)
        assert n_chips(m2) == 512
        print("OK")
    """, devices=512)
    assert "OK" in out
