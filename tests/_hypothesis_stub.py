"""Minimal deterministic stand-in for ``hypothesis``.

The test container has no network access, so ``hypothesis`` may be absent.
``conftest.py`` installs this module under the ``hypothesis`` name ONLY
when the real package cannot be imported, so ``tests/test_property.py``
still collects and exercises its invariants: each ``@given`` test runs
``max_examples`` seeded-random draws, with draw 0 pinned to each
strategy's minimal value (a poor man's shrink target).  Real hypothesis —
installed via ``pip install -e .[test]`` in CI — takes precedence.
"""

from __future__ import annotations

import random
import types

__version__ = "0.0-stub"


class _Strategy:
    def __init__(self, draw, edge=None):
        self._draw = draw
        self._edge = edge or draw

    def draw(self, rnd, edge=False):
        return self._edge(rnd) if edge else self._draw(rnd)


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     lambda r: min_value)


def booleans():
    return _Strategy(lambda r: r.random() < 0.5, lambda r: False)


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value),
                     lambda r: min_value)


def lists(elements, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [elements.draw(r)
                   for _ in range(r.randint(min_size, max_size))],
        lambda r: [elements.draw(r, edge=True) for _ in range(min_size)])


def tuples(*elements):
    return _Strategy(lambda r: tuple(e.draw(r) for e in elements),
                     lambda r: tuple(e.draw(r, edge=True) for e in elements))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq), lambda r: seq[0])


strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "booleans", "floats", "lists", "tuples",
              "sampled_from"):
    setattr(strategies, _name, globals()[_name])


def given(*strats):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", 20)
            rnd = random.Random(0)
            for i in range(n):
                fn(*[s.draw(rnd, edge=(i == 0)) for s in strats])

        # NOTE: no functools.wraps — copying __wrapped__ would make pytest
        # see the original signature and demand fixtures for the drawn args
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco
