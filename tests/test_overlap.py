"""Overlap-aware HLO accounting + quantized reduce-scatter collectives.

Covers the async-collective additions: ``*-start``/``*-done`` pairing with
explicit-span overlap credit, the async-runtime simulation model for
synchronous schedules (dual ICI/DCI links, alpha-beta message costs), the
per-device wire-bytes model, replica-group decoding, the quantized
reduce-scatter + all-gather round-trip error bound, and the columnar
normalize fast-path consistency fixes that rode along.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import repro.core as pasta
from repro.core.events import Event, EventBatch, EventKind
from repro.core.hlo import (analyze_text, collective_wire_bytes, parse_hlo)
from repro.dist.collectives import GROUP, simulate_compressed_psum

HW = {"peak_flops": 100e12, "hbm_bw": 800e9, "ici_bw": 50e9,
      "dci_bw": 12.5e9, "ici_latency": 0.0}


# ----------------------------------------------------- async *-start/*-done
GOLDEN_ASYNC = """
HloModule async_overlap

ENTRY %main (p0: f32[1024,1024], p1: f32[4096]) -> (f32[1024,1024], f32[4096]) {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %p1 = f32[4096]{0} parameter(1)
  %ar-start = f32[4096]{0} all-reduce-start(f32[4096]{0} %p1), replica_groups={{0,1,2,3}}, to_apply=%add
  %dot = f32[1024,1024]{1,0} dot(f32[1024,1024]{1,0} %p0, f32[1024,1024]{1,0} %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar-done = f32[4096]{0} all-reduce-done(f32[4096]{0} %ar-start)
  %use = f32[4096]{0} add(f32[4096]{0} %ar-done, f32[4096]{0} %ar-done)
  ROOT %t = (f32[1024,1024]{1,0}, f32[4096]{0}) tuple(f32[1024,1024]{1,0} %dot, f32[4096]{0} %use)
}
"""


def test_async_pair_overlap_credit():
    stats = analyze_text(GOLDEN_ASYNC, hw=HW)
    inst = {i["name"]: i for i in stats.collective_instances}
    a = inst["ar-start"]
    assert a["async"] and a["done"] == "ar-done"
    # the window spans the dot: 2*1024^3 flops of overlap capacity
    assert a["window_flops"] == 2 * 1024 ** 3
    # 16 KiB all-reduce: wire = 2 * bytes * (n-1)/n; fully hidden by the dot
    wire = collective_wire_bytes("all-reduce", 16384, 16384, 4)
    assert a["wire_bytes"] == wire
    assert a["overlapped"] and a["exposed_bytes"] == 0.0
    assert a["hidden_s"] > 0.0
    # the -done half is free: never a kernel, never a second collective
    assert "ar-done" not in stats.kernel_counts
    assert len(stats.collective_instances) == 1
    assert stats.exposed_collective_s < stats.collective_comm_s


def test_async_pair_without_compute_is_exposed():
    text = GOLDEN_ASYNC.replace(
        "%dot = f32[1024,1024]{1,0} dot(f32[1024,1024]{1,0} %p0, "
        "f32[1024,1024]{1,0} %p0), lhs_contracting_dims={1}, "
        "rhs_contracting_dims={0}",
        "%dot = f32[1024,1024]{1,0} add(f32[1024,1024]{1,0} %p0, "
        "f32[1024,1024]{1,0} %p0)")
    # an elementwise add still hides *some* of the transfer, a dot more;
    # shrink it to a scalar so the window is effectively empty
    text = text.replace("f32[1024,1024]", "f32[1,1]")
    stats = analyze_text(text, hw=HW)
    (a,) = stats.collective_instances
    assert a["exposed_bytes"] > 0.9 * a["wire_bytes"]


# ------------------------------------------- sync schedule, simulated async
GOLDEN_SYNC = """
HloModule sync_overlap

ENTRY %main (p0: f32[1024,1024], p1: f32[65536]) -> (f32[1024,1024], f32[65536]) {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %p1 = f32[65536]{0} parameter(1)
  %ar = f32[65536]{0} all-reduce(f32[65536]{0} %p1), replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add
  %dot = f32[1024,1024]{1,0} dot(f32[1024,1024]{1,0} %p0, f32[1024,1024]{1,0} %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %use = f32[65536]{0} add(f32[65536]{0} %ar, f32[65536]{0} %ar)
  ROOT %t = (f32[1024,1024]{1,0}, f32[65536]{0}) tuple(f32[1024,1024]{1,0} %dot, f32[65536]{0} %use)
}
"""


def test_sync_schedule_simulated_overlap():
    """The independent dot backfills onto the compute unit while the sync
    all-reduce's transfer drains — the async-runtime model credits it even
    though XLA:CPU scheduled nothing between the collective and its use."""
    stats = analyze_text(GOLDEN_SYNC, hw=HW)
    (a,) = stats.collective_instances
    assert not a["async"]
    assert a["overlapped"] and a["hidden_s"] > 0.0
    assert a["exposed_bytes"] < a["wire_bytes"]


def test_sync_dual_link_classification():
    # groups {0,4},{1,5},... span the pod boundary on an 8-device 2-pod
    # topology -> DCI; {{0,1}} stays intra-pod -> ICI
    stats = analyze_text(GOLDEN_SYNC, hw=HW, pods=2, n_devices=8)
    (a,) = stats.collective_instances
    assert a["link"] == "dci"
    text = GOLDEN_SYNC.replace("{{0,4},{1,5},{2,6},{3,7}}",
                               "{{0,1},{2,3},{4,5},{6,7}}")
    stats = analyze_text(text, hw=HW, pods=2, n_devices=8)
    (a,) = stats.collective_instances
    assert a["link"] == "ici"


def test_replica_group_decoding():
    mod = parse_hlo(GOLDEN_SYNC)
    ins = mod.entry_computation().instructions["ar"]
    assert ins.replica_groups() == [[0, 4], [1, 5], [2, 6], [3, 7]]
    iota = ins.attrs.replace("replica_groups={{0,4},{1,5},{2,6},{3,7}}",
                             "replica_groups=[4,2]<=[8]")
    ins.attrs = iota
    assert ins.replica_groups() == [[0, 1], [2, 3], [4, 5], [6, 7]]
    ins.attrs = ins.attrs.replace("replica_groups=[4,2]<=[8]",
                                  "replica_groups=[2,4]<=[4,2]T(1,0)")
    assert ins.replica_groups() == [[0, 2, 4, 6], [1, 3, 5, 7]]


def test_collective_wire_bytes_model():
    # ring all-reduce moves ~2x payload; gather/scatter move the shards
    # they receive/retire; all-to-all keeps (n-1)/n on the wire
    assert collective_wire_bytes("all-reduce", 1000, 1000, 4) == 1500.0
    assert collective_wire_bytes("all-gather", 250, 1000, 4) == 750.0
    assert collective_wire_bytes("reduce-scatter", 1000, 250, 4) == 750.0
    assert collective_wire_bytes("all-to-all", 1000, 1000, 4) == 750.0
    assert collective_wire_bytes("collective-permute", 1000, 1000, 4) == 1000.0
    # unknown group size: asymptotic (n-1)/n -> 1
    assert collective_wire_bytes("all-reduce", 1000, 1000, None) == 2000.0


# ------------------------------------ quantized reduce-scatter + all-gather
@settings(max_examples=30, deadline=None)
@given(st.sampled_from([2, 4, 8]), st.integers(4, 24), st.integers(4, 96),
       st.integers(0, 2 ** 31 - 1))
def test_quantized_rs_ag_roundtrip_error_bound(npods, rows, cols, seed):
    """The two-stage (quantize -> exchange -> requantize -> gather) layout
    stays within the <1% relative-error bound for gradient-like (zero-mean)
    tensors at pod counts 2/4/8."""
    rng = np.random.default_rng(seed)
    stacked = rng.standard_normal((npods, rows, cols)).astype(np.float32)
    ref = stacked.sum(axis=0)
    got = simulate_compressed_psum(stacked)
    rel = np.linalg.norm(got - ref) / np.linalg.norm(ref)
    assert rel < 0.01, rel


def test_quantized_rs_ag_pads_ragged_payloads():
    # payload not divisible by npods * GROUP: zero-padding must not leak
    rng = np.random.default_rng(0)
    stacked = rng.standard_normal((8, 3 * GROUP + 7)).astype(np.float32)
    got = simulate_compressed_psum(stacked)
    assert got.shape == stacked.shape[1:]
    ref = stacked.sum(axis=0)
    assert np.linalg.norm(got - ref) / np.linalg.norm(ref) < 0.01


# ----------------------------------------------- columnar normalize rides
def test_one_row_fast_path_materializes_counts():
    """The scalar fast path must leave the batch consistent with
    normalize_batch: normalized one-row batches carry a counts column."""
    handler = pasta.EventHandler()
    seen = []
    with pasta.EventProcessor(handler, tools=[]):
        handler.subscribe_batch(seen.append)
        handler.emit(Event(EventKind.KERNEL_LAUNCH, name="k",
                           attrs={"count": 5}))
        handler.emit(Event(EventKind.MEMCPY, name="m"))
    kb, mb = seen
    assert kb.normalized and kb.counts is not None and kb.counts[0] == 5
    assert mb.normalized and mb.counts is not None and mb.counts[0] == 1


def test_normalize_batch_vectorized_counts():
    b = EventBatch.of(EventKind.KERNEL_LAUNCH, n=3,
                      attrs=[{"count": 7}, None, {}])
    pasta.EventProcessor.normalize_batch(b)
    assert b.counts.tolist() == [7, 1, 1]
    # attrs-free batches take the single-np.full fast path
    b2 = EventBatch.of(EventKind.KERNEL_LAUNCH, n=4)
    pasta.EventProcessor.normalize_batch(b2)
    assert b2.counts.tolist() == [1, 1, 1, 1]


def test_eventbatch_of_names_unique_encoding():
    names = ["zz", "aa", "zz", "mm", "aa"]
    b = EventBatch.of(EventKind.KERNEL_LAUNCH, names=names)
    assert [b.name_of(i) for i in range(5)] == names
    assert sorted(b.name_table) == b.name_table       # np.unique order
    assert len(b.name_table) == 3
