"""Columnar event backbone: batch-vs-scalar golden equivalence.

Every ported tool must produce an *identical* ``finalize()`` report whether
the same logical event stream arrives via scalar ``emit``, via the buffered
SoA ring (at several flush boundaries, including capacity-1 and mid-stream
flushes), or as producer-built columnar batches — plus fused-kernel parity
against the separate kernels in interpret mode.
"""

import os

import numpy as np
import pytest

import repro.core as pasta
from repro.core.events import (Event, EventBatch, EventKind, EventRing,
                               reset_seq)
from repro.core.pool import CHUNK_ALIGN


HOT_CFG = {"base": CHUNK_ALIGN, "n_blocks": 64, "n_tbins": 4,
           "t_max": 1.0, "block_shift": 5}

KERNELS = [("fusion.1", 3, "train"), ("fusion.1", 2, "train"),
           ("dot.7", 5, ""), ("fusion.2", 1, "train"), ("copy", 4, ""),
           ("dot.7", 1, "eval"), ("fusion", 2, "")]


def _golden_tools():
    return [pasta.KernelFrequencyTool(), pasta.MemoryTimelineTool(),
            pasta.WorkingSetTool(), pasta.HotnessTool(n_tbins=4, n_blocks=64),
            pasta.RooflineTool()]


def _emit_kernels_scalar(handler):
    for name, count, label in KERNELS * 3:
        attrs = {"count": count, "bytes": 1 << 20}
        if label:
            attrs["label"] = label
        handler.emit(Event(EventKind.KERNEL_LAUNCH, name=name, attrs=attrs))


def _emit_kernels_batched(handler):
    rows = KERNELS * 3
    attrs = []
    for name, count, label in rows:
        a = {"count": count, "bytes": 1 << 20}
        if label:
            a["label"] = label
        attrs.append(a)
    handler.emit_batch(EventBatch.of(
        EventKind.KERNEL_LAUNCH, names=[r[0] for r in rows], attrs=attrs))


def _run_workload(emit_kernels, buffered_capacity=None):
    """One full coarse+fine workload; returns the tools' reports."""
    reset_seq()
    handler = pasta.EventHandler(
        buffer_capacity=buffered_capacity or 4096,
        buffered=buffered_capacity is not None)
    with pasta.EventProcessor(handler, tools=_golden_tools(),
                              hotness=HOT_CFG) as proc:
        handler.step_start(0)
        emit_kernels(handler)
        pool = pasta.MemoryPool(handler, chunk_size=1 << 20)
        ts = [pool.alloc((i + 1) << 12, f"t{i}") for i in range(6)]
        handler.operator_start(
            "op0", tensors=[(t.addr, t.size) for t in ts[:3]])
        handler.emit(Event(EventKind.COLLECTIVE, name="all-reduce.1",
                           size=1 << 16, attrs={"mult": 2}))
        handler.memcpy(4096, "h2d")
        objs = sorted(t.addr_range() for t in pool.live_tensors())
        rng = np.random.default_rng(7)
        starts = np.asarray([s for s, _ in objs])
        sizes = np.asarray([e - s for s, e in objs])
        pick = rng.integers(0, len(objs), size=400)
        addrs = starts[pick] + rng.integers(0, sizes[pick])
        handler.trace_buffer(addrs, name="k0", kernel="k0", objects=objs,
                             object_sizes=sizes.tolist(), time=0.3)
        for t in ts[::2]:
            pool.free(t)
        if buffered_capacity is not None and buffered_capacity > 16:
            handler.flush()          # mid-stream explicit flush boundary
        for t in ts[1::2]:
            pool.free(t)
        handler.step_end(0)
        return proc.finalize()


def test_batched_emit_matches_scalar():
    want = _run_workload(_emit_kernels_scalar)
    got = _run_workload(_emit_kernels_batched)
    assert got == want


@pytest.mark.parametrize("capacity", [1, 3, 7, 64, 4096])
def test_buffered_ring_matches_scalar(capacity):
    """Ring flushes at capacity / step boundaries / explicit flush must not
    change any report, for pathological and comfortable capacities alike."""
    want = _run_workload(_emit_kernels_scalar)
    got = _run_workload(_emit_kernels_scalar, buffered_capacity=capacity)
    assert got == want


def test_batched_and_buffered_match():
    got = _run_workload(_emit_kernels_batched, buffered_capacity=5)
    want = _run_workload(_emit_kernels_scalar)
    assert got == want


def test_template_fallback_subclass_sees_batches():
    """A legacy-style subclass overriding only on_<kind> hooks must behave
    identically under scalar and batched emission (loop-over-rows default)."""

    class CountingTool(pasta.PastaTool):
        EVENTS = (EventKind.KERNEL_LAUNCH,)

        def __init__(self):
            super().__init__()
            self.total = 0
            self.names = []

        def on_kernel_launch(self, ev):
            self.total += int(ev.attrs.get("count", 1))
            self.names.append(ev.name)

        def finalize(self):
            return {"total": self.total, "names": self.names}

    reps = []
    for emit in (_emit_kernels_scalar, _emit_kernels_batched):
        handler = pasta.EventHandler()
        with pasta.EventProcessor(handler, tools=[CountingTool()]) as proc:
            emit(handler)
            reps.append(proc.finalize()["CountingTool"])
    assert reps[0] == reps[1]
    assert reps[0]["total"] == sum(c for _n, c, _l in KERNELS) * 3


def test_normalize_batch_masked_negation():
    from repro.core.events import KIND_CODE
    codes = np.asarray([KIND_CODE[EventKind.TENSOR_FREE],
                        KIND_CODE[EventKind.ALLOC],
                        KIND_CODE[EventKind.TENSOR_FREE]], dtype=np.int16)
    b = EventBatch.of(codes, sizes=[-512, -128, 1024])
    pasta.EventProcessor.normalize_batch(b)
    assert b.sizes.tolist() == [512, -128, 1024]   # ALLOC keeps raw sign
    assert b.normalized


def test_scalar_subscribers_see_normalized_rows(handler):
    seen = []
    pasta.EventProcessor(handler)
    handler.subscribe(lambda e: seen.append(e),
                      kinds=(EventKind.TENSOR_FREE,))
    pool = pasta.MemoryPool(handler)
    t = pool.alloc(4096)
    with handler.buffering():
        pool.free(t)
    assert seen and seen[0].normalized and seen[0].size == t.size > 0


def test_processor_close_stops_double_dispatch(handler):
    t1 = pasta.KernelFrequencyTool()
    t2 = pasta.KernelFrequencyTool()
    p1 = pasta.EventProcessor(handler, tools=[t1])
    handler.emit(Event(EventKind.KERNEL_LAUNCH, name="a", attrs={"count": 1}))
    p1.close()
    p2 = pasta.EventProcessor(handler, tools=[t2])
    handler.emit(Event(EventKind.KERNEL_LAUNCH, name="a", attrs={"count": 1}))
    assert t1.counts["a"] == 1        # p1 detached before the second event
    assert t2.counts["a"] == 1
    p2.close()


def test_unsubscribe_targeted(handler):
    a, b = [], []
    fa, fb = a.append, b.append
    handler.subscribe(fa, kinds=(EventKind.SYNC,))
    handler.subscribe(fb, kinds=(EventKind.SYNC,))
    handler.sync()
    handler.unsubscribe(fa)
    handler.sync()
    assert len(a) == 1 and len(b) == 2


def test_trace_buffer_bypasses_ring(handler):
    """Heavy TRACE_BUFFER rows must dispatch (and be reduced to aggregates)
    immediately even under buffering — the ring must never pin raw
    access-record arrays until the next flush boundary."""
    proc = pasta.EventProcessor(handler)
    seen = []
    handler.subscribe(lambda e: seen.append(e), kinds=("trace_buffer",))
    with handler.buffering():
        handler.sync("before")                 # stays in the ring...
        handler.trace_buffer(np.arange(64), name="k")
        assert seen, "trace row was buffered instead of dispatched"
        assert "records" not in seen[0].attrs  # ...but the trace reduced
    proc.close()


def test_pool_handles_stamped_before_dispatch(handler):
    """Subscribers running during TENSOR_FREE dispatch must observe the
    freed handle as dead (free_seq stamped before emit)."""
    pool = pasta.MemoryPool(handler)
    live_during_dispatch = []
    handler.subscribe(
        lambda e: live_during_dispatch.append(
            pool.tensors[e.attrs["tensor_id"]].live),
        kinds=(EventKind.TENSOR_FREE,))
    t = pool.alloc(4096)
    assert t.alloc_seq > 0
    pool.free(t)
    assert live_during_dispatch == [False]


def test_ring_capacity_flush():
    ring = EventRing(capacity=2)
    from repro.core.events import KIND_CODE
    code = KIND_CODE[EventKind.SYNC]
    assert not ring.append(code, "s", 0, 0.0, 0, 0, 1, None, (), ())
    assert ring.append(code, "s2", 0, 0.0, 0, 0, 2, None, (), ())
    batch = ring.flush()
    assert len(batch) == 2 and len(ring) == 0
    assert batch.name_of(0) == "s" and batch.name_of(1) == "s2"
    assert ring.flush() is None


# ------------------------------------------------------- fused kernel parity
def _mk_trace(rng, k=17, n=5000):
    sizes = rng.integers(512, 4 << 20, size=k) // 512 * 512
    starts = np.zeros(k, dtype=np.int64)
    addr = 2 << 20
    for i in range(k):
        starts[i] = addr
        addr += sizes[i] + (2 << 20)
    ends = starts + sizes
    hits = rng.integers(0, k, size=n)
    addrs = starts[hits] + rng.integers(0, sizes[hits])
    addrs[::11] = ends[-1] + 12345           # out-of-object misses
    times = rng.random(n)
    return addrs, times, starts, ends


@pytest.mark.parametrize("n,nb,tb", [(100, 64, 4), (5000, 256, 8),
                                     (20000, 512, 16)])
def test_fused_matches_separate_kernels_interpret(rng, n, nb, tb):
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    try:
        from repro.kernels import ops
        addrs, times, starts, ends = _mk_trace(rng, n=n)
        base = 2 << 20
        c_sep = ops.object_histogram(addrs, starts, ends)
        h_sep = ops.hotness_histogram(addrs, times, base, nb, tb, 1.0)
        c_fused, h_fused = ops.trace_aggregate(addrs, times, starts, ends,
                                               base, nb, tb, 1.0)
        np.testing.assert_array_equal(c_fused, c_sep)
        np.testing.assert_array_equal(h_fused, h_sep)
    finally:
        os.environ["REPRO_PALLAS_INTERPRET"] = "0"


def test_fused_ref_backend_matches_separate(rng):
    from repro.kernels import ops
    addrs, times, starts, ends = _mk_trace(rng)
    base = 2 << 20
    c_sep = ops.object_histogram(addrs, starts, ends)
    h_sep = ops.hotness_histogram(addrs, times, base, 128, 8, 1.0)
    c_f, h_f = ops.trace_aggregate(addrs, times, starts, ends, base,
                                   128, 8, 1.0)
    np.testing.assert_array_equal(c_f, c_sep)
    np.testing.assert_array_equal(h_f, h_sep)


def test_fused_fallback_beyond_vmem_ceilings(handler, rng):
    """Problems larger than the fused kernel's resident-accumulator limits
    (object table > FUSE_MAX_K, hist cells > FUSE_MAX_HIST) must route to
    the tiled two-pass kernels instead of tripping the kernel asserts."""
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"   # pallas limits apply
    try:
        from repro.kernels import ops
        assert not ops.can_fuse(5000, 1024, 64)
        assert not ops.can_fuse(100, 32768, 64)
        assert ops.can_fuse(100, 1024, 64)
        hp = {"base": 2 << 20, "n_blocks": 32768, "n_tbins": 64,
              "t_max": 1.0}
        proc = pasta.EventProcessor(handler, hotness=hp)
        seen = []
        handler.subscribe(lambda e: seen.append(e), kinds=("trace_buffer",))
        starts = np.array([2 << 20, 64 << 20])
        ends = starts + (1 << 20)
        addrs = np.concatenate([rng.integers(starts[0], ends[0], 300),
                                rng.integers(starts[1], ends[1], 100)])
        handler.trace_buffer(addrs, name="k",
                             objects=list(zip(starts, ends)),
                             object_sizes=[1 << 20, 1 << 20], time=0.5)
        proc.close()
        ev = seen[0]
        assert ev.attrs["object_counts"].tolist() == [300, 100]
        assert int(ev.attrs["hotness_map"].sum()) == 400
    finally:
        os.environ["REPRO_PALLAS_INTERPRET"] = "0"


def test_processor_fused_single_pass_matches_two_pass(handler, rng):
    """The processor's fused device path must attach the same aggregates as
    the two-kernel path (hotness disabled → separate; enabled → fused)."""
    addrs, _times, starts, ends = _mk_trace(rng, k=5, n=800)
    objs = list(zip(starts, ends))
    sizes = [e - s for s, e in objs]
    seen = []
    proc = pasta.EventProcessor(handler, hotness=dict(HOT_CFG, base=2 << 20))
    handler.subscribe(lambda e: seen.append(e), kinds=("trace_buffer",))
    handler.trace_buffer(addrs, name="k", objects=objs, object_sizes=sizes,
                         time=0.25)
    proc.close()
    fused = seen[-1]
    c2, _ = pasta.analyze_access_trace(addrs, objs, mode="device")
    hp = dict(HOT_CFG, base=2 << 20)
    h2, _ = pasta.analyze_hotness_trace(
        addrs, np.full(len(addrs), 0.25), hp["base"], hp["n_blocks"],
        hp["n_tbins"], hp["t_max"], mode="device",
        block_shift=hp["block_shift"])
    np.testing.assert_array_equal(fused.attrs["object_counts"], c2)
    np.testing.assert_array_equal(fused.attrs["hotness_map"], h2)
