"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp oracles, in
interpret mode (the kernel body executes on CPU exactly as written for TPU).
"""

import os

import numpy as np
import pytest

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.trace_aggregate import object_histogram_pallas  # noqa: E402
from repro.kernels.hotness import hotness_histogram_pallas  # noqa: E402

import jax.numpy as jnp  # noqa: E402


def _mk_objects(rng, k, max_size=4 << 20):
    sizes = rng.integers(512, max_size, size=k) // 512 * 512
    starts = np.zeros(k, dtype=np.int64)
    addr = 2 << 20
    for i in range(k):
        starts[i] = addr
        addr += sizes[i] + (2 << 20)
    return starts, starts + sizes


@pytest.mark.parametrize("n,k", [(100, 3), (5000, 17), (65536, 512),
                                 (10000, 1000), (3, 1)])
def test_object_histogram_matches_oracle(rng, n, k):
    starts, ends = _mk_objects(rng, k)
    hits = rng.integers(0, k, size=n)
    addrs = starts[hits] + rng.integers(0, (ends - starts)[hits])
    # sprinkle misses
    addrs[:: max(n // 10, 1)] = ends[-1] + 12345
    got = ops.object_histogram(addrs, starts, ends)
    os.environ["REPRO_PALLAS_INTERPRET"] = "0"
    want = ops.object_histogram(addrs, starts, ends)
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    np.testing.assert_array_equal(got, want)
    assert got.sum() <= n


@pytest.mark.parametrize("dtype", [np.int64, np.int32])
def test_object_histogram_exact_counts(rng, dtype):
    starts = np.array([2 << 20, 8 << 20, 32 << 20], dtype=np.int64)
    ends = starts + np.array([1 << 20, 2 << 20, 512], dtype=np.int64)
    addrs = np.concatenate([
        rng.integers(starts[0], ends[0], 700),
        rng.integers(starts[1], ends[1], 300),
        np.full(5, starts[2]),
    ]).astype(dtype)
    got = ops.object_histogram(addrs, starts, ends)
    np.testing.assert_array_equal(got, [700, 300, 5])


@pytest.mark.parametrize("n,nb,tb", [(100, 32, 8), (4096, 512, 64),
                                     (20000, 1024, 16), (7, 512, 4)])
def test_hotness_matches_oracle(rng, n, nb, tb):
    base = 2 << 20
    addrs = base + rng.integers(0, nb * (2 << 20), size=n)
    times = rng.random(n)
    got = ops.hotness_histogram(addrs, times, base, nb, tb, 1.0)
    os.environ["REPRO_PALLAS_INTERPRET"] = "0"
    want = ops.hotness_histogram(addrs, times, base, nb, tb, 1.0)
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    np.testing.assert_array_equal(got, want)
    assert got.sum() == n          # all in range -> conservation


def test_hotness_out_of_range_dropped(rng):
    base = 2 << 20
    addrs = np.array([base - 4096, base + 100 * (2 << 20)], dtype=np.int64)
    got = ops.hotness_histogram(addrs, np.array([0.1, 0.2]), base, 8, 4, 1.0)
    assert got.sum() == 0


def test_pallas_padding_invariance(rng):
    """Counts must not change when N is not a tile multiple (padding path)."""
    starts, ends = _mk_objects(rng, 5)
    for n in (1, 2047, 2048, 2049, 4097):
        addrs = starts[rng.integers(0, 5, n)] + 256
        got = ops.object_histogram(addrs, starts, ends)
        assert got.sum() == n


def test_pallas_direct_call_shapes(rng):
    """Direct pallas_call with exact tile shapes (interpret)."""
    a = jnp.asarray(rng.integers(0, 10_000, 4096).astype(np.int32))
    s = jnp.asarray((np.arange(512) * 32).astype(np.int32))
    e = s + 16
    out = object_histogram_pallas(a, s, e, interpret=True)
    assert out.shape == (512,)
    oracle = np.asarray(ref.object_histogram_ref(a, s, e))
    np.testing.assert_array_equal(np.asarray(out).astype(np.int64), oracle)

    tb = jnp.asarray(rng.integers(0, 4, 1024).astype(np.int32))
    h = hotness_histogram_pallas(a[:1024], tb, 0, 512, 4, 12, interpret=True)
    assert h.shape == (4, 512)
