"""Static-analysis subsystem: registry + spec grammar, Finding/Baseline
plumbing, the five builtin passes against golden HLO, the collective
wire-bytes golden table (with pod/DCI classification), property tests
(spec round-trip, mutation robustness), and subprocess end-to-end
seeded-defect checks over real compiled train steps.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest
from hypothesis import given, settings, strategies as st

from repro import analysis
from repro.analysis import (Baseline, Finding, Findings, PASS_REGISTRY,
                            estimate_peak_bytes, format_pass_spec,
                            parse_pass_spec, resolve_passes, run_passes,
                            spec_of)
from repro.core.hlo import (analyze_text, collective_wire_bytes, parse_hlo)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HW = {"peak_flops": 100e12, "hbm_bw": 800e9, "ici_bw": 50e9,
      "dci_bw": 12.5e9, "ici_latency": 0.0, "hbm_bytes": 16 * 2 ** 30}

#: 2x2x2 pod x data x model mesh, row-major device ids
MESH = {"pod": 2, "data": 2, "model": 2}
MODEL_GROUPS = "{{0,1},{2,3},{4,5},{6,7}}"      # fastest axis -> model
POD_GROUPS = "{{0,4},{1,5},{2,6},{3,7}}"        # slowest axis -> pod

#: DEFAULT_RULES as a plain dict, without importing jax in this process
RULES = {
    "p_vocab": "model", "p_embed": "data", "p_heads": "model",
    "p_ff": "model", "p_experts": "data", "p_experts_ep": "model",
    "batch": ("pod", "data"), "seq_sp": "model", "heads": "model",
    "ff": "model", "vocab": "model", "experts_ep": "model",
}

RUN_KW = dict(mesh_axes=MESH, rules=RULES, kind="train", hw=HW,
              pods=2, n_devices=8, emit_events=False)


# ---------------------------------------------------------------------------
# registry + spec grammar
# ---------------------------------------------------------------------------

def test_registry_has_the_five_builtin_passes():
    for name in ("exposed-collectives", "implicit-reshard",
                 "dtype-promotion", "peak-memory", "host-sync"):
        assert name in PASS_REGISTRY


def test_spec_parse_and_knob_override():
    suite = resolve_passes(
        "exposed-collectives:threshold_frac=0.5,min_bytes=1024,peak-memory")
    assert len(suite) == 2
    assert suite[0].knobs["threshold_frac"] == 0.5
    assert suite[0].knobs["min_bytes"] == 1024
    assert suite[1].REGISTRY_NAME == "peak-memory"


def test_unknown_pass_and_unknown_knob_raise():
    with pytest.raises(KeyError):
        resolve_passes("no-such-pass")
    with pytest.raises(TypeError):
        resolve_passes("peak-memory:bogus_knob=1")


def test_spec_of_records_only_non_default_knobs():
    suite = resolve_passes("exposed-collectives:threshold_frac=0.5,host-sync")
    assert spec_of(suite) == "exposed-collectives:threshold_frac=0.5,host-sync"


# ---------------------------------------------------------------------------
# Finding / Baseline plumbing
# ---------------------------------------------------------------------------

def _mk(pass_name="p", sev="warn", opcode="all-gather", comp="main",
        ins="ag.1"):
    return Finding(pass_name=pass_name, severity=sev, message="m",
                   opcode=opcode, computation=comp, instruction=ins)


def test_finding_key_shape():
    assert _mk().key == "p:all-gather:main/ag.1"
    assert _mk(ins="").key == "p:all-gather:main"
    assert Finding(pass_name="p", severity="warn", message="m").key == "p:-:-"


def test_baseline_exact_then_glob(tmp_path):
    f = Findings()
    f.extend([_mk(ins="ag.1"), _mk(ins="ag.2"), _mk(pass_name="q")])
    base = {"version": 1, "suppress": [
        {"key": "p:all-gather:main/ag.1", "reason": "known"},
        {"key": "q:*"},
    ]}
    assert f.apply_baseline(base) == 2
    live = f.unsuppressed("warn")
    assert [x.instruction for x in live] == ["ag.2"]
    assert f.findings[0].suppressed_reason == "known"
    # write-baseline round trip accepts what still fires
    p = tmp_path / "b.json"
    f.write_baseline(str(p), reason="adopt")
    doc = json.loads(p.read_text())
    assert doc["suppress"] == [{"key": "p:all-gather:main/ag.2",
                                "reason": "adopt"}]
    f2 = Findings()
    f2.extend([_mk(ins="ag.2")])
    assert f2.apply_baseline(str(p)) == 1
    assert not f2.unsuppressed()


def test_findings_severity_filter_and_counts():
    f = Findings(label="cell")
    f.extend([_mk(sev="info"), _mk(sev="warn"), _mk(sev="error")])
    assert len(f.unsuppressed("warn")) == 2
    assert f.max_severity() == "error"
    assert f.counts() == {"p": {"info": 1, "warn": 1, "error": 1}}
    d = json.loads(f.to_json())
    assert d["label"] == "cell" and d["n_findings"] == 3
    assert len(d["findings"]) == 3 and d["findings"][0]["key"]


# ---------------------------------------------------------------------------
# exposed-collectives
# ---------------------------------------------------------------------------

BLOCKING_HLO = """
HloModule blocking_sync

ENTRY %main (p1: f32[1048576]) -> f32[1048576] {
  %p1 = f32[1048576]{0} parameter(0)
  %ar = f32[1048576]{0} all-reduce(f32[1048576]{0} %p1), replica_groups=""" \
    + POD_GROUPS + """, to_apply=%add
  ROOT %use = f32[1048576]{0} add(f32[1048576]{0} %ar, f32[1048576]{0} %ar)
}
"""

OVERLAPPED_HLO = """
HloModule overlapped_sync

ENTRY %main (p0: f32[1024,1024], p1: f32[4096]) -> (f32[1024,1024], f32[4096]) {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %p1 = f32[4096]{0} parameter(1)
  %ar-start = f32[4096]{0} all-reduce-start(f32[4096]{0} %p1), replica_groups=""" \
    + POD_GROUPS + """, to_apply=%add
  %dot = f32[1024,1024]{1,0} dot(f32[1024,1024]{1,0} %p0, f32[1024,1024]{1,0} %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar-done = f32[4096]{0} all-reduce-done(f32[4096]{0} %ar-start)
  ROOT %t = (f32[1024,1024]{1,0}, f32[4096]{0}) tuple(f32[1024,1024]{1,0} %dot, f32[4096]{0} %ar-done)
}
"""


def test_exposed_fires_on_blocking_sync():
    f = run_passes(BLOCKING_HLO, "exposed-collectives", **RUN_KW)
    hits = f.by_pass("exposed-collectives")
    assert len(hits) == 1
    (h,) = hits
    assert h.opcode == "all-reduce" and h.severity == "warn"
    assert h.data["link"] == "dci" and h.data["exposed_frac"] > 0.9
    assert h.seconds_impact > 0 and h.bytes_impact > 0
    assert "overlap" in h.fix_hint


def test_exposed_quiet_when_async_pair_hides_the_transfer():
    f = run_passes(OVERLAPPED_HLO, "exposed-collectives", **RUN_KW)
    assert not f.by_pass("exposed-collectives")


def test_exposed_link_filter_and_aggregate_budget():
    # per-instance gating off (threshold > 1), tiny DCI budget -> exactly
    # one summary finding anchored at total[dci]
    spec = ("exposed-collectives:link=dci,threshold_frac=1.1,"
            "total_budget_s=1e-07")
    f = run_passes(BLOCKING_HLO, spec, **RUN_KW)
    (h,) = f.by_pass("exposed-collectives")
    assert h.instruction == "total[dci]"
    assert h.data["total_exposed_s"] > 1e-07
    assert f.meta["exposed_s:dci"] == pytest.approx(h.data["total_exposed_s"])
    # the same budget scoped to ICI sees no traffic at all
    spec = ("exposed-collectives:link=ici,threshold_frac=1.1,"
            "total_budget_s=1e-07")
    f = run_passes(BLOCKING_HLO, spec, **RUN_KW)
    assert not f.by_pass("exposed-collectives")
    assert f.meta["exposed_s:ici"] == 0.0


# ---------------------------------------------------------------------------
# implicit-reshard
# ---------------------------------------------------------------------------

RESHARD_ACT_HLO = """
HloModule reshard_activation

ENTRY %main (p0: f32[512,512]) -> f32[1024,512] {
  %p0 = f32[512,512]{1,0} parameter(0)
  %dot = f32[512,512]{1,0} dot(f32[512,512]{1,0} %p0, f32[512,512]{1,0} %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(step)/jit(main)/dot_general"}
  ROOT %ag = f32[1024,512]{1,0} all-gather(f32[512,512]{1,0} %dot), replica_groups=""" \
    + MODEL_GROUPS + """, dimensions={0}, use_global_device_ids=true, metadata={op_name="jit(step)/jit(main)/dot_general"}
}
"""

RESHARD_WEIGHT_HLO = """
HloModule weight_gather

ENTRY %main (p0: bf16[512,512]) -> f32[1024,512] {
  %p0 = bf16[512,512]{1,0} parameter(0), metadata={op_name="params['embed']"}
  %cv = f32[512,512]{1,0} convert(bf16[512,512]{1,0} %p0)
  ROOT %ag = f32[1024,512]{1,0} all-gather(f32[512,512]{1,0} %cv), replica_groups=""" \
    + MODEL_GROUPS + """, dimensions={0}, use_global_device_ids=true, metadata={op_name="jit(step)/jit(main)/gather"}
}
"""

RESHARD_RSAG_HLO = """
HloModule rs_ag_decomposition

%cond (cp: (f32[512,512], s32[])) -> pred[] {
  %cp = (f32[512,512]{1,0}, s32[]) parameter(0)
  %iter = s32[] get-tuple-element((f32[512,512]{1,0}, s32[]) %cp), index=1
  %lim = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %iter, s32[] %lim), direction=LT
}

%body (bp: (f32[512,512], s32[])) -> (f32[512,512], s32[]) {
  %bp = (f32[512,512]{1,0}, s32[]) parameter(0)
  %acc = f32[512,512]{1,0} get-tuple-element((f32[512,512]{1,0}, s32[]) %bp), index=0
  %iter2 = s32[] get-tuple-element((f32[512,512]{1,0}, s32[]) %bp), index=1
  %grad = f32[1024,512]{1,0} iota(), iota_dimension=0
  %rs = f32[512,512]{1,0} reduce-scatter(f32[1024,512]{1,0} %grad), replica_groups=""" \
    + MODEL_GROUPS + """, dimensions={0}, to_apply=%add, metadata={op_name="jit(step)/jit(main)/psum"}
  %sum = f32[512,512]{1,0} add(f32[512,512]{1,0} %acc, f32[512,512]{1,0} %rs)
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %iter2, s32[] %one)
  ROOT %rt = (f32[512,512]{1,0}, s32[]) tuple(f32[512,512]{1,0} %sum, s32[] %next)
}

ENTRY %main (p0: f32[512,512]) -> f32[1024,512] {
  %p0 = f32[512,512]{1,0} parameter(0)
  %c0 = f32[512,512]{1,0} constant(0)
  %z = s32[] constant(0)
  %init = (f32[512,512]{1,0}, s32[]) tuple(f32[512,512]{1,0} %c0, s32[] %z)
  %w = (f32[512,512]{1,0}, s32[]) while((f32[512,512]{1,0}, s32[]) %init), condition=%cond, body=%body
  %g = f32[512,512]{1,0} get-tuple-element((f32[512,512]{1,0}, s32[]) %w), index=0
  ROOT %ag = f32[1024,512]{1,0} all-gather(f32[512,512]{1,0} %g), replica_groups=""" \
    + MODEL_GROUPS + """, dimensions={0}, use_global_device_ids=true, metadata={op_name="jit(step)/jit(main)/while"}
}
"""


def test_reshard_fires_on_activation_gather_over_tensor_axis():
    f = run_passes(RESHARD_ACT_HLO, "implicit-reshard", **RUN_KW)
    (h,) = f.by_pass("implicit-reshard")
    assert h.opcode == "all-gather"
    assert h.data["axes"] == ["model"]
    assert "mis-sharded" in h.fix_hint


def test_reshard_quiet_on_intended_batch_axis_gather():
    # the rs+ag gradient-sync layout gathers over the batch axes: intended
    text = RESHARD_ACT_HLO.replace(MODEL_GROUPS, POD_GROUPS)
    f = run_passes(text, "implicit-reshard", **RUN_KW)
    assert not f.by_pass("implicit-reshard")


def test_reshard_quiet_on_param_rooted_weight_gather():
    f = run_passes(RESHARD_WEIGHT_HLO, "implicit-reshard", **RUN_KW)
    assert not f.by_pass("implicit-reshard")


def test_reshard_quiet_on_rs_ag_decomposition_through_while():
    """The all-gather tail of an all-reduce XLA split around a microbatch
    loop (reduce-scatter inside the body, gather on the loop-carried
    accumulator) is intended reduction traffic."""
    f = run_passes(RESHARD_RSAG_HLO, "implicit-reshard", **RUN_KW)
    assert not f.by_pass("implicit-reshard")
    # break the evidence: a reduce-scatter over DIFFERENT axes is not the
    # partner of this gather -> the finding comes back
    text = RESHARD_RSAG_HLO.replace(
        "reduce-scatter(f32[1024,512]{1,0} %grad), replica_groups="
        + MODEL_GROUPS,
        "reduce-scatter(f32[1024,512]{1,0} %grad), replica_groups="
        + POD_GROUPS)
    f = run_passes(text, "implicit-reshard", **RUN_KW)
    assert len(f.by_pass("implicit-reshard")) == 1


def test_reshard_skips_explicitly_requested_collectives():
    text = RESHARD_ACT_HLO.replace(
        'op_name="jit(step)/jit(main)/dot_general"',
        'op_name="jit(step)/jit(main)/jit(shmap_body)/all_gather"')
    f = run_passes(text, "implicit-reshard", **RUN_KW)
    assert not f.by_pass("implicit-reshard")


def test_reshard_allow_axes_knob():
    f = run_passes(RESHARD_ACT_HLO, "implicit-reshard:allow_axes=model",
                   **RUN_KW)
    assert not f.by_pass("implicit-reshard")


# ---------------------------------------------------------------------------
# dtype-promotion
# ---------------------------------------------------------------------------

DTYPE_HLO = """
HloModule f32_leak

ENTRY %main (p0: bf16[1024,1024]) -> f32[1024,1024] {
  %p0 = bf16[1024,1024]{1,0} parameter(0)
  %cv = f32[1024,1024]{1,0} convert(bf16[1024,1024]{1,0} %p0)
  ROOT %mul = f32[1024,1024]{1,0} multiply(f32[1024,1024]{1,0} %cv, f32[1024,1024]{1,0} %cv)
}
"""


def test_dtype_fires_on_large_upcast():
    f = run_passes(DTYPE_HLO, "dtype-promotion", **RUN_KW)
    (h,) = f.by_pass("dtype-promotion")
    assert h.opcode == "convert" and h.data["src"] == "bf16"
    assert h.data["numel"] == 1024 * 1024


def test_dtype_exempts_reduction_accumulator():
    text = DTYPE_HLO.replace(
        "ROOT %mul = f32[1024,1024]{1,0} multiply(f32[1024,1024]{1,0} %cv, "
        "f32[1024,1024]{1,0} %cv)",
        "ROOT %r = f32[1024]{0} reduce(f32[1024,1024]{1,0} %cv, f32[] %zero)"
        ", dimensions={1}, to_apply=%add")
    f = run_passes(text, "dtype-promotion", **RUN_KW)
    assert not f.by_pass("dtype-promotion")
    # the exemption is a knob
    f = run_passes(text, "dtype-promotion:allow_reduce=false", **RUN_KW)
    assert len(f.by_pass("dtype-promotion")) == 1


def test_dtype_min_numel_floor():
    f = run_passes(DTYPE_HLO, "dtype-promotion:min_numel=2097152", **RUN_KW)
    assert not f.by_pass("dtype-promotion")


# ---------------------------------------------------------------------------
# peak-memory
# ---------------------------------------------------------------------------

PEAK_HLO = """
HloModule peak

ENTRY %main (p0: f32[512,512]) -> f32[512,512] {
  %p0 = f32[512,512]{1,0} parameter(0)
  %e = f32[512,512]{1,0} exponential(f32[512,512]{1,0} %p0)
  ROOT %d = f32[512,512]{1,0} dot(f32[512,512]{1,0} %e, f32[512,512]{1,0} %e), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

MIB = 2 ** 20


def test_estimate_peak_bytes_liveness():
    est = estimate_peak_bytes(parse_hlo(PEAK_HLO))
    assert est["persistent_bytes"] == 1 * MIB          # the parameter
    assert est["transient_peak_bytes"] == 2 * MIB      # %e and %d both live
    assert est["peak_bytes"] == 3 * MIB
    assert est["at_instruction"] == "d"


def test_peak_memory_budget_gate():
    f = run_passes(PEAK_HLO, "peak-memory", device_budget=2 * MIB,
                   **RUN_KW)
    (h,) = f.by_pass("peak-memory")
    assert h.severity == "error" and h.opcode == "liveness"
    assert f.meta["peak_bytes_est"] == 3 * MIB
    # 16 GiB default budget: quiet, but the estimate is still published
    f = run_passes(PEAK_HLO, "peak-memory", **RUN_KW)
    assert not f.by_pass("peak-memory")
    assert f.meta["peak_bytes_est"] == 3 * MIB


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

HOST_HLO = """
HloModule host_sync, input_output_alias={ {0}: (0, {}, may-alias) }

ENTRY %main (p0: f32[512,512], p1: f32[512,512]) -> (f32[512,512], f32[512,512]) {
  %p0 = f32[512,512]{1,0} parameter(0)
  %p1 = f32[512,512]{1,0} parameter(1)
  %cc = f32[512,512]{1,0} custom-call(f32[512,512]{1,0} %p0), custom_call_target="xla_ffi_python_cpu_callback"
  ROOT %t = (f32[512,512]{1,0}, f32[512,512]{1,0}) tuple(f32[512,512]{1,0} %cc, f32[512,512]{1,0} %p1)
}
"""


def test_host_sync_flags_callback_and_missed_donation():
    f = run_passes(HOST_HLO, "host-sync", **RUN_KW)
    hits = f.by_pass("host-sync")
    by_op = {h.opcode: h for h in hits}
    assert "custom-call" in by_op            # host callback round trip
    # p0 is aliased (donated); p1 matches an output shape but is not
    assert by_op["parameter"].instruction == "p1"
    assert by_op["parameter"].data["param_index"] == 1
    assert "donate" in by_op["parameter"].fix_hint


def test_host_sync_min_donate_bytes_floor():
    f = run_passes(HOST_HLO, "host-sync:min_donate_bytes=2097152", **RUN_KW)
    assert [h.opcode for h in f.by_pass("host-sync")] == ["custom-call"]


# ---------------------------------------------------------------------------
# collective wire-bytes golden table  (B = 96000 payload bytes)
# ---------------------------------------------------------------------------

#: (opcode, op_bytes, out_bytes, N) -> exact wire bytes of the ring model
WIRE_TABLE = [
    ("all-reduce",         96000, 96000,  2,  96000.0),
    ("all-reduce",         96000, 96000,  4, 144000.0),
    ("all-reduce",         96000, 96000,  8, 168000.0),
    ("all-gather",         48000, 96000,  2,  48000.0),
    ("all-gather",         24000, 96000,  4,  72000.0),
    ("all-gather",         12000, 96000,  8,  84000.0),
    ("reduce-scatter",     96000, 48000,  2,  48000.0),
    ("reduce-scatter",     96000, 24000,  4,  72000.0),
    ("reduce-scatter",     96000, 12000,  8,  84000.0),
    ("all-to-all",         96000, 96000,  2,  48000.0),
    ("all-to-all",         96000, 96000,  4,  72000.0),
    ("all-to-all",         96000, 96000,  8,  84000.0),
    ("collective-permute", 96000, 96000,  2,  96000.0),
    ("collective-permute", 96000, 96000,  4,  96000.0),
    ("collective-permute", 96000, 96000,  8,  96000.0),
]


@pytest.mark.parametrize("opcode,op_b,out_b,n,expected", WIRE_TABLE)
def test_collective_wire_bytes_golden(opcode, op_b, out_b, n, expected):
    assert collective_wire_bytes(opcode, op_b, out_b, n) == expected


#: groups on an 8-device 2-pod topology: (groups, group size, crosses DCI)
LINK_TABLE = [
    ("{{0,4},{1,5},{2,6},{3,7}}", 2, "dci"),
    ("{{0,1},{2,3},{4,5},{6,7}}", 2, "ici"),
    ("{{0,2,4,6},{1,3,5,7}}",     4, "dci"),
    ("{{0,1,2,3},{4,5,6,7}}",     4, "ici"),
    ("{{0,1,2,3,4,5,6,7}}",       8, "dci"),
]


@pytest.mark.parametrize("groups,n,link", LINK_TABLE)
@pytest.mark.parametrize("opcode", ["all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all"])
def test_collective_link_classification(opcode, groups, n, link):
    numel = 65536
    if opcode == "all-gather":
        in_shape, out_shape = f"f32[{numel // n}]", f"f32[{numel}]"
    elif opcode == "reduce-scatter":
        in_shape, out_shape = f"f32[{numel}]", f"f32[{numel // n}]"
    else:
        in_shape = out_shape = f"f32[{numel}]"
    dims = "" if opcode in ("all-reduce", "all-to-all") else \
        " dimensions={0},"
    text = f"""
HloModule link_class

ENTRY %main (p0: {in_shape}) -> {out_shape} {{
  %p0 = {in_shape}{{0}} parameter(0)
  ROOT %c = {out_shape}{{0}} {opcode}({in_shape}{{0}} %p0), replica_groups={groups},{dims} to_apply=%add
}}
"""
    stats = analyze_text(text, hw=HW, pods=2, n_devices=8)
    (inst,) = stats.collective_instances
    assert inst["link"] == link, (opcode, groups)


# ---------------------------------------------------------------------------
# event emission + robustness plumbing
# ---------------------------------------------------------------------------

def test_findings_emitted_as_session_events():
    emitted = []

    class _Handler:
        def emit(self, ev):
            emitted.append(ev)

    class _Session:
        handler = _Handler()

    f = run_passes(BLOCKING_HLO, "exposed-collectives", session=_Session(),
                   mesh_axes=MESH, rules=RULES, kind="train", hw=HW,
                   pods=2, n_devices=8)
    assert len(emitted) == len(f.findings) == 1
    ev = emitted[0]
    assert ev.kind.name == "FINDING"
    assert ev.attrs["severity"] == "warn" and ev.attrs["key"] == \
        f.findings[0].key


def test_unparseable_artifact_warns_never_raises():
    f = run_passes("this is not HLO at all {{{", None, **RUN_KW)
    assert isinstance(f, Findings)
    assert f.warnings, "garbage input must surface a counted warning"
    assert not any(k.startswith("pass-error") for k in f.warnings)


def test_pass_error_backstop():
    class Exploding(analysis.AnalysisPass):
        REGISTRY_NAME = "exploding"

        def run(self, ctx):
            raise RuntimeError("boom")

    f = run_passes(BLOCKING_HLO, [Exploding()], **RUN_KW)
    assert f.warnings.get("pass-error:exploding") == 1
    (h,) = f.findings
    assert h.severity == "error" and "boom" in h.message


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

_NAMES = sorted(PASS_REGISTRY)
_STR_CHOICES = ["warn", "error", "info", "dci", "ici", "model+data"]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, len(_NAMES) - 1),
                          st.integers(0, 3),
                          st.integers(0, 10 ** 6),
                          st.floats(0.0, 100.0),
                          st.booleans(),
                          st.integers(0, len(_STR_CHOICES) - 1)),
                min_size=1, max_size=6))
def test_pass_spec_round_trips_through_registry_parser(draws):
    """format_pass_spec(parse_pass_spec(s)) is the identity on canonical
    specs built from real registry passes with type-correct knob values."""
    entries = []
    for name_i, n_knobs, iv, fv, bv, si in draws:
        name = _NAMES[name_i]
        cls = PASS_REGISTRY[name]
        knobs = {}
        for k, default in sorted(cls.KNOBS.items())[:n_knobs]:
            if isinstance(default, bool):
                knobs[k] = bv
            elif isinstance(default, int):
                knobs[k] = iv
            elif isinstance(default, float):
                knobs[k] = fv
            else:
                knobs[k] = _STR_CHOICES[si]
        entries.append((name, knobs))
    spec = format_pass_spec(entries)
    assert parse_pass_spec(spec) == entries
    assert format_pass_spec(parse_pass_spec(spec)) == spec
    # every canonical spec also instantiates
    suite = resolve_passes(spec)
    assert [p.REGISTRY_NAME for p in suite] == [n for n, _ in entries]


_MUTATION_BASE = (RESHARD_RSAG_HLO + DTYPE_HLO + HOST_HLO
                  + BLOCKING_HLO + OVERLAPPED_HLO)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 10 ** 9),
                          st.integers(0, 10 ** 9)),
                min_size=1, max_size=8))
def test_random_hlo_mutations_never_make_passes_raise(mutations):
    """Dropped/truncated/duplicated/corrupted lines must degrade to counted
    warnings — run_passes never raises AND no pass crashes internally."""
    lines = _MUTATION_BASE.splitlines()
    for kind, a, b in mutations:
        if not lines:
            break
        i = a % len(lines)
        j = b % len(lines)
        if kind == 0:
            del lines[i]
        elif kind == 1:
            lines[i] = lines[i][:b % (len(lines[i]) + 1)]
        elif kind == 2:
            lines.insert(j, lines[i])
        elif kind == 3:
            lines[i], lines[j] = lines[j], lines[i]
        elif kind == 4:
            toks = lines[i].split(" ")
            if toks:
                toks[a % len(toks)] = "@@corrupt@@"
            lines[i] = " ".join(toks)
        else:
            lines.insert(i, "%%% not hlo %%%")
    f = run_passes("\n".join(lines), None, **RUN_KW)
    assert isinstance(f, Findings)
    assert not any(k.startswith("pass-error") for k in f.warnings), \
        f.warnings


# ---------------------------------------------------------------------------
# end-to-end: real compiled train cells (subprocess, 8 virtual devices)
# ---------------------------------------------------------------------------

def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_e2e_seeded_reshard_defect_fires_and_green_is_quiet():
    out = run_sub("""
        import sys
        sys.argv = ["lint", "--devices", "8"]
        from repro.launch import lint

        green = lint.smoke_cell("qwen3-32b", spec="implicit-reshard")
        base = {"version": 1,
                "suppress": [{"key": f.key} for f in green.findings]}
        green.apply_baseline(base)
        assert not green.unsuppressed("warn"), green.to_json()

        defect = lint.smoke_cell("qwen3-32b",
                                 rules_patch=dict(lint.DEFECT_RULES),
                                 spec="implicit-reshard", baseline=base)
        hits = [f for f in defect.unsuppressed("warn")
                if f.pass_name == "implicit-reshard"]
        assert hits, defect.to_json()
        assert all(f.data["axes"] == ["model"] for f in hits)
        print("OK green=", len(green.findings), " defect_new=", len(hits))
    """)
    assert "OK" in out


def test_e2e_blocking_sync_trips_dci_budget_overlap_does_not():
    out = run_sub("""
        import sys
        sys.argv = ["lint", "--devices", "8"]
        from repro.launch import lint

        spec = ("exposed-collectives:link=dci,threshold_frac=1.1,"
                "total_budget_s=1e-06")
        ok = lint.smoke_cell("qwen3-32b", overlap_sync=True, spec=spec)
        assert not ok.by_pass("exposed-collectives"), ok.to_json()

        bad = lint.smoke_cell("qwen3-32b", overlap_sync=False, spec=spec)
        (h,) = bad.by_pass("exposed-collectives")
        assert h.instruction == "total[dci]"
        assert h.data["total_exposed_s"] > 1e-06
        print("OK exposed_us=", h.data["total_exposed_s"] * 1e6)
    """)
    assert "OK" in out


def test_e2e_peak_memory_estimate_tracks_measured_peak():
    """The static liveness estimate must land within 20% of the
    dryrun-measured (XLA memory_analysis) peak."""
    out = run_sub("""
        import sys
        sys.argv = ["lint", "--devices", "8"]
        from repro.launch import lint

        f = lint.smoke_cell("qwen3-32b", spec="peak-memory")
        est = f.meta["peak_bytes_est"]
        meas = f.meta["measured_peak_bytes"]
        assert meas > 0
        ratio = est / meas
        assert 0.8 <= ratio <= 1.2, (est, meas, ratio)
        print("OK ratio=", ratio)
    """)
    assert "OK" in out
