"""In-kernel device-side event recording (fine-grained Table-II tier)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.instrumented_matmul import (matmul_traced,
                                               matmul_traced_ref, BM, BN)
import repro.core as pasta


@pytest.mark.parametrize("m,k,n", [(128, 64, 128), (256, 128, 384),
                                   (384, 32, 128)])
def test_traced_matmul_matches_oracle(rng, m, k, n):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out, trace = matmul_traced(x, w, interpret=True)
    out_ref, trace_ref = matmul_traced_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(trace), np.asarray(trace_ref))


def test_trace_buffer_flows_through_pasta(handler, rng):
    """The device trace surfaces as a TRACE_BUFFER event whose aggregate the
    tools consume — never the raw records."""
    x = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
    out, trace = matmul_traced(x, w, interpret=True)
    seen = []
    proc = pasta.EventProcessor(handler)
    handler.subscribe(lambda e: seen.append(e), kinds=("trace_buffer",))
    handler.trace_buffer(np.asarray(trace), name="matmul",
                         kernel="matmul_traced",
                         bytes_read=int(np.asarray(trace)[:, 2].sum()),
                         bytes_written=int(np.asarray(trace)[:, 3].sum()))
    assert len(seen) == 1
    ev = seen[0]
    assert ev.attrs["bytes_read"] == (256 // BM) * (256 // BN) * \
        (BM * 64 * 4 + 64 * BN * 4)
