"""Per-arch smoke tests (reduced configs) + model-math correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import (init_params, forward, init_cache, cross_entropy,
                          param_axes)
from repro.models.mamba2 import ssd_chunked, ssd_ref
from repro.models.layers import _sdpa_dense, _sdpa_blocked, _group
from repro.train import OptConfig, make_train_step
from repro.train.optimizer import init_opt_state

ARCHS = C.ASSIGNED


def _inputs(cfg, key, b=2, s=64):
    if cfg.frontend == "embed":
        x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        x = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return x, labels


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one full train step on CPU: output shapes + no NaNs."""
    cfg = C.reduced(C.get(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x, labels = _inputs(cfg, key)
    logits, _ = jax.jit(lambda p, x: forward(p, x, cfg))(params, x)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    opt_cfg = OptConfig(lr=1e-3, moment_dtype=cfg.opt_moment_dtype)
    step = make_train_step(cfg, opt_cfg, microbatches=2)
    opt = init_opt_state(params, opt_cfg)
    batch = {"inputs": x, "labels": labels}
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_param_axes_cover_tree(arch):
    """Sharding-axes tree must mirror the param tree exactly."""
    cfg = C.reduced(C.get(arch))
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    axes = param_axes(cfg)
    is_ax = lambda x: isinstance(x, tuple)  # noqa: E731
    matched = jax.tree.map(lambda ax, leaf: len(ax) == leaf.ndim, axes,
                           params, is_leaf=is_ax)
    assert all(jax.tree.leaves(matched))


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "glm4-9b", "zamba2-7b",
                                  "mamba2-2.7b", "dbrx-132b"])
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill == teacher-forced forward logits."""
    cfg = C.reduced(C.get(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    full_logits, _ = forward(params, toks, cfg)
    # prefill on the first 24 tokens, then 8 decode steps
    _, cache = forward(params, toks[:, :24], cfg, return_cache=True,
                       logits_mode="last")
    # grow cache to 32 slots
    from repro.serve.engine import _pad_cache_to
    cache = _pad_cache_to(cache, cfg, 32)
    errs = []
    for t in range(24, 32):
        lg, cache = forward(params, toks[:, t:t + 1], cfg, cache=cache,
                            logits_mode="last")
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 2e-2, errs


def test_ssd_chunked_matches_sequential(rng):
    b, s, h, p, n = 2, 128, 4, 8, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.4,
                     jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal(h)) - 0.05, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
    y_ref, st_ref = ssd_ref(x, dt, A, B, Cm)
    for chunk in (16, 32, 128):
        y, st = ssd_chunked(x, dt, A, B, Cm, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                                   atol=2e-4, rtol=1e-3)


def test_blocked_attention_matches_dense(rng):
    b, s, hkv, g, d = 2, 256, 2, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hkv * g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    qg = _group(q, hkv)
    dense = _sdpa_dense(qg, k, v, causal=True)
    blocked = _sdpa_blocked(qg, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(blocked),
                               atol=2e-5, rtol=1e-4)


def test_moe_capacity_drops_are_bounded(rng):
    cfg = C.reduced(C.get("dbrx-132b"))
    from repro.models.moe import moe_layer, init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.float32)
    y, aux = moe_layer(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux["dropped_frac"]) < 0.5
    assert float(aux["lb_loss"]) > 0.5          # ~1.0 when balanced


def test_cross_entropy_matches_manual(rng):
    logits = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32, (2, 8)))
    loss, parts = cross_entropy(logits, labels, z_loss=0.0)
    p = jax.nn.log_softmax(logits, -1)
    want = -np.mean([p[i, j, labels[i, j]] for i in range(2)
                     for j in range(8)])
    np.testing.assert_allclose(float(loss), want, rtol=1e-5)


def test_full_config_param_counts():
    """Full configs must match published parameter counts (±5%)."""
    expected = {
        "mamba2-2.7b": 2.7e9, "stablelm-1.6b": 1.6e9, "glm4-9b": 9.4e9,
        "gemma-7b": 8.5e9, "qwen3-32b": 32.8e9, "zamba2-7b": 7.0e9,
        "qwen2-vl-72b": 72.7e9, "dbrx-132b": 132e9,
        "kimi-k2-1t-a32b": 1.04e12, "musicgen-large": 3.3e9,
    }
    for arch, want in expected.items():
        got = C.get(arch).n_params
        assert abs(got - want) / want < 0.06, (arch, got, want)
    assert abs(C.get("kimi-k2-1t-a32b").n_active_params - 33e9) / 33e9 < 0.1
    assert abs(C.get("dbrx-132b").n_active_params - 36e9) / 36e9 < 0.05
