"""Hypothesis property tests on system invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

import repro.core as pasta
from repro.core.pool import MemoryPool, TENSOR_ROUND
from repro.kernels import ops
from repro.train.optimizer import _quant, _dequant
from repro.dist.collectives import quantize_int8, dequantize_int8

jax.config.update("jax_platforms", "cpu")


# ---------------------------------------------------------------- allocator
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 1 << 22), st.booleans()),
                min_size=1, max_size=60))
def test_pool_invariants(ops_list):
    """No two live tensors overlap; every tensor sits inside its object;
    live-byte accounting is exact."""
    pool = MemoryPool(pasta.EventHandler(), chunk_size=1 << 20)
    live = []
    for size, do_free in ops_list:
        t = pool.alloc(size)
        live.append(t)
        if do_free and live:
            victim = live.pop(0)
            pool.free(victim)
        # invariants
        lt = sorted(pool.live_tensors(), key=lambda x: x.addr)
        for a, b in zip(lt, lt[1:]):
            assert a.addr + a.size <= b.addr, "overlap"
        for t2 in lt:
            o = pool.objects[t2.object_id]
            assert o.base <= t2.addr and t2.addr + t2.size <= o.base + o.size
        assert pool.live_bytes == sum(t2.size for t2 in lt)
        assert pool.live_bytes <= pool.footprint


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 1 << 20))
def test_pool_rounding(nbytes):
    pool = MemoryPool(pasta.EventHandler())
    t = pool.alloc(nbytes)
    assert t.size % TENSOR_ROUND == 0 and t.size >= nbytes


# -------------------------------------------------------------- histograms
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4000), st.integers(1, 40), st.integers(0, 2 ** 31))
def test_histogram_conservation(n, k, seed):
    """Σ counts == #records-in-range, for any object layout."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(512, 1 << 20, size=k) // 512 * 512
    starts = np.cumsum(np.concatenate([[2 << 20], sizes[:-1] + (2 << 20)]))
    ends = starts + sizes
    addrs = rng.integers(0, ends[-1] + (4 << 20), size=n)
    counts = ops.object_histogram(addrs, starts, ends)
    in_range = sum(int(((addrs >= s) & (addrs < e)).sum())
                   for s, e in zip(starts, ends))
    assert counts.sum() == in_range
    assert (counts >= 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3000), st.integers(1, 16), st.integers(1, 64),
       st.integers(0, 2 ** 31))
def test_hotness_conservation(n, tb, nb, seed):
    rng = np.random.default_rng(seed)
    base = 2 << 20
    addrs = base + rng.integers(0, nb * (2 << 20), size=n)
    times = rng.random(n)
    hot = ops.hotness_histogram(addrs, times, base, nb, tb, 1.0)
    assert hot.shape == (tb, nb)
    assert hot.sum() == n


# ------------------------------------------------------------ quantization
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 128), st.integers(0, 2 ** 31),
       st.floats(1e-4, 1e4))
def test_int8_moment_quantization_error_bound(rows, cols, seed, scale):
    """Per-row absmax int8: |x - deq(q)| <= amax_row / 127 (half-ulp ~ /254,
    use /126 slack for rounding)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)) * scale, jnp.float32)
    q, s = _quant(x)
    back = _dequant(q, s)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    assert np.all(np.abs(np.asarray(back - x)) <= amax / 126.0 + 1e-12)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 32), st.integers(1, 64), st.integers(0, 2 ** 31))
def test_compressed_gradient_roundtrip_relative_error(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    rel = float(jnp.linalg.norm(back - x) / (jnp.linalg.norm(x) + 1e-9))
    assert rel < 0.01                           # <1% relative error


# ------------------------------------------------------------ event stream
@settings(max_examples=20, deadline=None)
@given(st.lists(st.sampled_from(["a", "b", "c"]), max_size=8))
def test_nested_regions_always_balance(names):
    h = pasta.current_handler()
    evs = []
    h.subscribe(lambda e: evs.append(e), kinds=("region_start", "region_end"))
    for n in names:
        pasta.start(n)
    for n in reversed(names):
        pasta.end(n)
    assert pasta.current_region() == ()
    starts = [e for e in evs if e.kind.value == "region_start"]
    ends = [e for e in evs if e.kind.value == "region_end"]
    assert len(starts) == len(ends) == len(names)


# ------------------------------------------------------------- checkpoints
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_checkpoint_roundtrip(seed):
    import tempfile
    from repro.train import checkpoint as ckpt
    rng = np.random.default_rng(seed)
    state = {"params": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                         jnp.float32),
                        "b": jnp.asarray(rng.standard_normal(8),
                                         jnp.float32)},
             "opt": {"mu": {"w": jnp.zeros((4, 8)), "b": jnp.ones(8)},
                     "step": jnp.asarray(7)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 42, state)
        assert ckpt.latest_step(d) == 42
        step, back = ckpt.restore(d, state)
    assert step == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- paged KV block pool
def _paged_pool_invariants(pool, stored):
    """The allocator's full-state contract, checked after every op."""
    refs, srefs = pool._refs, pool._store_refs
    assert (refs >= 0).all() and (srefs >= 0).all()
    assert (srefs <= refs).all(), "store refs exceed total refs"
    free = list(pool._free)
    assert len(set(free)) == len(free), "free-list duplicate"
    assert set(free) == set(np.flatnonzero(refs == 0)), \
        "free list out of sync with refcounts"
    # every reference is accounted for: block-table entries + store holds
    table_counts = np.zeros(pool.n_blocks, np.int64)
    for row in pool.tables:
        for b in row[row < pool.n_blocks]:
            table_counts[int(b)] += 1
    assert (refs == table_counts + srefs).all(), \
        "refcount != table references + store references"
    # deferred-scrub blocks must all be free (a live block may never be
    # zeroed out from under its owner)
    assert pool._dirty <= set(free), "dirty block is live"
    st = pool.stats()
    assert (st["blocks_live"] + st["blocks_evictable"]
            + st["blocks_free"] == st["n_blocks"]), st
    assert pool.available() == st["blocks_free"] + st["blocks_evictable"]


_SERVE: dict = {}


def _serve_fixture():
    """One lazily-built engine + solo reference outputs, shared across
    examples: jit caches are per-engine-instance, so rebuilding per draw
    would recompile everything.  Temperature-0 sampling is keyed on
    position, so outputs are independent of request ids and of how the
    examples interleave."""
    if not _SERVE:
        import repro.configs as C
        from repro.models import init_params
        from repro.serve import SamplingParams, ServeEngine

        cfg = C.reduced(C.get("paper-gpt2"))
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, cfg.vocab_size, (n,), dtype=np.int32)
                   for n in (5, 9, 12, 17, 23, 30)]
        sp = SamplingParams(max_new_tokens=6)
        eng = ServeEngine(cfg, params, max_seq=48, max_slots=3,
                          prefix_block=8, prefill_chunk=16,
                          policy="priority")
        refs = [list(eng.run([(p, sp)]).values())[0] for p in prompts]
        _SERVE.update(eng=eng, prompts=prompts, refs=refs, sp=sp)
    return _SERVE


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 5)),
                min_size=1, max_size=30))
def test_engine_random_submit_step_preempt_abort(ops_list):
    """Random submit / step / preempt / abort sequences against an
    undersized engine keep every paged-pool invariant, and whatever
    finishes is byte-identical to its solo reference — preemption and
    aborts never corrupt another request's stream."""
    from repro.serve.scheduler import RequestState

    fx = _serve_fixture()
    eng, prompts, refs, sp = (fx["eng"], fx["prompts"], fx["refs"],
                              fx["sp"])
    from repro.serve.slo import SLOSpec
    mine: dict = {}                        # rid -> prompt index
    for op, idx in ops_list:
        if op == 0:                        # submit (priorities vary)
            rid = eng.submit(prompts[idx],
                             sp, slo=SLOSpec(priority=idx % 3))
            mine[rid] = idx
        elif op == 1 and eng.sched.has_work:
            eng.step()
        elif op == 2:                      # preempt a running request
            running = sorted(r.rid for r in eng.sched.running.values())
            if running:
                eng.preempt(running[idx % len(running)])
        elif op == 3:                      # abort a live request
            live = sorted(r.rid for r in list(eng.sched.waiting)
                          + list(eng.sched.running.values()))
            if live:
                eng.abort(live[idx % len(live)])
        _paged_pool_invariants(eng.pool, [])
    while eng.sched.has_work:              # drain so examples are isolated
        eng.step()
        _paged_pool_invariants(eng.pool, [])
    assert not any(eng._owed.values()), eng._owed
    for rid, idx in mine.items():
        req = eng.requests.get(rid)
        if req is not None and req.state is RequestState.FINISHED:
            assert list(req.tokens) == list(refs[idx]), \
                (rid, idx, req.preemptions)


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["poison", "nan_logits",
                                           "tick_error", "stall",
                                           "pressure", "preempt"]),
                          st.integers(0, 2), st.integers(1, 2),
                          st.integers(0, 4)),
                max_size=4))
def test_random_fault_plans_never_corrupt_innocents(specs):
    """Arbitrary small fault plans (poisons, NaN rows, tick errors, stall /
    pressure windows, host preemptions) against live traffic: the paged
    pool invariants hold after every tick, untargeted requests always
    finish byte-identically to their solo references, and a targeted
    request may only fail if its cumulative fault charges exceed the
    engine's retry budget."""
    from repro.serve.faults import FaultPlan, FaultSpec
    from repro.serve.scheduler import RequestState

    fx = _serve_fixture()
    eng, prompts, refs, sp = (fx["eng"], fx["prompts"], fx["refs"],
                              fx["sp"])
    idxs = (1, 3, 5)
    rids = [eng.submit(prompts[i], sp) for i in idxs]
    t0 = eng.ticks
    plan, charges = [], {}
    for kind, tgt, ttl, off in specs:
        if kind in ("poison", "nan_logits"):
            plan.append(FaultSpec(kind=kind, rid=rids[tgt], ttl=ttl))
            charges[rids[tgt]] = charges.get(rids[tgt], 0) + ttl
        elif kind == "tick_error":
            plan.append(FaultSpec(kind=kind, tick=t0 + 1 + off))
        elif kind == "stall":
            plan.append(FaultSpec(kind=kind, tick=t0 + 1 + off,
                                  duration=2, stall_s=0.002))
        elif kind == "pressure":
            plan.append(FaultSpec(kind=kind, tick=t0 + 1 + off,
                                  duration=2, blocks=1))
        else:
            plan.append(FaultSpec(kind="preempt", tick=t0 + 1 + off))
    eng.faults = FaultPlan(plan)
    try:
        while eng.has_work:
            eng.step()
            _paged_pool_invariants(eng.pool, [])
    finally:
        eng.faults = None
    assert not any(eng._owed.values()), eng._owed
    for rid, idx in zip(rids, idxs):
        req = eng.requests[rid]
        if req.state is RequestState.FAILED:
            # only a sufficiently-charged target may exhaust its retries
            assert charges.get(rid, 0) > eng.max_request_retries, \
                (rid, charges)
        else:
            assert req.state is RequestState.FINISHED, req.state
            assert list(req.tokens) == list(refs[idx]), (rid, idx)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 3),
                          st.integers(1, 40)),
                min_size=1, max_size=80))
def test_paged_pool_invariants_under_random_ops(ops_list):
    """Random alloc/bind/ensure/truncate/publish/evict/scrub sequences on an
    undersized pool keep the free-list, refcounts, block tables, prefix-store
    holds and deferred-scrub set mutually consistent."""
    import repro.configs as C
    from repro.serve import PagedKVPool

    cfg = C.reduced(C.get("paper-gpt2"))
    pool = PagedKVPool(cfg, slots=4, max_seq=32, block_size=8, n_blocks=10)
    span = pool.blocks_per_seq * pool.block_size
    stored = []          # published prefix-store entries (lists of ids)
    pool.evict_cb = (lambda: bool(stored)
                     and (pool.release(stored.pop(0), store=True) or True))
    bound = [False] * pool.slots
    for op, slot, n in ops_list:
        n_tok = max(n % span, 1)
        if op == 0 and not bound[slot]:
            ids = pool.alloc(pool.blocks_for(n_tok))
            if ids is not None:
                pool.bind_slot(slot, [], ids)
                bound[slot] = True
        elif op == 1 and bound[slot]:
            row = pool.tables[slot]
            have = int((row < pool.n_blocks).sum())
            need = pool.blocks_for(n_tok)
            if need - have <= pool.available():
                pool.ensure(slot, n_tok)
        elif op == 2 and bound[slot]:
            pool.truncate(slot, n_tok)
        elif op == 3 and bound[slot]:
            pool.free_slot(slot)
            bound[slot] = False
        elif op == 4 and bound[slot]:
            row = pool.tables[slot]
            real = [int(b) for b in row[row < pool.n_blocks]]
            if real:
                pool.retain(real, store=True)
                stored.append(real)
        elif op == 5 and stored:
            pool.release(stored.pop(0), store=True)
        elif op == 6:
            pool.scrub()
        _paged_pool_invariants(pool, stored)
    # teardown drains everything: the pool must come back whole
    for slot in range(pool.slots):
        if bound[slot]:
            pool.free_slot(slot)
    while stored:
        pool.release(stored.pop(0), store=True)
    pool.scrub()
    _paged_pool_invariants(pool, stored)
    assert pool.n_free == pool.n_blocks and not pool._dirty
