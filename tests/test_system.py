"""End-to-end behaviour tests: training loop, elasticity, serving, PASTA
instrumentation over a real (reduced) workload."""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as C
import repro.core as pasta
from repro.models import init_params
from repro.train import (OptConfig, make_train_step, DataConfig,
                         SyntheticTokens, LoopConfig, TrainLoop,
                         checkpoint as ckpt)
from repro.train.optimizer import init_opt_state

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(arch="paper-gpt2", steps=12, seq=64, batch=4, **loop_kw):
    cfg = C.reduced(C.get(arch))
    opt_cfg = OptConfig(lr=3e-3, total_steps=steps, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2),
                   donate_argnums=(0, 1))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, opt_cfg)
    src = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                     global_batch=batch))
    place = lambda b: {k: jnp.asarray(v) for k, v in b.items()}  # noqa: E731
    # no handler passed: the loop resolves the innermost active session
    loop = TrainLoop(LoopConfig(total_steps=steps, **loop_kw), step, src,
                     place)
    return cfg, params, opt, loop


def test_train_loop_loss_decreases():
    losses = []
    cfg, params, opt, loop = _setup(steps=15)
    params, opt, step = loop.run(params, opt,
                                 metrics_cb=lambda s, m: losses.append(
                                     m["loss"]))
    assert step == 15 and len(losses) == 15
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_elastic_restart_after_injected_failure(tmp_path):
    """A mid-run failure restores the last checkpoint and completes; the
    step-indexed pipeline replays deterministically."""
    cfg, params, opt, loop = _setup(steps=12, ckpt_dir=str(tmp_path),
                                    ckpt_every=4, inject_failure_at=6)
    seen = []
    params, opt, step = loop.run(params, opt,
                                 metrics_cb=lambda s, m: seen.append(
                                     (s, m["loss"])))
    assert step == 12
    assert loop.restarts == 1
    # steps 4/5 executed twice (replay from the step-4 checkpoint) with
    # identical losses -> bit-exact restart
    by_step = {}
    replayed = 0
    for s, l in seen:
        if s in by_step:
            replayed += 1
            assert by_step[s] == pytest.approx(l, rel=0, abs=0), s
        by_step[s] = l
    assert replayed >= 1
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_failure_exhausts_restarts(tmp_path):
    cfg, params, opt, loop = _setup(steps=8, ckpt_dir=str(tmp_path),
                                    ckpt_every=4)
    loop.cfg.max_restarts = 0
    loop.cfg.inject_failure_at = 5
    with pytest.raises(RuntimeError):
        loop.run(params, opt)


def test_straggler_watchdog_counts():
    cfg, params, opt, loop = _setup(steps=10)
    orig = loop.train_step
    calls = {"n": 0}

    def slow_step(*a):
        calls["n"] += 1
        if calls["n"] == 8:
            import time
            time.sleep(1.5)
        return orig(*a)

    loop.train_step = slow_step
    loop.run(params, opt)
    assert loop.stragglers >= 1


def test_serve_engine_batched_generation():
    cfg = C.reduced(C.get("glm4-9b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.serve import ServeEngine
    eng = ServeEngine(cfg, params, max_seq=48)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (3, 16), dtype=np.int32)
    out = eng.generate(prompts, max_new_tokens=8)
    assert out.shape == (3, 8)
    # greedy decode is deterministic
    out2 = ServeEngine(cfg, params, max_seq=48).generate(
        prompts, max_new_tokens=8)
    np.testing.assert_array_equal(out, out2)


def test_pasta_instruments_training_end_to_end(handler):
    """The paper's core scenario: attach tools, run a workload, get reports
    with kernel frequencies from the compiled artifact."""
    tools = [pasta.KernelFrequencyTool(), pasta.LocatorTool()]
    proc = pasta.EventProcessor(handler, tools=tools)
    cfg, params, opt, loop = _setup(steps=3)
    src = loop.source
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    compiled = jax.jit(make_train_step(
        cfg, OptConfig(), microbatches=1)).lower(params, opt,
                                                 batch).compile()
    with pasta.region("capture"):
        stats = handler.capture_compiled(compiled, label="train",
                                         default_trip=cfg.n_layers, steps=3)
    rep = proc.finalize()
    kf = rep["KernelFrequencyTool"]
    assert kf["total_invocations"] > 0
    assert kf["by_label"]["train"]
    assert rep["LocatorTool"]["kernel"]
    assert stats.flops > 0 and stats.hbm_bytes > 0


def test_checkpoint_restore_validates_manifest(tmp_path):
    """A checkpoint saved from a different model must be refused with an
    error naming the mismatch, not silently unflattened into garbage."""
    state = {"params": {"w": np.ones((4, 2), np.float32),
                        "b": np.zeros((2,), np.float32)},
             "step_count": np.int32(7)}
    ckpt.save(str(tmp_path), 3, state)
    step, back = ckpt.restore(str(tmp_path), state)
    assert step == 3
    np.testing.assert_array_equal(back["params"]["w"], state["params"]["w"])

    wrong_shape = {"params": {"w": np.ones((4, 3), np.float32),
                              "b": np.zeros((2,), np.float32)},
                   "step_count": np.int32(7)}
    with pytest.raises(ValueError, match=r"params/w"):
        ckpt.restore(str(tmp_path), wrong_shape)

    wrong_dtype = {"params": {"w": np.ones((4, 2), np.float16),
                              "b": np.zeros((2,), np.float32)},
                   "step_count": np.int32(7)}
    with pytest.raises(ValueError, match=r"float16"):
        ckpt.restore(str(tmp_path), wrong_dtype)

    wrong_tree = {"params": {"w": np.ones((4, 2), np.float32),
                             "extra": np.zeros((1,), np.float32)},
                  "step_count": np.int32(7)}
    with pytest.raises(ValueError, match=r"tree mismatch"):
        ckpt.restore(str(tmp_path), wrong_tree)


def test_checkpoint_crash_mid_save_is_ignored(tmp_path):
    """Simulated crash debris — an in-flight ``.tmp`` dir, a dir missing
    COMMIT, junk names — must never shadow the last good checkpoint."""
    state = {"w": np.arange(6, dtype=np.float32)}
    ckpt.save(str(tmp_path), 2, state)

    # crash mid-write: .tmp never renamed (manifest present, no COMMIT)
    tmp_dir = tmp_path / "step_00000004.tmp"
    tmp_dir.mkdir()
    (tmp_dir / "manifest.json").write_text("{}")
    # torn dir without COMMIT (e.g. partially copied from another host)
    (tmp_path / "step_00000006").mkdir()
    # junk that merely looks checkpoint-shaped
    (tmp_path / "step_garbage").mkdir()
    (tmp_path / "step_notes.txt").write_text("x")

    assert ckpt.latest_step(str(tmp_path)) == 2
    step, back = ckpt.restore(str(tmp_path), state)
    assert step == 2
    np.testing.assert_array_equal(back["w"], state["w"])


def test_elastic_resize_restore_across_device_counts(tmp_path):
    """Save sharded at 2 forced host devices, resume at 1 (and at 2): the
    checkpoint holds global arrays, so the same trajectory replays
    regardless of the device count it restores onto."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "paper-gpt2", "--reduced", "--seq-len", "32",
            "--global-batch", "4", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "3", "--pasta-tools", "kernel_freq"]
    two_dev = ["--devices", "2", "--mesh", "2x1"]

    r = subprocess.run(base + two_dev + ["--steps", "3"],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    assert ckpt.latest_step(str(tmp_path)) == 3

    def resume(extra):
        # --ckpt-every 100: resumes must not publish new checkpoints, or
        # the second resume would restore the first one's step-6 save
        r = subprocess.run(base + extra + ["--resume", "--steps", "6",
                                           "--ckpt-every", "100"],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        assert r.returncode == 0, r.stderr
        assert "resumed from step 3" in r.stdout
        assert "done at step 6" in r.stdout
        return [ln.split("loss")[1].split()[0]
                for ln in r.stdout.splitlines()
                if ln.startswith("[train] step")]

    one_losses = resume([])                  # N=2 save -> M=1 restore
    two_losses = resume(two_dev)             # and back onto N=2
    assert len(one_losses) == 3
    # the replayed steps 4-6 match to printed precision across meshes
    assert one_losses == two_losses, (one_losses, two_losses)


def test_train_driver_cli_resume(tmp_path):
    """CLI driver: train 6 steps with checkpointing, then resume."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "paper-gpt2", "--reduced", "--steps", "6", "--seq-len", "32",
            "--global-batch", "2", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "3", "--pasta-tools", "kernel_freq"]
    r = subprocess.run(args, capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr
    assert "done at step 6" in r.stdout
    r2 = subprocess.run(args + ["--resume", "--steps", "8"],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr
    assert "resumed from step 6" in r2.stdout
    assert "done at step 8" in r2.stdout
