"""pasta.Session facade: registry + knob specs, scoped attach, session
isolation (concurrent + nested), child forwarding, structured reports,
deprecation shims.

The isolation goldens are strict: a session running concurrently with
another session over the same workload must produce reports *byte-identical*
to the same session running alone.
"""

import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

import repro.core as pasta
from repro.core import session as S
from repro.core.events import Event, EventKind, reset_seq
from repro.core.tools.base import (TOOL_REGISTRY, parse_tool_spec,
                                   resolve_tools)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: tool set whose reports carry no global sequence numbers — the seq counter
#: is the one process-global concurrent sessions share, so these reports
#: must be bit-equal under any interleaving
ISOLATION_TOOLS = "kernel_freq,workingset,roofline"


# ------------------------------------------------------------ registry/spec
def test_tool_spec_parsing():
    entries = parse_tool_spec(
        "kernel_freq,timeline:bins=64,hotness:n_tbins=8,hot_frac=0.75,"
        "locator")
    assert entries == [
        ("kernel_freq", {}),
        ("timeline", {"bins": 64}),
        ("hotness", {"n_tbins": 8, "hot_frac": 0.75}),
        ("locator", {}),
    ]
    assert parse_tool_spec("") == []
    assert parse_tool_spec("a:x=true,y=no,z=1.5e3") == [
        ("a", {"x": True, "y": False, "z": 1500.0})]


def test_tool_spec_errors():
    with pytest.raises(ValueError):
        parse_tool_spec("top_k=5")           # knob with no tool
    with pytest.raises(ValueError):
        parse_tool_spec(":x=1")              # empty tool name
    with pytest.raises(KeyError):
        resolve_tools("no_such_tool")


def test_resolve_tools_mixed_forms():
    inst = pasta.KernelFrequencyTool(top_k=3)
    tools = resolve_tools([inst, "timeline", pasta.WorkingSetTool,
                           ("hotness", {"n_tbins": 2})])
    assert tools[0] is inst
    assert isinstance(tools[1], pasta.MemoryTimelineTool)
    assert isinstance(tools[2], pasta.WorkingSetTool)
    assert tools[3].n_tbins == 2
    knobs = resolve_tools("kernel_freq:top_k=7")
    assert knobs[0].top_k == 7


def test_register_decorator_round_trip():
    @pasta.register("session_test_tool")
    class SessionTestTool(pasta.PastaTool):
        EVENTS = (EventKind.SYNC,)

        def __init__(self, factor=1, **knobs):
            super().__init__(**knobs)
            self.factor = factor
            self.n = 0

        def on_sync(self, ev):
            self.n += self.factor

        def finalize(self):
            return {"n": self.n}

    try:
        with pasta.Session(tools="session_test_tool:factor=3") as s:
            s.handler.sync()
            s.handler.sync()
        rep = s.reports()["session_test_tool"]
        assert rep.data == {"n": 6}
        assert rep.tool_class == "SessionTestTool"
        # name stealing is rejected
        with pytest.raises(ValueError):
            pasta.register("session_test_tool")(pasta.KernelFrequencyTool)
    finally:
        del TOOL_REGISTRY["session_test_tool"]


# ------------------------------------------------------------------ reports
def test_reports_typed_mapping_and_json(tmp_path):
    with pasta.Session(tools="kernel_freq,workingset",
                       name="json_test") as s:
        s.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="gemm.1",
                             attrs={"count": 4}))
    reports = s.reports()
    assert sorted(reports) == ["kernel_freq", "workingset"]
    rep = reports["kernel_freq"]
    assert isinstance(rep, pasta.Report)
    assert rep.tool == "kernel_freq" and rep.session == "json_test"
    assert rep["total_invocations"] == 4          # mapping-style access
    js = json.loads(rep.to_json())
    assert js["tool"] == "kernel_freq"
    assert js["data"]["total_invocations"] == 4
    # JSONL streaming export round-trips
    p = tmp_path / "reports.jsonl"
    assert reports.to_jsonl(p) == 2
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert [ln["tool"] for ln in lines] == ["kernel_freq", "workingset"]
    assert lines[0]["data"]["total_invocations"] == 4
    # whole-mapping JSON too
    assert json.loads(reports.to_json())["workingset"]["tool_class"] \
        == "WorkingSetTool"


def test_duplicate_tool_keys_suffix():
    with pasta.Session(tools=[pasta.KernelFrequencyTool(),
                              pasta.KernelFrequencyTool()]) as s:
        s.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="a"))
    assert sorted(s.reports()) == ["kernel_freq", "kernel_freq#2"]


# ---------------------------------------------------------------- isolation
def _drive(session, trace=False):
    """Deterministic workload against one session's pipeline: kernels,
    pool alloc/free, an operator, a collective.  Pool addresses are
    pool-local, so the stream is identical no matter what other sessions
    are doing concurrently."""
    h = session.handler
    h.step_start(0)
    for i in range(8):
        h.emit(Event(EventKind.KERNEL_LAUNCH, name=f"fusion.{i % 3}",
                     attrs={"count": i + 1, "bytes": 1 << 20}))
    pool = pasta.MemoryPool(h, chunk_size=1 << 20)
    ts = [pool.alloc((i + 1) << 12, f"t{i}") for i in range(5)]
    h.operator_start("op0", tensors=[(t.addr, t.size) for t in ts[:3]])
    h.emit(Event(EventKind.COLLECTIVE, name="all-reduce.1", size=1 << 16,
                 attrs={"mult": 2}))
    for t in ts[::2]:
        pool.free(t)
    h.step_end(0)
    return session.reports().data


def test_concurrent_sessions_byte_identical_to_solo():
    """Two Sessions running the same workload concurrently (their own
    threads, overlapping lifetimes) each produce reports byte-identical to
    a solo run."""
    reset_seq()
    with pasta.Session(tools=ISOLATION_TOOLS, name="solo") as solo:
        golden = _drive(solo)

    reset_seq()
    sessions = [pasta.Session(tools=ISOLATION_TOOLS, name=f"conc{i}")
                for i in range(2)]
    barrier = threading.Barrier(2)
    out, errs = {}, []

    def run(sess, key):
        try:
            with sess:
                barrier.wait(timeout=10)
                out[key] = _drive(sess)
        except Exception as e:                              # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=run, args=(s, i))
               for i, s in enumerate(sessions)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errs, errs
    assert out[0] == golden
    assert out[1] == golden


def test_nested_sessions_route_to_innermost():
    """Ambient emissions (pasta.region, handler-less MemoryPool) land in
    the innermost active session; outer sessions see nothing from inner
    scopes."""
    outer_regions, inner_regions = [], []
    with pasta.Session(tools="timeline", name="outer") as outer:
        outer.handler.subscribe(lambda e: outer_regions.append(e.name),
                                kinds=("region_start",))
        with pasta.Session(tools="timeline", name="inner") as inner:
            inner.handler.subscribe(lambda e: inner_regions.append(e.name),
                                    kinds=("region_start",))
            with pasta.region("deep"):
                pool = pasta.MemoryPool()        # ambient -> inner
                t = pool.alloc(4096)
                pool.free(t)
        with pasta.region("shallow"):
            pass
    assert inner_regions == ["deep"]
    assert outer_regions == ["shallow"]
    inner_tl = inner.reports()["timeline"].data
    outer_tl = outer.reports()["timeline"].data
    assert inner_tl["alloc_events"] and not outer_tl["alloc_events"]


def test_current_session_falls_back_to_root(pasta_root_session):
    assert pasta.active_session() is None
    assert pasta.current_session() is pasta_root_session
    assert pasta.current_handler() is pasta_root_session.handler
    with pasta.Session(name="scoped") as s:
        assert pasta.active_session() is s
        assert pasta.current_handler() is s.handler
    assert pasta.active_session() is None


def test_closed_session_cannot_reenter():
    s = pasta.Session(tools="kernel_freq")
    s.close()
    with pytest.raises(RuntimeError):
        with s:
            pass


def test_close_inside_with_block_is_safe():
    """close() mid-scope must not break __exit__ (or mask the body's
    exception with an IndexError)."""
    with pasta.Session(tools="kernel_freq") as s:
        s.close()
    assert s.closed and pasta.active_session() is None


def test_unregistered_subclass_keyed_by_class_name():
    """A subclass of a registered tool inherits REGISTRY_NAME but is not
    itself registered — its report must be keyed by its own class name."""
    class MyKernelTool(pasta.KernelFrequencyTool):
        pass

    with pasta.Session(tools=[MyKernelTool(), pasta.KernelFrequencyTool()]) \
            as s:
        s.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="a"))
    assert sorted(s.reports()) == ["MyKernelTool", "kernel_freq"]


# ----------------------------------------------------------- child sessions
def test_child_session_isolated_reports_and_forwarding():
    with pasta.Session(tools="kernel_freq", name="parent") as parent:
        with parent.child(tools="kernel_freq", name="req0") as c0:
            c0.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="a",
                                  attrs={"count": 2}))
        with parent.child(tools="kernel_freq", name="req1") as c1:
            c1.handler.emit(Event(EventKind.KERNEL_LAUNCH, name="b",
                                  attrs={"count": 5}))
    # children are isolated from each other...
    assert c0.reports()["kernel_freq"]["total_invocations"] == 2
    assert c1.reports()["kernel_freq"]["total_invocations"] == 5
    # ...while the parent aggregates both (forwarded batches)
    assert parent.reports()["kernel_freq"]["total_invocations"] == 7
    assert [c.name for c in parent.children] == ["req0", "req1"]


def test_serve_engine_per_request_child_sessions():
    import repro.configs as C
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = C.reduced(C.get("paper-gpt2"))
    params = init_params(__import__("jax").random.PRNGKey(0), cfg)
    ops = []
    with pasta.Session(name="engine", tools="kernel_freq") as sess:
        sess.handler.subscribe(lambda e: ops.append(e.name),
                               kinds=("operator_start",))
        eng = ServeEngine(cfg, params, max_seq=24, session=sess,
                          request_tools="kernel_freq")
        out = eng.generate(np.zeros((2, 8), dtype=np.int32),
                           max_new_tokens=4)
        eng.generate(np.zeros((2, 8), dtype=np.int32), max_new_tokens=2)
    assert out.shape == (2, 4)
    # per-request children forwarded their operator events to the parent
    assert ops.count("serve.prefill") == 2
    assert ops.count("serve.decode") == (4 - 1) + (2 - 1)
    # one isolated report set per request
    assert len(eng.request_reports) == 2
    names = [rep.session for req in eng.request_reports
             for rep in req.values()]
    assert names == ["engine/request0", "engine/request1"]
    # request children are closed after report collection, so a long-lived
    # engine session never accumulates per-request pipelines
    assert sess.children == []


def test_child_default_ignores_pasta_tool_env(monkeypatch):
    """Children (and so per-request engine sessions) must not silently
    build pipelines from the PASTA_TOOL environment default."""
    monkeypatch.setenv("PASTA_TOOL", "workingset")
    with pasta.Session(tools="kernel_freq", name="p") as p:
        with p.child(name="c") as c:
            pass
    assert c.tools == []
    # explicit None at the Session level still honors the env (CLI parity)
    s = pasta.Session()
    assert [type(t).__name__ for t in s.tools] == ["WorkingSetTool"]
    s.close()


# ------------------------------------------------------------------- shims
def test_deprecated_shims_still_work():
    with pytest.warns(DeprecationWarning, match="pasta.attach"):
        h = pasta.attach()
    assert h is S.root_session().handler
    with pytest.warns(DeprecationWarning, match="pasta.default_handler"):
        h2 = pasta.default_handler()
    assert h2 is h
    with pytest.warns(DeprecationWarning, match="pasta.make_tools"):
        tools = pasta.make_tools("kernel_freq,timeline")
    assert [type(t).__name__ for t in tools] == ["KernelFrequencyTool",
                                                 "MemoryTimelineTool"]
    # the shimmed wiring still functions end to end
    with pytest.warns(DeprecationWarning):
        handler = pasta.attach()
    proc = pasta.EventProcessor(handler, tools=tools)
    handler.emit(Event(EventKind.KERNEL_LAUNCH, name="x", attrs={"count": 3}))
    assert proc.finalize()["KernelFrequencyTool"]["total_invocations"] == 3
    proc.close()


def test_shim_attach_respects_innermost_session():
    """default_handler() inside a session scope resolves that session —
    legacy emit sites compose with scoped sessions."""
    with pasta.Session(name="scoped") as s:
        with pytest.warns(DeprecationWarning):
            assert pasta.default_handler() is s.handler


# ------------------------------------------------------------ end-to-end
def test_quickstart_example_runs_session_only():
    """Acceptance: examples/quickstart.py runs end-to-end on pasta.Session
    alone — with pasta deprecation warnings escalated to errors, proving it
    never touches attach()/default_handler()/make_tools()."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["PYTHONWARNINGS"] = "error:pasta:DeprecationWarning::"
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "quickstart.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    assert "kernel_freq: total=" in r.stdout
    assert "workingset:" in r.stdout
    assert "timeline: peak=" in r.stdout
