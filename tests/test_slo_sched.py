"""SLO-aware multi-tenant scheduling: policies, preemption, traffic.

The load-bearing guarantees of the scheduling subsystem:

  * preemption is LOSSLESS: a preempted request's committed KV blocks park
    in the prefix store (refcount holds, zero copies) and re-admission
    aliases them back, so outputs stay byte-identical to an unpreempted
    solo run — at temperature 0 and at temperature > 0 (sampling is keyed
    on (seed, position), never on batch composition);
  * policies only decide ORDER — FCFS/priority/EDF runs of the same
    submissions produce identical per-request tokens;
  * admission block reservations (``_owed``) are released exactly on
    abort/preempt/retire: the pool always comes back whole;
  * the traffic generator is deterministic in its seed and round-trips
    through JSONL;
  * the serving tool reports per-tenant SLO attainment, goodput and
    preemption/recovery counters.
"""

import functools
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

import repro.configs as C
import repro.core as pasta
from repro.models import init_params
from repro.serve import (EDFPolicy, FairSharePolicy, FCFSPolicy, POLICIES,
                         PriorityPolicy, SamplingParams, Scheduler,
                         ServeEngine, SLOSpec, TenantSpec, get_policy,
                         load_trace, make_trace, max_seq_for, save_trace,
                         two_tenant_bursty)
from repro.serve.scheduler import Request, RequestState
from repro.serve.traffic import PRESETS, _interarrivals

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@functools.lru_cache(maxsize=None)
def _setup(arch="paper-gpt2"):
    cfg = C.reduced(C.get(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(rid, slo=None, tokens=(), submit_time=1.0, prompt_len=4):
    r = Request(rid=rid, prompt=np.arange(prompt_len, dtype=np.int32),
                params=SamplingParams(), slo=slo, submit_time=submit_time)
    r.tokens = list(tokens)
    return r


# ------------------------------------------------------------ policy units
def test_get_policy_resolution_and_errors():
    assert get_policy(None) is None
    inst = PriorityPolicy(preempt=False)
    assert get_policy(inst) is inst and not inst.preemptive
    assert isinstance(get_policy("fcfs"), FCFSPolicy)
    assert isinstance(get_policy("edf"), EDFPolicy)
    assert set(POLICIES) == {"fcfs", "priority", "edf", "fair"}
    # stateful policies must come out fresh per engine
    assert get_policy("fair") is not get_policy("fair")
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_policy("sjf")


def test_fcfs_policy_never_reorders():
    sched = Scheduler(1, policy=get_policy("fcfs"))
    hi = _req(0, SLOSpec(priority=9))
    lo = _req(1, SLOSpec(priority=0))
    sched.submit(lo)
    sched.submit(hi)
    sched.reorder(0.0)
    assert [r.rid for r in sched.waiting] == [1, 0]   # arrival order kept


def test_priority_policy_orders_and_evicts_youngest_lowest():
    pol = get_policy("priority")
    sched = Scheduler(2, policy=pol)
    lo0, lo1 = _req(0, SLOSpec(priority=0)), _req(1, SLOSpec(priority=0))
    hi = _req(2, SLOSpec(priority=5))
    untagged = _req(3)                                # priority 0 default
    for r in (lo0, lo1, hi, untagged):
        sched.submit(r)
    sched.reorder(0.0)
    assert [r.rid for r in sched.waiting] == [2, 0, 1, 3]
    # both slots held by lo, hi waits: the YOUNGEST lowest-priority running
    # request is the victim (least sunk work)
    assert pol.victims([hi], {0: lo0, 1: lo1}, 0, 0.0) == [lo1]
    # a free slot satisfies the waiter — no eviction
    assert pol.victims([hi], {0: lo0, 1: lo1}, 1, 0.0) == []
    # equal priority never preempts (strict inequality)
    assert pol.victims([lo0], {0: lo1, 1: hi}, 0, 0.0) == []
    # two high waiters, two low runners: both evicted, youngest first
    hi2 = _req(4, SLOSpec(priority=5))
    assert pol.victims([hi, hi2], {0: lo0, 1: lo1}, 0, 0.0) == [lo1, lo0]


def test_edf_policy_deadline_order_and_first_token_guard():
    pol = get_policy("edf")
    a = _req(0, SLOSpec(ttft_target_s=5.0), submit_time=10.0)   # ddl 15
    b = _req(1, SLOSpec(ttft_target_s=1.0), submit_time=12.0)   # ddl 13
    c = _req(2)                                                 # no target
    sched = Scheduler(1, policy=pol)
    for r in (a, b, c):
        sched.submit(r)
    sched.reorder(0.0)
    assert [r.rid for r in sched.waiting] == [1, 0, 2]   # targetless last
    # preemption only targets runners that have NOT produced a first token
    decoding = _req(3, tokens=[7])
    fresh = _req(4)
    assert pol.victims([b], {0: decoding, 1: fresh}, 0, 0.0) == [fresh]
    assert pol.victims([b], {0: decoding}, 0, 0.0) == []
    # an earlier-deadline runner is never evicted for a later waiter
    urgent = _req(5, SLOSpec(ttft_target_s=0.1), submit_time=10.0)
    assert pol.victims([a], {0: urgent}, 0, 0.0) == []


def test_fair_share_policy_orders_by_served_tokens():
    pol = get_policy("fair")
    chatty = _req(0, SLOSpec(tenant="chatty"))
    quiet = _req(1, SLOSpec(tenant="quiet"))
    for _ in range(5):
        pol.note_tokens(chatty)
    sched = Scheduler(1, policy=pol)
    sched.submit(chatty)
    sched.submit(quiet)
    sched.reorder(0.0)
    assert [r.rid for r in sched.waiting] == [1, 0]   # least-served first
    assert pol.served == {"chatty": 5}


def test_scheduler_preempt_requeues_front_with_tokens():
    sched = Scheduler(1)
    r = _req(0, tokens=[5, 6])
    sched.submit(r)
    sched.admit()
    assert r.state is RequestState.RUNNING and sched.n_free == 0
    sched.submit(_req(1))
    sched.preempt(r)
    assert r.state is RequestState.QUEUED and r.slot is None
    assert r.preemptions == 1 and r.tokens == [5, 6]
    assert [q.rid for q in sched.waiting] == [0, 1]   # front of the queue
    assert sched.n_free == 1
    with pytest.raises(ValueError, match="does not hold a slot"):
        sched.preempt(r)


# ----------------------------------------------------------------- traffic
def test_make_trace_deterministic_sorted_and_tenant_independent():
    ten = [TenantSpec(name="a", n_requests=6, rate=40.0, arrival="poisson",
                      shared_prefix=8, prefix_pool=2, priority=1),
           TenantSpec(name="b", n_requests=5, rate=25.0, arrival="gamma",
                      cv2=4.0, start_s=0.1, ttft_target_s=0.5)]
    t1 = make_trace(ten, vocab=97, seed=3)
    t2 = make_trace(ten, vocab=97, seed=3)
    assert len(t1) == 11
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(t1, t1[1:]))
    for x, y in zip(t1, t2):
        assert x.arrival_s == y.arrival_s and x.max_new_tokens == \
            y.max_new_tokens and np.array_equal(x.prompt, y.prompt)
    assert any(not np.array_equal(x.prompt, y.prompt) for x, y in
               zip(t1, make_trace(ten, vocab=97, seed=4)))
    # per-tenant streams: adding a tenant never perturbs another's trace
    solo_a = make_trace(ten[:1], vocab=97, seed=3)
    both_a = [t for t in t1 if t.tenant == "a"]
    for x, y in zip(solo_a, both_a):
        assert x.arrival_s == y.arrival_s and np.array_equal(x.prompt,
                                                             y.prompt)
    assert max_seq_for(t1, pad=4) == max(len(t.prompt) + t.max_new_tokens
                                         for t in t1) + 4


def test_arrival_processes_rate_and_clumping():
    spec = TenantSpec(n_requests=64, rate=10.0, arrival="burst",
                      burst_size=4)
    gaps = _interarrivals(spec, np.random.default_rng(0))
    # burst: arrivals land in simultaneous clumps of burst_size
    assert len(set(gaps.tolist())) == 16
    assert _interarrivals(TenantSpec(n_requests=5, rate=0.0),
                          np.random.default_rng(0)).tolist() == [0.0] * 5
    with pytest.raises(ValueError, match="unknown arrival process"):
        _interarrivals(TenantSpec(arrival="lognormal", rate=1.0),
                       np.random.default_rng(0))


def test_trace_jsonl_roundtrip(tmp_path):
    ten = [TenantSpec(name="t", n_requests=4, rate=5.0, shared_prefix=4,
                      priority=2, ttft_target_s=0.25)]
    trace = make_trace(ten, vocab=50, seed=1)
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, trace, seed=1, meta={"note": "x"})
    back, meta = load_trace(path)
    assert meta["seed"] == 1 and meta["note"] == "x"
    assert meta["n_requests"] == len(back) == len(trace)
    for x, y in zip(trace, back):
        assert np.array_equal(x.prompt, y.prompt)
        assert x.arrival_s == y.arrival_s
        assert x.max_new_tokens == y.max_new_tokens
        assert x.slo == y.slo


def test_trace_schema_versioning(tmp_path):
    """save_trace stamps the schema version; load_trace refuses traces
    from a newer writer, accepts legacy headerless-schema files, and
    round-trips the hard deadline."""
    from repro.serve.traffic import TRACE_SCHEMA, TraceRequest

    slo = SLOSpec(tenant="t", deadline_s=2.5)
    trace = [TraceRequest(arrival_s=0.0,
                          prompt=np.arange(4, dtype=np.int32),
                          max_new_tokens=3, slo=slo)]
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, trace, seed=7)
    back, meta = load_trace(path)
    assert meta["schema"] == TRACE_SCHEMA
    assert back[0].slo.deadline_s == 2.5

    # legacy v0: header without a schema field still loads
    lines = open(path).read().splitlines()
    head = json.loads(lines[0])["_meta"]
    del head["schema"]
    legacy = str(tmp_path / "legacy.jsonl")
    with open(legacy, "w") as f:
        f.write(json.dumps({"_meta": head}) + "\n")
        f.write("\n".join(lines[1:]) + "\n")
    back2, meta2 = load_trace(legacy)
    assert "schema" not in meta2 and len(back2) == 1

    # a future writer's trace is refused, not misread
    head["schema"] = TRACE_SCHEMA + 1
    future = str(tmp_path / "future.jsonl")
    with open(future, "w") as f:
        f.write(json.dumps({"_meta": head}) + "\n")
        f.write("\n".join(lines[1:]) + "\n")
    with pytest.raises(ValueError, match=r"schema v2.*newer"):
        load_trace(future)


def test_two_tenant_bursty_preset():
    trace = two_tenant_bursty(vocab=64, seed=0)
    assert PRESETS["two-tenant-bursty"] is two_tenant_bursty
    tenants = {t.tenant for t in trace}
    assert tenants == {"lo", "hi"}
    hi = [t for t in trace if t.tenant == "hi"]
    lo = [t for t in trace if t.tenant == "lo"]
    assert all(t.slo.priority == 5 and t.arrival_s >= 0.15 for t in hi)
    assert all(t.slo.priority == 0 and t.arrival_s == 0.0 for t in lo)
    assert all(t.max_new_tokens < min(x.max_new_tokens for x in lo)
               for t in hi)


# -------------------------------------------------------------- preemption
def _solo(cfg, params, prompt, max_new, **kw):
    eng = ServeEngine(cfg, params, max_seq=64, max_slots=1, prefix_block=8,
                      **kw)
    out = eng.run([(prompt, SamplingParams(max_new_tokens=max_new))])
    return list(out.values())[0]


def _mixed_run(cfg, params, lo_prompts, hi_prompts, temperature=0.0, **kw):
    """2 lo requests decode for 3 ticks, then 2 hi (priority 5) burst in;
    returns (engine, session report, lo rids, hi rids)."""
    with pasta.Session(tools="serving", name="mix") as sess:
        eng = ServeEngine(cfg, params, max_seq=64, max_slots=2,
                          session=sess, prefix_block=8, **kw)
        lo = [eng.submit(p, SamplingParams(max_new_tokens=12,
                                           temperature=temperature),
                         slo=SLOSpec(tenant="lo", priority=0,
                                     ttft_target_s=60.0))
              for p in lo_prompts]
        for _ in range(3):
            eng.step()
        hi = [eng.submit(p, SamplingParams(max_new_tokens=4,
                                           temperature=temperature),
                         slo=SLOSpec(tenant="hi", priority=5,
                                     ttft_target_s=60.0))
              for p in hi_prompts]
        while eng.sched.has_work:
            eng.step()
    return eng, sess.reports()["serving"].data, lo, hi


def test_priority_preemption_byte_identical_to_solo():
    """The tentpole guarantee: preempt → park in prefix store → resume
    aliases back, outputs byte-identical to unpreempted solo runs, zero
    duplicate copies, pool accounting whole."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    lo_p = [rng.integers(0, cfg.vocab_size, (12,), dtype=np.int32)
            for _ in range(2)]
    hi_p = [rng.integers(0, cfg.vocab_size, (10,), dtype=np.int32)
            for _ in range(2)]
    refs_lo = [_solo(cfg, params, p, 12) for p in lo_p]
    refs_hi = [_solo(cfg, params, p, 4) for p in hi_p]

    eng, rep, lo, hi = _mixed_run(cfg, params, lo_p, hi_p,
                                  policy="priority")
    assert eng.preemptions == 2 and eng.parked_blocks > 0
    assert eng.recovered_blocks > 0 and eng.recovered_tokens > 0
    assert eng.duplicate_copy_bytes == 0
    for rid, want in zip(lo, refs_lo):
        assert eng.requests[rid].preemptions == 1
        assert list(eng.requests[rid].tokens) == list(want)
    for rid, want in zip(hi, refs_hi):
        assert list(eng.requests[rid].tokens) == list(want)
    eng.pool.scrub()
    st = eng.pool.stats()
    assert (st["blocks_live"] + st["blocks_evictable"] + st["blocks_free"]
            == st["n_blocks"]), st

    # serving-tool accounting of the same run
    assert rep["preemption"]["count"] == 2
    assert rep["preemption"]["resumed"] == 2
    assert rep["preemption"]["parked_blocks"] == eng.parked_blocks
    assert rep["preemption"]["recovered_blocks"] == eng.recovered_blocks
    assert rep["tenants"]["lo"]["preemptions"] == 2
    assert rep["tenants"]["hi"]["preemptions"] == 0
    assert rep["slo"]["attainment"] == 1.0          # 60 s targets: all met
    assert rep["slo"]["good_tokens"] == rep["generated_tokens"]
    assert 0 < rep["slo"]["jain_fairness"] <= 1
    rows = rep["by_request"]
    assert all(rows[rid]["tenant"] == "lo" and rows[rid]["preempts"] == 1
               and rows[rid]["slo_met"] for rid in lo)
    assert all(rows[rid]["tenant"] == "hi" for rid in hi)


def test_preemption_schedule_invariant_at_temperature():
    """Sampling keys on (seed, position) — so even at temperature > 0 a
    preempting policy and FCFS produce identical streams per request."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    lo_p = [rng.integers(0, cfg.vocab_size, (12,), dtype=np.int32)
            for _ in range(2)]
    hi_p = [rng.integers(0, cfg.vocab_size, (10,), dtype=np.int32)
            for _ in range(2)]
    eng_f, _, lo_f, hi_f = _mixed_run(cfg, params, lo_p, hi_p,
                                      temperature=0.8, policy="fcfs")
    eng_p, _, lo_p2, hi_p2 = _mixed_run(cfg, params, lo_p, hi_p,
                                        temperature=0.8, policy="priority")
    assert eng_f.preemptions == 0 and eng_p.preemptions == 2
    for a, b in zip(lo_f + hi_f, lo_p2 + hi_p2):
        assert list(eng_f.requests[a].tokens) == \
            list(eng_p.requests[b].tokens)


def test_mid_prefill_preemption_resumes_exactly():
    """Preempting a request that has only chunk-prefilled part of its
    prompt restarts cleanly: the finished prefix parks (block-aligned) and
    the resumed admission completes the prompt, matching solo output."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, (40,), dtype=np.int32)
    want = _solo(cfg, params, prompt, 6, prefill_chunk=8)

    eng = ServeEngine(cfg, params, max_seq=64, max_slots=1, prefix_block=8,
                      prefill_chunk=8)
    rid = eng.submit(prompt, SamplingParams(max_new_tokens=6))
    eng.step()                                  # one 8-token chunk in
    req = eng.requests[rid]
    assert 0 < req.progress < req.prompt_len and not req.prefilled
    assert eng.preempt(rid) is True
    assert req.state is RequestState.QUEUED and req.progress == 0
    while eng.sched.has_work:
        eng.step()
    assert list(req.tokens) == list(want)
    assert eng.preemptions == 1 and eng.recovered_blocks > 0


def test_preempt_validation_and_interleave_errors():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=1, prefix_block=8)
    assert eng.preempt(123) is False            # unknown rid
    eng.submit(np.arange(1, 9, dtype=np.int32),
               SamplingParams(max_new_tokens=2))
    rid2 = eng.submit(np.arange(1, 9, dtype=np.int32),
                      SamplingParams(max_new_tokens=2))
    assert eng.preempt(rid2) is False           # QUEUED, not RUNNING
    eng.abort_all()

    # preemptive policies need the paged pool to park KV
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, max_seq=32, max_slots=1, paged=False,
                    policy="priority")
    # ... but a non-preemptive priority policy is fine without it
    ServeEngine(cfg, params, max_seq=32, max_slots=1, paged=False,
                policy=PriorityPolicy(preempt=False))
    with pytest.raises(ValueError, match="interleave"):
        ServeEngine(cfg, params, max_seq=32, max_slots=1,
                    interleave="sideways")
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine(cfg, params, max_seq=32, max_slots=1,
                    interleave="decode")


def test_legacy_dense_pool_rejects_preempt():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, max_seq=32, max_slots=1, paged=False)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32),
                     SamplingParams(max_new_tokens=4))
    eng.step()
    assert eng.requests[rid].state is RequestState.RUNNING
    with pytest.raises(ValueError, match="paged"):
        eng.preempt(rid)
    eng.abort_all()


def test_interleave_decode_defers_prefill_until_decode_idle():
    """interleave='decode': chunk work only runs on decode-idle ticks, so
    a cold prompt makes zero prefill progress while another slot decodes —
    and arbitration never changes the sampled tokens."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    short = rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
    long_p = rng.integers(0, cfg.vocab_size, (40,), dtype=np.int32)
    want_short = _solo(cfg, params, short, 8, prefill_chunk=8)
    want_long = _solo(cfg, params, long_p, 4, prefill_chunk=8)

    eng = ServeEngine(cfg, params, max_seq=64, max_slots=2, prefix_block=8,
                      prefill_chunk=8, interleave="decode")
    rid_s = eng.submit(short, SamplingParams(max_new_tokens=8))
    eng.step()
    assert eng.requests[rid_s].prefilled
    rid_l = eng.submit(long_p, SamplingParams(max_new_tokens=4))
    req_s, req_l = eng.requests[rid_s], eng.requests[rid_l]
    while eng.sched.has_work:
        eng.step()
        if req_s.state is RequestState.RUNNING and req_s.prefilled:
            # decode-priority: the cold prompt must not advance this tick
            assert req_l.progress == 0
    assert req_l.first_token_time > req_s.finish_time
    assert list(req_s.tokens) == list(want_short)
    assert list(req_l.tokens) == list(want_long)


# --------------------------------------------- admission reservations/abort
def test_abort_releases_owed_reservations_and_blocks_exactly():
    """Aborting queued and running requests restores the pool to the exact
    block count it had, and ``_owed`` only ever tracks running requests —
    a queued request that never admitted holds no reservation."""
    cfg, params = _setup()
    # 6 blocks of 8 tokens: one 32-token-horizon request owes 4 blocks, so
    # a second identical one cannot fit and queues (prefix cache off keeps
    # the ledger pure — no store-held blocks)
    eng = ServeEngine(cfg, params, max_seq=64, max_slots=2, block_size=8,
                      n_blocks=6, prefix_cache=False)
    free0 = eng.pool.available()
    assert free0 == 6
    sp = SamplingParams(max_new_tokens=16)
    rng = np.random.default_rng(4)
    r1 = eng.submit(rng.integers(0, cfg.vocab_size, (16,), np.int32)
                    .astype(np.int32), sp)
    r2 = eng.submit(rng.integers(0, cfg.vocab_size, (16,), np.int32)
                    .astype(np.int32), sp)
    eng.step()
    assert eng.requests[r1].state is RequestState.RUNNING
    assert eng.requests[r2].state is RequestState.QUEUED
    assert set(eng._owed) <= {r1}               # no reservation for queued
    # aborting the queued request changes nothing in the pool ledger
    avail_before = eng.pool.available()
    owed_before = sum(eng._owed.values())
    assert eng.abort(r2)
    assert eng.pool.available() == avail_before
    assert sum(eng._owed.values()) == owed_before
    # aborting the running request restores every block
    assert eng.abort(r1)
    assert not eng._owed
    eng.pool.scrub()
    assert eng.pool.available() == eng.pool.n_free == free0
    # the whole pool is usable again: a full-capacity request drains fine
    r3 = eng.submit(rng.integers(0, cfg.vocab_size, (32,), np.int32)
                    .astype(np.int32), SamplingParams(max_new_tokens=16))
    while eng.sched.has_work:
        eng.step()
    assert eng.requests[r3].state is RequestState.FINISHED
    assert len(eng.requests[r3].tokens) == 16


def test_preempted_then_aborted_request_frees_parked_blocks_on_evict():
    """A preempted request's parked blocks are store-held (evictable, not
    leaked): aborting it while queued leaves them reclaimable and the pool
    balances after eviction."""
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, max_seq=64, max_slots=1, prefix_block=8)
    rng = np.random.default_rng(5)
    rid = eng.submit(rng.integers(0, cfg.vocab_size, (16,), np.int32)
                     .astype(np.int32), SamplingParams(max_new_tokens=8))
    for _ in range(3):
        eng.step()
    assert eng.preempt(rid)
    assert eng.abort(rid)
    st = eng.pool.stats()
    assert st["blocks_live"] == 0               # nothing held by slots
    assert st["blocks_evictable"] > 0           # parked KV, reclaimable
    assert eng.pool.available() == st["n_blocks"]
    assert (st["blocks_live"] + st["blocks_evictable"] + st["blocks_free"]
            == st["n_blocks"]), st


# ------------------------------------------------------- serving-tool SLO
def test_serving_tool_slo_attainment_and_tenant_sections():
    """Deterministic SLO accounting: impossible (1 ns) targets miss, lax
    (1e9 s) targets meet; goodput counts only SLO-meeting requests."""
    cfg, params = _setup()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
               for _ in range(4)]
    ok = SLOSpec(ttft_target_s=1e9, tpot_target_s=1e9, tenant="batch")
    bad = SLOSpec(ttft_target_s=1e-9, tenant="rt")
    with pasta.Session(tools="serving", name="slo") as sess:
        eng = ServeEngine(cfg, params, max_seq=32, max_slots=2,
                          session=sess, prefix_block=8)
        sp = SamplingParams(max_new_tokens=4)
        for p, slo in zip(prompts, (ok, ok, bad, bad)):
            eng.submit(p, sp, slo=slo)
        while eng.sched.has_work:
            eng.step()
    rep = sess.reports()["serving"].data
    assert rep["slo"]["attainment"] == 0.5
    assert rep["slo"]["good_tokens"] == 8       # the two "batch" requests
    assert rep["slo"]["goodput_tok_per_s"] > 0
    assert 0 < rep["slo"]["jain_fairness"] <= 1
    bt = rep["tenants"]
    assert set(bt) == {"batch", "rt"}
    assert bt["batch"]["slo_attainment"] == 1.0
    assert bt["rt"]["slo_attainment"] == 0.0
    assert bt["rt"]["goodput_tok_per_s"] == 0.0
    assert bt["batch"]["generated_tokens"] == 8
    assert bt["batch"]["ttft_s"]["p50"] > 0
    met = [r["slo_met"] for r in rep["by_request"].values()]
    assert sorted(met) == [False, False, True, True]
    # untagged traffic keeps the legacy shape: no tenants beyond "default"
    assert rep["preemption"]["count"] == 0


# ----------------------------------------------------------------- driver
def test_serve_driver_traffic_policy_and_trace_roundtrip(tmp_path):
    """--traffic preset + --policy priority + --save-trace: the JSON
    carries policy/SLO/preemption sections and the saved JSONL replays the
    exact preset trace (satellite: trace seed recorded for replay)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    path = tmp_path / "serve.json"
    trace_path = tmp_path / "trace.jsonl"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--reduced",
         "--traffic", "two-tenant-bursty", "--policy", "priority",
         "--max-slots", "2", "--prefix-block", "8", "--prefill-chunk", "32",
         "--seed", "5", "--save-trace", str(trace_path),
         "--json", str(path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    out = json.loads(path.read_text())
    assert out["status"] == "ok"
    assert out["config"]["policy"] == "priority"
    assert out["config"]["traffic"] == "two-tenant-bursty"
    assert out["config"]["trace_seed"] == 5
    s = out["summary"]
    assert s["preemption"]["count"] > 0
    assert s["preemption"]["recovered_blocks"] > 0
    assert s["pool"]["duplicate_copy_bytes"] == 0
    assert set(s["tenants"]) == {"lo", "hi"}
    assert s["slo"]["goodput_tok_per_s"] > 0
    # the saved trace replays the preset byte-for-byte
    back, meta = load_trace(str(trace_path))
    assert meta["seed"] == 5 and meta["preset"] == "two-tenant-bursty"
    cfg, _ = _setup()
    want = two_tenant_bursty(cfg.vocab_size, seed=5)
    assert len(back) == len(want) == meta["n_requests"]
    for x, y in zip(back, want):
        assert np.array_equal(x.prompt, y.prompt)
        assert x.arrival_s == y.arrival_s
        assert x.max_new_tokens == y.max_new_tokens
        assert x.slo == y.slo
