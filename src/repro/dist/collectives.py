"""Compressed cross-pod collectives.

Cross-pod gradient sync is the one collective that crosses the slow
inter-pod links, so it gets a compressed variant: each participant
quantizes its local tensor to int8 with per-row (last-axis) absmax scales,
the int8 payload + f32 scales move over the wire (~4× fewer bytes than an
f32 all-reduce), and the sum is taken after dequantization.  Relative
error for gradient-like (zero-mean) tensors is <1% (property-tested).

``plain_psum`` / ``compressed_psum`` are collective primitives usable
inside any ``shard_map``; :func:`make_pod_sync` wraps them into a
pytree-level gradient synchronizer over the ``"pod"`` mesh axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------- quantization
def quantize_int8(x):
    """Symmetric int8 with per-row (last-axis) absmax scales.

    Returns ``(q, scale)`` with ``q`` int8 of ``x``'s shape and ``scale``
    f32 of shape ``(*x.shape[:-1], 1)`` — shapes (hence shardings) of the
    original tensor are preserved.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


# -------------------------------------------------------------- collectives
def plain_psum(x, axis_name: str):
    """Uncompressed all-reduce over ``axis_name`` (baseline)."""
    return jax.lax.psum(x, axis_name)


def compressed_psum(x, axis_name: str):
    """int8-compressed all-reduce over ``axis_name``.

    quantize → all-gather the (int8, scale) pairs → dequantize → local sum.
    Only the quantized payload crosses the interconnect; the result matches
    :func:`plain_psum` within quantization error (<1% relative).

    NOTE: all-gather wire bytes grow with the axis size N — the ~4× saving
    over an f32 ring all-reduce holds for the 2-pod production mesh this
    targets and erodes to parity by N≈8.  Scaling past 2 pods needs the
    quantized reduce-scatter layout (see ROADMAP "Multi-pod meshes").
    """
    squeeze = x.ndim == 0
    if squeeze:
        x = x.reshape(1)
    q, s = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis_name)
    sg = jax.lax.all_gather(s, axis_name)
    out = jnp.sum(dequantize_int8(qg, sg), axis=0).astype(x.dtype)
    return out[0] if squeeze else out


def make_pod_sync(mesh, compressed: bool = False, axis: str = "pod",
                  specs=None):
    """Cross-pod gradient synchronizer: pytree → pytree, psum over ``axis``.

    Float leaves are all-reduced over the pod axis (int8-compressed when
    ``compressed=True``); non-float leaves (step counters, ...) pass
    through.  Identity when the mesh has no pod axis.

    ``specs`` is an optional pytree of ``PartitionSpec`` (matching the
    gradient tree) describing how leaves are sharded over the non-pod
    axes; supply it for FSDP/TP-sharded gradients so each device syncs
    only its shard.  The default ``P()`` treats leaves as replicated —
    fine for small trees, but it forces a full all-gather of sharded
    gradients first.
    """
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return lambda grads: grads
    op = compressed_psum if compressed else plain_psum

    def sync_one(g):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g
        return op(g, axis)

    def sync(grads):
        leaves, treedef = jax.tree.flatten(grads)
        in_specs = specs if specs is not None \
            else treedef.unflatten([P()] * len(leaves))
        f = shard_map(lambda tr: jax.tree.map(sync_one, tr), mesh=mesh,
                      in_specs=(in_specs,), out_specs=in_specs,
                      check_rep=False)
        return f(grads)

    return sync
