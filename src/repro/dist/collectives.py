"""Compressed + async cross-pod collectives.

Cross-pod gradient sync is the one collective that crosses the slow
inter-pod links, so it gets a compressed variant and an async (split)
variant:

* :func:`compressed_psum` — quantized **reduce-scatter + all-gather**: each
  participant quantizes its payload to int8 with per-group absmax scales,
  the pod all-to-all delivers every peer's contribution for the local output
  shard (wire: ~1× int8 payload regardless of pod count), the shard is
  summed locally, re-quantized, and all-gathered (wire: ~1× int8 payload).
  Per-device wire bytes are therefore **O(1) in pod count** — unlike the old
  all-gather-everything layout whose received bytes grew linearly with N and
  eroded to parity with an f32 ring all-reduce by N≈8.  Relative error for
  gradient-like (zero-mean) tensors is <1% (property-tested).
* :func:`psum_start` / :func:`psum_wait` — the bucketed async primitives:
  ``psum_start`` issues the reduce half (reduce-scatter, or the quantized
  all-to-all + local sum) and returns a :class:`PsumHandle`; ``psum_wait``
  completes it with the all-gather.  Compute placed between a start and its
  wait can overlap the in-flight collective — XLA's latency-hiding
  scheduler turns the split halves into ``*-start``/``*-done`` async pairs
  on TPU/GPU, and the PASTA HLO walker credits the overlap either way
  (see :mod:`repro.core.hlo`).

``plain_psum`` / ``compressed_psum`` are collective primitives usable
inside any ``shard_map``; :func:`make_pod_sync` wraps them into a
pytree-level gradient synchronizer over the ``"pod"`` mesh axis.  The
bucketed *overlapped* sync lives in :mod:`repro.train.trainer` and is built
from the start/wait primitives here.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

#: quantization group size for the wire layout — one f32 scale per GROUP
#: int8 payload bytes (+6% scale overhead, enough resolution for the <1%
#: round-trip bound through both quantization stages)
GROUP = 64


# ------------------------------------------------------------- quantization
def quantize_int8(x):
    """Symmetric int8 with per-row (last-axis) absmax scales.

    Returns ``(q, scale)`` with ``q`` int8 of ``x``'s shape and ``scale``
    f32 of shape ``(*x.shape[:-1], 1)`` — shapes (hence shardings) of the
    original tensor are preserved.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _quantize_groups(flat, group: int = GROUP):
    """Quantize a flat f32 payload (length divisible by ``group``) to int8
    with one f32 absmax scale per contiguous group of ``group`` elements.
    Returns ``(q int8 [L], scales f32 [L // group])``."""
    g = flat.reshape(-1, group)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale.reshape(-1)


def _dequantize_groups(q, scales, group: int = GROUP):
    g = q.reshape(-1, group).astype(jnp.float32)
    return (g * scales.reshape(-1, 1)).reshape(-1)


def _flatten_pad(x, multiple: int):
    """Flatten ``x`` to f32 1-D, zero-padded to a multiple of ``multiple``.
    Returns ``(flat, pad)``."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % multiple
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat, pad


# ----------------------------------------------------------- async handles
@dataclasses.dataclass
class PsumHandle:
    """In-flight bucketed psum: the reduced local shard (plus scales when
    compressed) and the metadata needed to finish and unflatten it."""

    payload: jax.Array            # (chunk,) f32, or int8 when compressed
    scales: jax.Array | None      # (chunk // group,) f32, compressed only
    shape: tuple
    dtype: object
    pad: int
    compressed: bool
    group: int = GROUP


jax.tree_util.register_dataclass(
    PsumHandle, data_fields=["payload", "scales"],
    meta_fields=["shape", "dtype", "pad", "compressed", "group"])


def psum_start(x, axis_name: str, compressed: bool = False,
               group: int = GROUP) -> PsumHandle:
    """Issue the *reduce* half of a bucketed psum over ``axis_name``.

    Plain: one reduce-scatter — each device ends up holding the fully
    reduced 1/N shard of the flattened payload.  Compressed: quantize →
    pod all-to-all of the (int8, scales) chunks → dequantize + local sum →
    re-quantize the reduced shard.  Either way the expensive wire transfer
    is *in flight* from this point; schedule independent compute before
    calling :func:`psum_wait`.
    """
    n = jax.lax.psum(1, axis_name)
    if not compressed:
        flat, pad = _flatten_pad(x, n)
        shard = jax.lax.psum_scatter(flat, axis_name, scatter_dimension=0,
                                     tiled=True)
        return PsumHandle(shard, None, tuple(x.shape), x.dtype, pad, False,
                          group)
    flat, pad = _flatten_pad(x, n * group)
    chunks = flat.reshape(n, -1)
    q, s = _quantize_groups(chunks.reshape(-1), group)
    q = jax.lax.all_to_all(q.reshape(n, -1), axis_name, split_axis=0,
                           concat_axis=0)
    s = jax.lax.all_to_all(s.reshape(n, -1), axis_name, split_axis=0,
                           concat_axis=0)
    shard = _dequantize_groups(q.reshape(-1), s.reshape(-1),
                               group).reshape(n, -1).sum(axis=0)
    qr, sr = _quantize_groups(shard, group)
    return PsumHandle(qr, sr, tuple(x.shape), x.dtype, pad, True, group)


def psum_wait(handle: PsumHandle, axis_name: str):
    """Finish a bucketed psum: all-gather the reduced shards and restore the
    original shape/dtype."""
    if handle.compressed:
        q = jax.lax.all_gather(handle.payload, axis_name, tiled=True)
        s = jax.lax.all_gather(handle.scales, axis_name, tiled=True)
        flat = _dequantize_groups(q, s, handle.group)
    else:
        flat = jax.lax.all_gather(handle.payload, axis_name, tiled=True)
    if handle.pad:
        flat = flat[:flat.size - handle.pad]
    return flat.reshape(handle.shape).astype(handle.dtype)


# -------------------------------------------------------------- collectives
def plain_psum(x, axis_name: str):
    """Uncompressed all-reduce over ``axis_name`` (baseline)."""
    return jax.lax.psum(x, axis_name)


def compressed_psum(x, axis_name: str):
    """int8-compressed all-reduce over ``axis_name``.

    Quantized reduce-scatter (all-to-all + local sum) followed by a
    quantized all-gather: only int8 payloads (+6% f32 group scales) cross
    the interconnect, and per-device wire bytes stay ~2× the int8 payload
    *independent of the axis size* — O(1) in pod count, ~4× fewer wire
    bytes than an f32 ring all-reduce at any N.  The result matches
    :func:`plain_psum` within quantization error (<1% relative through
    both quantization stages, property-tested at N = 2/4/8).
    """
    squeeze = x.ndim == 0
    if squeeze:
        x = x.reshape(1)
    out = psum_wait(psum_start(x, axis_name, compressed=True), axis_name)
    return out[0] if squeeze else out


def simulate_compressed_psum(stacked: np.ndarray) -> np.ndarray:
    """Deterministic numpy mirror of :func:`compressed_psum` for property
    tests: ``stacked[i]`` is participant *i*'s payload; returns what every
    participant would hold after the quantized reduce-scatter + all-gather.
    Exercises the exact same quantization helpers as the collective (the
    all-to-all / all-gather data movement is a no-op on a host array)."""
    n = stacked.shape[0]
    flats_pads = [_flatten_pad(jnp.asarray(x), n * GROUP) for x in stacked]
    pad = flats_pads[0][1]
    # stage A: per-participant quantization, exchange, local shard sum
    chunks = []
    for flat, _ in flats_pads:
        q, s = _quantize_groups(flat, GROUP)
        chunks.append(_dequantize_groups(q, s, GROUP).reshape(n, -1))
    shards = [sum(c[d] for c in chunks) for d in range(n)]   # shard per dev
    # stage B: re-quantize reduced shards, gather
    out = []
    for shard in shards:
        qr, sr = _quantize_groups(shard, GROUP)
        out.append(_dequantize_groups(qr, sr, GROUP))
    flat = jnp.concatenate(out)
    if pad:
        flat = flat[:flat.size - pad]
    return np.asarray(flat.reshape(stacked.shape[1:]))


def make_pod_sync(mesh, compressed: bool = False, axis: str = "pod",
                  specs=None, mean: bool = False):
    """Cross-pod gradient synchronizer: pytree → pytree, psum over ``axis``.

    This is the *blocking* baseline — one synchronous all-reduce per leaf at
    the point of call (the overlapped, bucketed variant is
    ``repro.train.trainer.make_overlapped_pod_sync``).  Float leaves are
    all-reduced over the pod axis (int8 reduce-scatter + all-gather when
    ``compressed=True``); non-float leaves (step counters, ...) pass
    through.  ``mean=True`` divides by the pod count (cross-pod *data*
    parallelism averages).  Identity when the mesh has no pod axis.

    ``specs`` is an optional pytree of ``PartitionSpec`` (matching the
    gradient tree) describing how leaves are sharded over the non-pod
    axes; supply it for FSDP/TP-sharded gradients so each device syncs
    only its shard.  The default ``P()`` treats leaves as replicated —
    fine for small trees, but it forces a full all-gather of sharded
    gradients first.
    """
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return lambda grads: grads
    op = compressed_psum if compressed else plain_psum
    inv_n = 1.0 / mesh.shape[axis]

    def sync_one(g):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g
        out = op(g, axis)
        return (out * inv_n).astype(g.dtype) if mean else out

    def sync(grads):
        leaves, treedef = jax.tree.flatten(grads)
        in_specs = specs if specs is not None \
            else treedef.unflatten([P()] * len(leaves))
        f = shard_map(lambda tr: jax.tree.map(sync_one, tr), mesh=mesh,
                      in_specs=(in_specs,), out_specs=in_specs,
                      check_rep=False)
        return f(grads)

    return sync
