"""Microbatch pipeline parallelism over a ``"pipe"`` mesh axis.

GPipe-style schedule expressed as an SPMD program: ``shard_map`` splits the
layer-stacked weights over the pipe axis (stage s owns layers
``[s·L/S, (s+1)·L/S)``), microbatches stream through the stages, and
activations move stage→stage with ``lax.ppermute`` on a ring.  The
schedule runs ``M + S - 1`` ticks; at tick ``t`` stage ``s`` processes
microbatch ``t - s`` (bubble ticks compute on zeros and are discarded).
Outputs are collected on the last stage and ``psum``-broadcast so every
device returns the full result.  ``ppermute`` has an exact transpose rule,
so the whole pipeline is differentiable — gradients flow backwards along
the same ring.

Numerics match sequential layer-by-layer execution exactly (no
rematerialization or dtype tricks), which is what ``tests/test_dist.py``
asserts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def make_pipelined_fn(mesh, block, n_stages: int, layers_per_stage: int):
    """Build ``fn(ws, xs) -> ys`` running ``block`` as a pipeline.

    ``block(w, x) -> x`` is one layer; ``ws`` stacks the per-layer weights
    on the leading dim (``n_stages * layers_per_stage`` layers total);
    ``xs`` stacks microbatches on the leading dim.  The per-microbatch
    batch dim (``xs.shape[1]``) additionally shards over the mesh's
    ``"data"`` axis when divisible.
    """
    if "pipe" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'pipe' axis")
    if mesh.shape["pipe"] != n_stages:
        raise ValueError(f"n_stages={n_stages} != pipe axis size "
                         f"{mesh.shape['pipe']}")
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def pipelined(ws, xs):
        if ws.shape[0] != n_stages * layers_per_stage:
            raise ValueError(f"expected {n_stages * layers_per_stage} "
                             f"layers, got {ws.shape[0]}")
        n_micro = xs.shape[0]

        def run(ws_local, xs_local):
            stage = jax.lax.axis_index("pipe")
            state = jnp.zeros(xs_local.shape[1:], xs_local.dtype)
            outputs = jnp.zeros_like(xs_local)
            for t in range(n_micro + n_stages - 1):
                # stage 0 ingests microbatch t; later stages consume the
                # activation ppermuted to them at the end of tick t-1
                mb = xs_local[t] if t < n_micro else jnp.zeros_like(state)
                x_in = jnp.where(stage == 0, mb, state)
                y = x_in
                for i in range(layers_per_stage):
                    y = block(ws_local[i], y)
                out_idx = t - (n_stages - 1)
                if out_idx >= 0:          # last stage emits mb ``out_idx``
                    outputs = outputs.at[out_idx].set(
                        jnp.where(stage == n_stages - 1, y,
                                  outputs[out_idx]))
                state = jax.lax.ppermute(y, "pipe", perm)
            # non-last stages hold zeros -> psum broadcasts the result
            return jax.lax.psum(outputs, "pipe")

        batch_ax = None
        if "data" in mesh.axis_names and xs.ndim >= 2 \
                and dict(mesh.shape)["data"] > 1 \
                and xs.shape[1] % dict(mesh.shape)["data"] == 0:
            batch_ax = "data"
        x_spec = P(None, batch_ax) if xs.ndim >= 2 else P(None)
        return shard_map(run, mesh=mesh, in_specs=(P("pipe"), x_spec),
                         out_specs=x_spec, check_rep=False)(ws, xs)

    return pipelined
