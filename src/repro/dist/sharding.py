"""Logical-axis sharding: process-global mesh registry + rule table.

Model code never names physical mesh axes.  Parameters, caches and
activations are annotated with *logical* axis names ("p_embed", "seq_sp",
"expert_ff", ...) and a :class:`ShardingRules` table maps each logical name
to a physical mesh axis (or a tuple of axes, or ``None`` for replicated).
``logical(*axes, dims=...)`` resolves one annotation tuple into a
``PartitionSpec``; ``shard(x, *axes)`` applies it as a GSPMD sharding
constraint (a no-op when no mesh is registered, so single-device smoke
tests run the exact same model code).

Resolution rules (what makes the table safe to apply blindly):

  * physical axes absent from the current mesh — or of size 1 — are dropped
    (the same model runs on ``("data","model")``, ``("pod","data","model")``
    and ``("pipe","data")`` meshes);
  * a physical axis may appear in at most one dimension of a spec; the
    first (leftmost) logical axis that claims it wins, later claims
    resolve to ``None`` (e.g. MoE expert weights: "p_experts" takes the
    ZeRO "data" axis, so "p_embed" in the same tensor stays local);
  * when ``dims`` is given, a physical axis that does not evenly divide its
    dimension is dropped (reduced smoke configs have e.g. 1 KV head —
    ``device_put`` would reject a 4-way sharding of it).

Defaults implement the standard FSDP("data") × TP("model") layout with an
optional leading "pod" data-parallel axis and sequence-parallel KV caches
("seq_sp" → "model", the flash-decode layout in models.layers).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class ShardingRules(dict):
    """Mapping from logical axis name to physical mesh axis/axes.

    Values are a mesh axis name, a tuple of names (the dimension shards over
    their product, major first), or ``None`` (replicated).  Plain-``dict``
    semantics so call sites can patch with ``{**DEFAULT_RULES, ...}``.
    """

    def physical(self, name):
        if name is None:
            return None
        try:
            return self[name]
        except KeyError:
            raise KeyError(
                f"unknown logical axis {name!r}; known: {sorted(self)}"
            ) from None


DEFAULT_RULES = ShardingRules({
    # ---- parameters --------------------------------------------------------
    "p_layers": None,             # scan-stacked layer dim stays local
    "p_vocab": "model",
    "p_embed": "data",            # FSDP / ZeRO-3 axis
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_ff": "model",
    "p_experts": "data",          # TP-MoE: expert dim ZeRO-shards over data
    "p_experts_ep": "model",      # EP-MoE: experts over model
    "p_expert_ff": "model",
    "p_ssm_inner": "model",       # Mamba2 head parallelism
    # ---- activations / caches ---------------------------------------------
    "batch": ("pod", "data"),
    "seq": None,                  # no SP for training activations
    "seq_sp": "model",            # KV-cache sequence dim (flash-decode SP)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": None,                # replicated between-layer activations
    "ff": "model",
    "vocab": "model",
    "experts": None,              # TP-MoE: token buffer stays data-local
    "experts_ep": "model",        # EP-MoE: the token all-to-all
    "expert_ff": "model",
    "ssm_heads": "model",
})

_MESH = None
_RULES: ShardingRules = DEFAULT_RULES


def set_mesh(mesh, rules: ShardingRules | None = None) -> None:
    """Register the process-global mesh (``None`` disables sharding hints).

    ``rules=None`` resets to :data:`DEFAULT_RULES`; pass
    ``set_mesh(mesh, get_rules())`` to keep a custom table in force.
    """
    global _MESH, _RULES
    _MESH = mesh
    _RULES = ShardingRules(rules) if rules is not None else DEFAULT_RULES


def get_mesh():
    return _MESH


def get_rules() -> ShardingRules:
    return _RULES


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis; 1 when no mesh is set or the axis is absent."""
    mesh = get_mesh()
    if mesh is None:
        return 1
    return int(mesh.shape.get(name, 1))


def logical(*axes, dims=None, rules: ShardingRules | None = None,
            mesh=None) -> P:
    """Resolve a tuple of logical axis names into a ``PartitionSpec``.

    ``dims`` (the tensor shape) enables the divisibility filter; ``rules``
    and ``mesh`` default to the registered globals.
    """
    rules = rules if rules is not None else get_rules()
    mesh = mesh if mesh is not None else get_mesh()
    if dims is not None and len(dims) != len(axes):
        raise ValueError(f"rank mismatch: {len(axes)} logical axes for "
                         f"shape {tuple(dims)}")
    used: set = set()
    spec = []
    for i, name in enumerate(axes):
        phys = rules.physical(name)
        if phys is None:
            spec.append(None)
            continue
        cands = (phys,) if isinstance(phys, str) else tuple(phys)
        kept = []
        prod = 1
        for a in cands:
            if a in used:
                continue
            if mesh is not None:
                size = dict(mesh.shape).get(a)
                if size is None or size == 1:
                    continue
                if dims is not None and dims[i] % (prod * size):
                    continue
                prod *= size
            kept.append(a)
            used.add(a)
        if not kept:
            spec.append(None)
        elif len(kept) == 1:
            spec.append(kept[0])
        else:
            spec.append(tuple(kept))
    return P(*spec)


def shard(x, *axes):
    """Sharding-constraint hint: constrain ``x`` to ``logical(*axes)``.

    No-op when no mesh is registered or when the annotation rank does not
    match ``x`` (callers annotate the common layout; reshaped variants pass
    through unconstrained rather than erroring).
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    if len(axes) != getattr(x, "ndim", -1):
        return x
    spec = logical(*axes, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
