"""Logical-axis sharding: process-global mesh registry + rule table.

Model code never names physical mesh axes.  Parameters, caches and
activations are annotated with *logical* axis names ("p_embed", "seq_sp",
"expert_ff", ...) and a :class:`ShardingRules` table maps each logical name
to a physical mesh axis (or a tuple of axes, or ``None`` for replicated).
``logical(*axes, dims=...)`` resolves one annotation tuple into a
``PartitionSpec``; ``shard(x, *axes)`` applies it as a GSPMD sharding
constraint (a no-op when no mesh is registered, so single-device smoke
tests run the exact same model code).

Resolution rules (what makes the table safe to apply blindly):

  * physical axes absent from the current mesh — or of size 1 — are dropped
    (the same model runs on ``("data","model")``, ``("pod","data","model")``
    and ``("pipe","data")`` meshes);
  * a physical axis may appear in at most one dimension of a spec; the
    first (leftmost) logical axis that claims it wins, later claims
    resolve to ``None`` (e.g. MoE expert weights: "p_experts" takes the
    ZeRO "data" axis, so "p_embed" in the same tensor stays local);
  * when ``dims`` is given, a physical axis that does not evenly divide its
    dimension is dropped (reduced smoke configs have e.g. 1 KV head —
    ``device_put`` would reject a 4-way sharding of it).

Defaults implement the standard FSDP("data") × TP("model") layout with an
optional leading "pod" data-parallel axis and sequence-parallel KV caches
("seq_sp" → "model", the flash-decode layout in models.layers).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class ShardingRules(dict):
    """Mapping from logical axis name to physical mesh axis/axes.

    Values are a mesh axis name, a tuple of names (the dimension shards over
    their product, major first), or ``None`` (replicated).  Plain-``dict``
    semantics so call sites can patch with ``{**DEFAULT_RULES, ...}``.
    """

    def physical(self, name):
        if name is None:
            return None
        try:
            return self[name]
        except KeyError:
            raise KeyError(
                f"unknown logical axis {name!r}; known: {sorted(self)}"
            ) from None


DEFAULT_RULES = ShardingRules({
    # ---- parameters --------------------------------------------------------
    "p_layers": None,             # scan-stacked layer dim stays local
    "p_vocab": "model",
    "p_embed": "data",            # FSDP / ZeRO-3 axis
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_ff": "model",
    "p_experts": "data",          # TP-MoE: expert dim ZeRO-shards over data
    "p_experts_ep": "model",      # EP-MoE: experts over model
    "p_expert_ff": "model",
    "p_ssm_inner": "model",       # Mamba2 head parallelism
    # ---- activations / caches ---------------------------------------------
    "batch": ("pod", "data"),
    "seq": None,                  # no SP for training activations
    "seq_sp": "model",            # KV-cache sequence dim (flash-decode SP)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": None,                # replicated between-layer activations
    "ff": "model",
    "vocab": "model",
    "experts": None,              # TP-MoE: token buffer stays data-local
    "experts_ep": "model",        # EP-MoE: the token all-to-all
    "expert_ff": "model",
    "ssm_heads": "model",
})

_MESH = None
_RULES: ShardingRules = DEFAULT_RULES


def set_mesh(mesh, rules: ShardingRules | None = None) -> None:
    """Register the process-global mesh (``None`` disables sharding hints).

    ``rules=None`` resets to :data:`DEFAULT_RULES`; pass
    ``set_mesh(mesh, get_rules())`` to keep a custom table in force.
    """
    global _MESH, _RULES
    _MESH = mesh
    _RULES = ShardingRules(rules) if rules is not None else DEFAULT_RULES


def get_mesh():
    return _MESH


def get_rules() -> ShardingRules:
    return _RULES


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis; 1 when no mesh is set or the axis is absent."""
    mesh = get_mesh()
    if mesh is None:
        return 1
    return int(mesh.shape.get(name, 1))


def logical(*axes, dims=None, rules: ShardingRules | None = None,
            mesh=None) -> P:
    """Resolve a tuple of logical axis names into a ``PartitionSpec``.

    ``dims`` (the tensor shape) enables the divisibility filter; ``rules``
    and ``mesh`` default to the registered globals.
    """
    rules = rules if rules is not None else get_rules()
    mesh = mesh if mesh is not None else get_mesh()
    if dims is not None and len(dims) != len(axes):
        raise ValueError(f"rank mismatch: {len(axes)} logical axes for "
                         f"shape {tuple(dims)}")
    used: set = set()
    spec = []
    for i, name in enumerate(axes):
        phys = rules.physical(name)
        if phys is None:
            spec.append(None)
            continue
        cands = (phys,) if isinstance(phys, str) else tuple(phys)
        kept = []
        prod = 1
        for a in cands:
            if a in used:
                continue
            if mesh is not None:
                size = dict(mesh.shape).get(a)
                if size is None or size == 1:
                    continue
                if dims is not None and dims[i] % (prod * size):
                    continue
                prod *= size
            kept.append(a)
            used.add(a)
        if not kept:
            spec.append(None)
        elif len(kept) == 1:
            spec.append(kept[0])
        else:
            spec.append(tuple(kept))
    return P(*spec)


# ---------------------------------------------------------------------------
# Static-analysis intent model (consumed by repro.analysis implicit-reshard)
# ---------------------------------------------------------------------------

def axes_of_replica_groups(groups, mesh_axes: dict):
    """Classify a collective's replica groups onto the mesh axes they span.

    ``mesh_axes`` is the *ordered* ``{axis_name: size}`` of the mesh the
    artifact was partitioned for (device id = row-major linearization of the
    mesh coordinates, XLA's convention for ``jax.make_mesh``).  Returns a
    ``frozenset`` of axis names when every group is exactly a sub-grid
    varying over those axes, else ``None`` (groups that do not align to the
    mesh — e.g. hand-written shard_map topologies — cannot be judged
    against the rule table and are skipped by the intent check).
    """
    if not groups or not mesh_axes:
        return None
    names = list(mesh_axes)
    sizes = [int(mesh_axes[n]) for n in names]
    ndev = 1
    for s in sizes:
        ndev *= s
    strides = []
    acc = 1
    for s in reversed(sizes):
        strides.append(acc)
        acc *= s
    strides.reverse()

    def coords(d):
        return tuple((d // strides[i]) % sizes[i] for i in range(len(sizes)))

    varying: set = set()
    for g in groups:
        if any(not isinstance(d, int) or d < 0 or d >= ndev for d in g):
            return None
        cs = [coords(d) for d in g]
        for dim in range(len(sizes)):
            if len({c[dim] for c in cs}) > 1:
                varying.add(dim)
    expect = 1
    for dim in varying:
        expect *= sizes[dim]
    if any(len(g) != expect for g in groups):
        return None         # partial/ragged sub-grid: not a clean axis set
    return frozenset(names[i] for i in varying)


#: opcodes whose job is *data movement between layouts* — the reshard
#: family the implicit-reshard pass audits (reductions are never reshards)
RESHARD_OPCODES = ("all-gather", "all-to-all", "ragged-all-to-all",
                   "collective-permute", "collective-broadcast")


def intended_collectives(rules=None, mesh_axes=None, kind: str = "",
                         mesh=None) -> dict:
    """The reshard traffic the rule table *intends*: a map from reshard
    opcode to the set of mesh-axis sets it may legitimately span.

    Derivation (documented so a lint finding is actionable):

      * **all-gather** — ZeRO/FSDP parameter gathers: axes shared between a
        ``p_*`` parameter rule and the ``batch`` rule (weights sharded over
        a data-parallel axis are gathered before use; TP weight shards are
        never gathered).  Plus the ``batch`` axes themselves (the
        reduce-scatter + all-gather gradient-sync layout), and — for cells
        with KV caches (``kind != "train"``) — the ``seq_sp``
        sequence-parallel cache axes (flash-decode gathers).
      * **all-to-all** / **ragged-all-to-all** — expert-parallel token
        dispatch: axes of the ``experts_ep`` / ``p_experts_ep`` rules.
      * **collective-permute** — pipeline neighbour shifts: the ``pipe``
        axis (plus ``batch`` axes: collective-permute shows up inside
        XLA's all-gather/reduce-scatter lowerings on those axes).
      * **collective-broadcast** — same budget as all-gather.

    Every returned set also admits subsets (a gather over one axis of a
    declared tuple is a partial, still-intended reshard) — that check
    lives in the pass.  Anything else the partitioner inserts is traffic
    the table never asked for: an *implicit reshard*.
    """
    rules = dict(rules if rules is not None else get_rules())
    if mesh_axes is None:
        mesh = mesh if mesh is not None else get_mesh()
        mesh_axes = dict(mesh.shape) if mesh is not None else {}
    present = {a for a, s in mesh_axes.items() if int(s) > 1}

    def axset(val):
        if val is None:
            return frozenset()
        axes = (val,) if isinstance(val, str) else tuple(val)
        return frozenset(a for a in axes if a in present)

    batch_axes = axset(rules.get("batch"))
    gather: set = set()
    if batch_axes:
        gather.add(batch_axes)
    for key, val in rules.items():
        if key.startswith("p_"):
            zero = axset(val) & batch_axes
            if zero:
                gather.add(zero)
    if kind != "train":
        sp = axset(rules.get("seq_sp"))
        if sp:
            gather.add(sp)
    a2a: set = set()
    for key in ("experts_ep", "p_experts_ep"):
        ax = axset(rules.get(key))
        if ax:
            a2a.add(ax)
    permute: set = set()
    if "pipe" in present:
        permute.add(frozenset(("pipe",)))
    if batch_axes:
        permute.add(batch_axes)
    return {
        "all-gather": gather,
        "collective-broadcast": set(gather),
        "all-to-all": a2a,
        "ragged-all-to-all": set(a2a),
        "collective-permute": permute,
    }


def shard(x, *axes):
    """Sharding-constraint hint: constrain ``x`` to ``logical(*axes)``.

    No-op when no mesh is registered or when the annotation rank does not
    match ``x`` (callers annotate the common layout; reshaped variants pass
    through unconstrained rather than erroring).
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    if len(axes) != getattr(x, "ndim", -1):
        return x
    spec = logical(*axes, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
