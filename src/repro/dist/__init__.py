"""``repro.dist`` — the distribution subsystem.

Three orthogonal layers, consumed by models / trainer / serving / dry-run:

``sharding``
    Logical-axis rule table + process-global mesh registry.  Model code
    annotates tensors with logical names ("p_embed", "seq_sp",
    "expert_ff", ...); :data:`~repro.dist.sharding.DEFAULT_RULES` maps them
    to physical mesh axes (FSDP "data" × TP "model", optional "pod"),
    :func:`~repro.dist.sharding.logical` resolves an annotation into a
    ``PartitionSpec`` (dropping absent / size-1 / non-dividing / duplicate
    axes), and :func:`~repro.dist.sharding.shard` applies it as a GSPMD
    constraint — a no-op without a registered mesh.

``collectives``
    int8-compressed + async cross-pod gradient sync: ``quantize_int8`` /
    ``dequantize_int8``, ``plain_psum`` / ``compressed_psum`` (quantized
    reduce-scatter + all-gather, O(1) wire bytes in pod count), the
    bucketed async primitives ``psum_start`` / ``psum_wait``, and
    ``make_pod_sync(mesh, compressed=)`` over the "pod" axis (the blocking
    baseline; the overlapped pipeline lives in
    ``repro.train.trainer.make_overlapped_pod_sync``).

``pipeline``
    GPipe-style microbatch pipeline parallelism over a "pipe" axis
    (``shard_map`` + ``lax.ppermute`` ring; differentiable; numerics match
    sequential execution).
"""

from . import collectives, pipeline, sharding
from .collectives import (PsumHandle, compressed_psum, dequantize_int8,
                          make_pod_sync, plain_psum, psum_start, psum_wait,
                          quantize_int8)
from .pipeline import make_pipelined_fn
from .sharding import (DEFAULT_RULES, ShardingRules, get_mesh, get_rules,
                       logical, mesh_axis_size, set_mesh, shard)

__all__ = [
    "collectives", "pipeline", "sharding",
    "DEFAULT_RULES", "ShardingRules", "get_mesh", "get_rules", "logical",
    "mesh_axis_size", "set_mesh", "shard",
    "quantize_int8", "dequantize_int8", "plain_psum", "compressed_psum",
    "PsumHandle", "psum_start", "psum_wait",
    "make_pod_sync", "make_pipelined_fn",
]
