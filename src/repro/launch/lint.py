"""Static-analysis lint over the configs grid: ``python -m repro.launch.lint``.

Compiles each cell of the grid (reduced smoke configs on a virtual 2x2x2
pod x data x model mesh by default, the full production dry-run grid with
``--full``) and runs the :mod:`repro.analysis` pass suite over every
compiled artifact.  Exit status is the CI gate: non-zero when any
unsuppressed finding at or above ``--fail-on`` severity fires, so a green
baseline stays at **zero unsuppressed findings** and a sharding/overlap
regression turns the job red before it burns hardware.

Seeded-defect self-check (the lint analogue of a mutation test)::

    python -m repro.launch.lint --seed-defect reshard   # must exit non-zero
    python -m repro.launch.lint --seed-defect blocking  # must exit non-zero

``reshard`` patches the rule table to shard between-layer activations over
the tensor axis (every layer boundary then all-gathers activations the
table never intended — implicit-reshard fires); ``blocking`` compiles the
explicit blocking cross-pod gradient sync (exposed-collectives fires where
the bucketed overlap pipeline stays quiet).

Usage:
  python -m repro.launch.lint [--archs qwen3-32b,mamba2-2.7b,dbrx-132b]
  python -m repro.launch.lint --passes 'exposed-collectives:threshold_frac=0.5'
  python -m repro.launch.lint --baseline lint_baseline.json --json out.json
  python -m repro.launch.lint --write-baseline lint_baseline.json
"""

import os
import sys


def _early_devices(argv) -> int:
    """--devices must take effect before jax initializes its backend."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 8


N_DEVICES = _early_devices(sys.argv)
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEVICES}")

import argparse      # noqa: E402
import json          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

import repro.configs as configs                             # noqa: E402
from repro import analysis                                  # noqa: E402
from repro.dist.sharding import (DEFAULT_RULES, ShardingRules,  # noqa: E402
                                 get_rules, set_mesh)
from repro.train import OptConfig, make_train_step, train_shardings  # noqa: E402
from repro.train.trainer import batch_shardings             # noqa: E402

#: one representative per assigned architecture family (dense / ssm / moe)
SMOKE_ARCHS = ("qwen3-32b", "mamba2-2.7b", "dbrx-132b")

#: pass spec calibrated for the reduced smoke grid.  At smoke scale every
#: individual collective looks exposed (there is almost no compute to hide
#: behind), so exposed-collectives gates on the *aggregate* DCI exposure
#: instead: the bucketed overlap pipeline measures <=0.7us across the
#: three archs where the blocking sync measures >=1.3us — the 1us budget
#: sits between them.  dtype-promotion's jaxpr floor is raised above the
#: ~32k-element dequantize upcasts the compressed sync performs on
#: purpose (a real f32 activation leak is megabytes, not kilobytes).
SMOKE_SPEC = ("exposed-collectives:link=dci,threshold_frac=1.1,"
              "total_budget_s=1e-06,"
              "implicit-reshard,"
              "dtype-promotion:min_numel_jaxpr=65536,"
              "peak-memory,host-sync")

#: rule-table patch for ``--seed-defect reshard``: sharding the
#: between-layer activations over the tensor axis forces the partitioner
#: to all-gather them at every layer boundary — traffic the default table
#: never intends, which implicit-reshard must flag
DEFECT_RULES = {"embed": "model"}


def smoke_cell(arch: str, *, overlap_sync=True, rules_patch=None,
               seq: int = 64, batch: int = 8, spec=None, baseline=None,
               label: str = "") -> analysis.Findings:
    """Compile one reduced train cell on the virtual mesh and lint it."""
    cfg = configs.reduced(configs.get(arch))
    opt_cfg = OptConfig()
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = None
    if rules_patch:
        rules = ShardingRules({**DEFAULT_RULES, **rules_patch})
    set_mesh(mesh, rules)
    # compressed 4-bucket sync: at smoke scale this is the schedule where
    # blocking vs overlapped cross-pod sync separate on aggregate DCI
    # exposure (the plain schedule's ratio is too close to 1 to gate on)
    step = make_train_step(cfg, opt_cfg, overlap_sync=overlap_sync,
                           sync_compressed=True, sync_buckets=4)
    p_sh, o_sh, p_shapes, o_shapes = train_shardings(mesh, cfg, opt_cfg)
    specs = {"inputs": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    b_sh = batch_shardings(mesh, specs, include_pod=overlap_sync is None)
    fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
    args = (p_shapes, o_shapes, specs)
    jaxprs = []
    try:
        jaxprs.append((label or arch, fn.trace(*args).jaxpr))
    except Exception:                                       # noqa: BLE001
        pass
    compiled = fn.lower(*args).compile()
    meta = {}
    try:
        mem = compiled.memory_analysis()
        meta["measured_peak_bytes"] = float(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))
    except Exception:                                       # noqa: BLE001
        pass
    return analysis.run_passes(
        compiled.as_text(), spec, baseline=baseline, emit_events=False,
        mesh_axes=dict(mesh.shape), rules=get_rules(), kind="train",
        default_trip=cfg.n_layers, pods=mesh.shape.get("pod", 1),
        n_devices=N_DEVICES, jaxprs=jaxprs, meta=meta,
        label=label or f"{arch}.train.smoke")


def run_grid(archs, *, overlap_sync=True, rules_patch=None, spec=None,
             baseline=None) -> list:
    """[(label, Findings-or-None, error-or-None)] over the smoke grid."""
    out = []
    for arch in archs:
        label = f"{arch}.train.smoke"
        try:
            lint = smoke_cell(arch, overlap_sync=overlap_sync,
                              rules_patch=rules_patch, spec=spec,
                              baseline=baseline, label=label)
            out.append((label, lint, None))
        except Exception:                                   # noqa: BLE001
            out.append((label, None, traceback.format_exc()[-2000:]))
    return out


def run_full_grid(spec=None, baseline=None) -> list:
    """Lint every (arch x shape) production cell via the dry-run compiler.
    Expensive — minutes per cell at 512 virtual devices."""
    from repro.launch import dryrun                         # noqa: PLC0415
    from repro.configs.shapes import SHAPES                 # noqa: PLC0415
    out = []
    for arch in configs.ASSIGNED:
        for shape in SHAPES:
            label = f"{arch}.{shape}"
            try:
                _, lint = dryrun.run_cell(arch, shape, multi_pod=True,
                                          lint_spec=spec,
                                          lint_baseline=baseline)
                out.append((label, lint, None))
            except Exception:                               # noqa: BLE001
                out.append((label, None, traceback.format_exc()[-2000:]))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.lint",
        description="static-analysis lint over the configs grid")
    ap.add_argument("--archs", default=",".join(SMOKE_ARCHS),
                    help="comma list of archs for the smoke grid")
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual host devices (read before jax init)")
    ap.add_argument("--passes", default=None,
                    help="pass spec (default: the full suite) — e.g. "
                         "'exposed-collectives:threshold_frac=0.3,"
                         "peak-memory:budget_frac=0.8'")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON suppressing known-accepted findings")
    ap.add_argument("--write-baseline", default=None,
                    help="write a baseline accepting everything that fired, "
                         "then exit 0 (brownfield adoption)")
    ap.add_argument("--json", default=None,
                    help="write the full findings report to this path")
    ap.add_argument("--fail-on", default="warn",
                    choices=analysis.SEVERITIES,
                    help="exit non-zero on unsuppressed findings at or "
                         "above this severity")
    ap.add_argument("--overlap", default="overlap",
                    choices=("overlap", "blocking", "auto"),
                    help="cross-pod gradient sync variant to compile")
    ap.add_argument("--seed-defect", default=None,
                    choices=("reshard", "blocking"),
                    help="inject a known defect; the run MUST go red "
                         "(CI uses this to prove the lint can fail)")
    ap.add_argument("--full", action="store_true",
                    help="lint the production dry-run grid instead of the "
                         "reduced smoke grid")
    args = ap.parse_args()

    overlap = {"overlap": True, "blocking": False, "auto": None}[args.overlap]
    rules_patch = None
    if args.seed_defect == "reshard":
        rules_patch = dict(DEFECT_RULES)
    elif args.seed_defect == "blocking":
        overlap = False

    if args.full:
        results = run_full_grid(spec=args.passes, baseline=args.baseline)
    else:
        results = run_grid([a.strip() for a in args.archs.split(",")
                            if a.strip()],
                           overlap_sync=overlap, rules_patch=rules_patch,
                           spec=args.passes or SMOKE_SPEC,
                           baseline=args.baseline)

    report = {"cells": [], "errors": {}}
    n_unsup = 0
    worst = None
    for label, lint, err in results:
        if lint is None:
            report["errors"][label] = err
            print(f"[lint] {label}: COMPILE ERROR\n{err}")
            continue
        cell = lint.as_dict()
        report["cells"].append(cell)
        hits = lint.unsuppressed(args.fail_on)
        n_unsup += len(hits)
        sev = lint.max_severity()
        if sev and (worst is None
                    or analysis.severity_rank(sev)
                    > analysis.severity_rank(worst)):
            worst = sev
        print(f"[lint] {label}: {len(lint.findings)} finding(s), "
              f"{len(hits)} unsuppressed >= {args.fail_on} "
              f"(suppressed {cell['n_suppressed']})")
        for f in hits:
            print(f"  [{f.severity}] {f.pass_name}: {f.message}")
            if f.fix_hint:
                print(f"      fix: {f.fix_hint}")
    report["n_unsuppressed"] = n_unsup
    report["max_severity"] = worst
    report["fail_on"] = args.fail_on

    if args.write_baseline:
        merged = analysis.Findings()
        for _, lint, _err in results:
            if lint is not None:
                merged.extend(lint.findings)
        merged.write_baseline(args.write_baseline,
                              reason="accepted by --write-baseline")
        print(f"[lint] baseline written: {args.write_baseline}")
        return 0

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=str)
        print(f"[lint] report written: {args.json}")

    if report["errors"]:
        print(f"[lint] FAIL: {len(report['errors'])} cell(s) failed to "
              f"compile")
        return 2
    if n_unsup:
        print(f"[lint] FAIL: {n_unsup} unsuppressed finding(s) at or above "
              f"{args.fail_on!r}")
        return 1
    print("[lint] OK: zero unsuppressed findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
