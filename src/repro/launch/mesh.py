"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for subprocess distribution tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def n_chips(mesh) -> int:
    return mesh.devices.size
