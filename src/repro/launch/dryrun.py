import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

For each cell this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod);
  2. eval_shape's params/optimizer/caches (no allocation — 1T params OK);
  3. ``jax.jit(step, in_shardings, out_shardings).lower(**input_specs)``
     then ``.compile()`` — sharding mismatches / unsupported collectives
     fail HERE, which is the point of the dry-run;
  4. prints ``memory_analysis()`` / ``cost_analysis()`` and walks the
     compiled HLO with the PASTA hlo module (kernels, collectives ×
     known_trip_count multipliers);
  5. writes results/dryrun/<arch>__<shape>__<mesh>.json for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.configs.shapes import get_shape
import repro.core as pasta
from repro.core.tools import roofline as RL
from repro.dist.sharding import set_mesh
from repro.launch.mesh import make_production_mesh, mesh_name, n_chips
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig
from repro.train import trainer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell (the
    paper-workflow analogue: weak-type-correct, shardable, no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "embed":
        mk = lambda bb, ss: jax.ShapeDtypeStruct(   # noqa: E731
            (bb, ss, cfg.d_model), jnp.bfloat16)
    else:
        mk = lambda bb, ss: jax.ShapeDtypeStruct(   # noqa: E731
            (bb, ss), jnp.int32)
    if shape.kind == "train":
        return {"inputs": mk(b, s),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "prefill":
        return {"inputs": mk(b, s)}
    return {"tokens": mk(b, 1)}          # decode: one new token, cache of s


def _opt_cfg(cfg: ModelConfig) -> OptConfig:
    return OptConfig(moment_dtype=cfg.opt_moment_dtype)


def _sharded_bytes(shapes_tree, shardings_tree) -> int:
    """Exact per-device bytes of a sharded abstract tree."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(shapes_tree),
                        jax.tree.leaves(shardings_tree,
                                        is_leaf=lambda x: isinstance(
                                            x, NamedSharding))):
        n = 1
        for d in leaf.shape:
            n *= d
        shard_n = n
        if isinstance(sh, NamedSharding):
            denom = 1
            for ax in sh.spec:
                if ax is None:
                    continue
                axs = ax if isinstance(ax, tuple) else (ax,)
                for a in axs:
                    denom *= sh.mesh.shape[a]
            shard_n = n // max(denom, 1)
        total += shard_n * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape, mesh, overlap_sync=None):
    """Returns (jitted_fn, kwargs_of_ShapeDtypeStructs, meta).

    ``overlap_sync``: ``None`` keeps the partitioner-implicit pod
    reduction; ``False``/``True`` compile the explicit blocking / bucketed-
    overlap cross-pod sync (batch replicated across pods — see
    :mod:`repro.train.trainer`)."""
    set_mesh(mesh)
    meta = {"microbatches": 1}
    include_pod = overlap_sync is None
    if shape.kind == "train":
        opt_cfg = _opt_cfg(cfg)
        micro = shape.microbatches
        # keep per-microbatch batch divisible by the dp axes
        dp = mesh.shape["data"]
        if include_pod:
            dp *= mesh.shape.get("pod", 1)
        while micro > 1 and (shape.global_batch // micro) % dp:
            micro //= 2
        meta["microbatches"] = micro
        meta["overlap_sync"] = overlap_sync
        step = trainer.make_train_step(cfg, opt_cfg, microbatches=micro,
                                       overlap_sync=overlap_sync)
        p_sh, o_sh, p_shapes, o_shapes = trainer.train_shardings(
            mesh, cfg, opt_cfg)
        specs = input_specs(cfg, shape)
        b_sh = trainer.batch_shardings(mesh, specs,
                                       include_pod=include_pod)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        args = (p_shapes, o_shapes, specs)
        meta["state_bytes_per_device"] = (
            _sharded_bytes(p_shapes, p_sh) + _sharded_bytes(o_shapes, o_sh))
        meta["default_trip"] = cfg.n_layers
        return fn, args, meta
    if shape.kind == "prefill":
        step = trainer.make_prefill_step(cfg)
        p_sh, c_sh, p_shapes, _c = trainer.serve_shardings(
            mesh, cfg, shape.global_batch, shape.seq_len)
        specs = input_specs(cfg, shape)
        b_sh = trainer.batch_shardings(mesh, specs)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh["inputs"]),
                     out_shardings=None)
        args = (p_shapes, specs["inputs"])
        meta["state_bytes_per_device"] = _sharded_bytes(p_shapes, p_sh)
        meta["default_trip"] = cfg.n_layers
        return fn, args, meta
    # decode
    step = trainer.make_decode_step(cfg)
    p_sh, c_sh, p_shapes, c_shapes = trainer.serve_shardings(
        mesh, cfg, shape.global_batch, shape.seq_len)
    specs = input_specs(cfg, shape)
    b_sh = trainer.batch_shardings(mesh, specs)
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh["tokens"]),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    args = (p_shapes, c_shapes, specs["tokens"])
    meta["state_bytes_per_device"] = (
        _sharded_bytes(p_shapes, p_sh) + _sharded_bytes(c_shapes, c_sh))
    meta["default_trip"] = cfg.n_layers
    return fn, args, meta


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_patch: dict | None = None, tag: str = "",
             cfg_overrides: dict | None = None,
             microbatches: int | None = None,
             overlap_sync: bool | None = None,
             lint_spec: str | None = None,
             lint_baseline=None) -> dict:
    import dataclasses
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    if microbatches is not None:
        shape = dataclasses.replace(shape, microbatches=microbatches)
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ({"arch": arch, "shape": shape_name, "status": "skipped",
                 "reason": "pure full-attention arch; 0.5M-token quadratic "
                           "attention out of assigned scope (DESIGN.md §4)"},
                None)
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh)
    if rules_patch:
        from repro.dist.sharding import DEFAULT_RULES, ShardingRules
        rules = ShardingRules({**DEFAULT_RULES, **rules_patch})
        set_mesh(mesh, rules)
    chips = n_chips(mesh)
    t0 = time.time()
    fn, args, meta = build_cell(cfg, shape, mesh, overlap_sync=overlap_sync)
    if isinstance(args, tuple):
        lowered = fn.lower(*args)
    else:
        lowered = fn.lower(**args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # --- analyses ----------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes") if hasattr(mem, k)}
    except Exception as e:                                  # noqa: BLE001
        mem_d = {"error": str(e)}
    try:
        cost = compiled.cost_analysis() or {}
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and k in
                  ("flops", "bytes accessed", "transcendentals")}
    except Exception as e:                                  # noqa: BLE001
        cost_d = {"error": str(e)}

    # the traced jaxpr (pre-lowering) feeds the dtype-promotion lint pass;
    # optional — some step fns may not trace standalone
    jaxprs = []
    try:
        traced = fn.trace(*args) if isinstance(args, tuple) \
            else fn.trace(**args)
        jaxprs.append((f"{arch}.{shape_name}", traced.jaxpr))
    except Exception:                                       # noqa: BLE001
        pass

    # scoped capture: the compiled artifact flows through a per-cell Session
    # (kernel/collective events -> kernel_freq tool), no ambient state;
    # static lint runs inside the same session so findings land as events
    from repro import analysis
    from repro.dist.sharding import get_rules
    text = compiled.as_text()
    with pasta.Session(tools="kernel_freq:top_k=5",
                       name=f"dryrun/{arch}/{shape_name}") as sess:
        stats = sess.capture_compiled(text, label=f"{arch}.{shape_name}",
                                      default_trip=meta["default_trip"])
        lint = analysis.run_passes(
            text, lint_spec, stats=stats, session=sess,
            baseline=lint_baseline,
            mesh_axes=dict(mesh.shape), rules=get_rules(),
            kind=shape.kind, default_trip=meta["default_trip"],
            pods=mesh.shape.get("pod", 1), n_devices=chips,
            jaxprs=jaxprs, label=f"{arch}.{shape_name}")
    kernel_freq = sess.reports()["kernel_freq"].data

    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mf = RL.model_flops(cfg.n_params, n_tokens,
                        training=shape.kind == "train",
                        n_active_params=cfg.n_active_params
                        if cfg.family == "moe" else None)
    rl = RL.roofline(stats.flops, stats.hbm_bytes,
                     stats.total_collective_bytes,
                     model_flops_per_chip=mf / chips)
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name(mesh),
        "chips": chips, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "microbatches": meta["microbatches"],
        "state_bytes_per_device": meta.get("state_bytes_per_device"),
        "memory_analysis": mem_d, "cost_analysis": cost_d,
        "hlo": {
            "flops_per_device": stats.flops,
            "hbm_bytes_per_device": stats.hbm_bytes,
            "collective_bytes_per_device": stats.collective_bytes,
            "collective_total_bytes": stats.total_collective_bytes,
            "collective_wire_bytes_per_device": stats.collective_wire_bytes,
            "collective_wire_total_bytes": stats.total_wire_bytes,
            "exposed_collective_bytes": stats.exposed_collective_bytes,
            "exposed_collective_s": stats.exposed_collective_s,
            "hidden_collective_s": stats.hidden_collective_s,
            "n_kernels": len(stats.kernel_counts),
            "n_collectives": len(stats.collective_instances),
            "top_kernels": kernel_freq["top"],
        },
        "overlap_sync": overlap_sync,
        "lint": lint.summary(),
        "model_flops_total": mf,
        "roofline": rl.as_dict(),
        "tag": tag,
    }
    return out, lint


def _print_lint(lint, min_severity: str = "info") -> None:
    for f in lint.unsuppressed(min_severity):
        print(f"  [{f.severity}] {f.pass_name}: {f.message}")
        if f.fix_hint:
            print(f"      fix: {f.fix_hint}")


def save_cell(out: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"__{out['tag']}" if out.get("tag") else ""
    name = f"{out['arch']}__{out['shape']}__{out.get('mesh', 'skip')}{tag}.json"
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--micro", type=int, default=None,
                    help="override train microbatch count")
    ap.add_argument("--overlap-sync", default="auto",
                    choices=("auto", "blocking", "overlap"),
                    help="cross-pod gradient sync: partitioner-implicit "
                         "(auto), explicit blocking all-reduce, or the "
                         "bucketed psum_start/psum_wait overlap pipeline")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override key=value (perf knobs)")
    ap.add_argument("--lint", action="store_true",
                    help="print static-analysis findings per cell (the "
                         "lint section lands in the JSON either way)")
    ap.add_argument("--lint-spec", default=None,
                    help="pass spec, e.g. "
                         "'exposed-collectives:threshold_frac=0.3,"
                         "peak-memory'")
    ap.add_argument("--lint-baseline", default=None,
                    help="baseline JSON of accepted findings to suppress")
    args = ap.parse_args()

    overrides = {}
    if args.set:
        import dataclasses as _dc
        from repro.models.config import ModelConfig as _MC
        ftypes = {f.name: f.type for f in _dc.fields(_MC)}
        for kv in args.set:
            k, v = kv.split("=", 1)
            t = ftypes.get(k, "str")
            if t in ("bool", bool):
                overrides[k] = v.lower() in ("1", "true", "yes")
            elif t in ("int", int):
                overrides[k] = int(v)
            elif t in ("float", float):
                overrides[k] = float(v)
            else:
                overrides[k] = v

    cells = []
    archs = configs.ASSIGNED if args.arch is None else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.shape is None else [args.shape])
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    for arch, shape in cells:
        tag = f"__{args.tag}" if args.tag else ""
        path = os.path.join(RESULTS_DIR,
                            f"{arch}__{shape}__{mesh_tag}{tag}.json")
        skip_path = os.path.join(RESULTS_DIR, f"{arch}__{shape}__skip{tag}.json")
        if args.skip_existing and (os.path.exists(path)
                                   or os.path.exists(skip_path)):
            print(f"[dryrun] {arch} {shape}: cached")
            continue
        lint = None
        try:
            out, lint = run_cell(
                arch, shape, args.multi_pod, tag=args.tag,
                cfg_overrides=overrides or None,
                microbatches=args.micro,
                overlap_sync={"auto": None, "blocking": False,
                              "overlap": True}[args.overlap_sync],
                lint_spec=args.lint_spec,
                lint_baseline=args.lint_baseline)
        except Exception as e:                              # noqa: BLE001
            out = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                   "status": "error", "error": str(e),
                   "traceback": traceback.format_exc()[-2000:],
                   "tag": args.tag}
        p = save_cell(out)
        if out["status"] == "ok":
            r = out["roofline"]
            lt = out.get("lint", {})
            print(f"[dryrun] {arch} {shape} {out['mesh']}: OK "
                  f"compile={out['compile_s']}s "
                  f"compute={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
                  f"coll={r['collective_s']:.4f}s -> {r['bottleneck']} "
                  f"frac={r['roofline_fraction']:.3f} "
                  f"lint={lt.get('n_unsuppressed', 0)} ({p})")
            if args.lint and lint is not None:
                _print_lint(lint)
        else:
            print(f"[dryrun] {arch} {shape}: {out['status']} "
                  f"{out.get('reason', out.get('error', ''))[:200]}")


if __name__ == "__main__":
    main()
