"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + decode over the unified LM with PASTA instrumentation
(operator events per phase; compiled decode artifact captured at the end).
"""

import argparse
import os
import sys
import time


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--pasta-tools", default="kernel_freq")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np

    import repro.configs as configs
    import repro.core as pasta
    from repro.dist.sharding import set_mesh
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model")) if d * m > 1 else None
    set_mesh(mesh)

    with pasta.Session(tools=args.pasta_tools, name="serve") as session:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        engine = ServeEngine(cfg, params,
                             max_seq=args.prompt_len + args.max_new_tokens,
                             session=session,
                             request_tools=args.pasta_tools)
        rng = np.random.default_rng(args.seed)
        vocab = max(cfg.vocab_size, 2)
        prompts = rng.integers(0, vocab, (args.batch, args.prompt_len),
                               dtype=np.int32)
        if cfg.frontend == "embed":
            prompts = rng.standard_normal(
                (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)

        t0 = time.perf_counter()
        out = engine.generate(prompts, max_new_tokens=args.max_new_tokens,
                              temperature=args.temperature)
        dt = time.perf_counter() - t0
        n_tok = out.shape[0] * out.shape[1]
        print(f"[serve] generated {out.shape} in {dt:.2f}s "
              f"({n_tok / dt:.1f} tok/s)")
        print(f"[serve] sample: {out[0][:12].tolist()}")
        reports = session.reports()
    for name, rep in reports.items():
        short = {k: v for k, v in rep.data.items()
                 if k not in ("series", "top", "by_label")}
        print(f"  {name}: {short}")
    for req in engine.request_reports:
        for name, rep in req.items():
            short = {k: v for k, v in rep.data.items()
                     if k not in ("series", "top", "by_label")}
            print(f"  [{rep.session}] {name}: {short}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
