"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Open-loop request-trace driver over the request-lifecycle ``ServeEngine``:
``--num-requests`` ragged prompts (optionally sharing a ``--shared-prefix``)
arrive as a Poisson process at ``--rate`` req/s (0 = all at once) and are
``submit()``-ed into the continuous-batching scheduler; the loop ticks
``engine.step()`` until the trace drains.  PASTA instrumentation is two-level:
the fleet session carries the registered ``serving`` tool (TTFT/TPOT
percentiles, batch-occupancy timeline, prefix-cache hit rate) plus whatever
``--pasta-tools`` names, and each request's child session carries
``--request-tools``.

Multi-tenant traffic: ``--traffic <preset>`` swaps the uniform Poisson
trace for a ``repro.serve.traffic`` preset (mixed lengths, bursty
arrivals, per-tenant SLO tags), ``--policy`` picks the scheduling policy
(fcfs/priority/edf/fair), and traces are reproducible artifacts —
``--save-trace out.jsonl`` writes the materialized trace,
``--trace-file in.jsonl`` replays one exactly (so two policies can be
compared on the *same* arrivals).

Chaos + fault tolerance: ``--chaos <preset> --chaos-seed N`` arms a
deterministic :class:`repro.serve.faults.FaultPlan` (tick errors,
poisoned requests, NaN logits, stalls, pool pressure, host preemptions);
the engine recovers by blame-and-retry — only blamed requests end
``failed``, innocents are re-queued losslessly.  ``--deadline-s`` stamps
a hard per-request deadline onto every trace request's SLO (status
``timeout`` on expiry).  The JSON summary gains the serving tool's
``health`` section plus a top-level ``request_states`` map, so a chaos
run's outcome is machine-checkable against its fault-free twin.

``--compile-cache <dir>`` turns on the persistent XLA compilation cache
(cold run compiles and populates; warm runs skip XLA) — ``compile_s`` in
the JSON summary shows the cold-vs-warm difference.

``--json <path>`` writes the structured results (per-request + fleet
reports, token throughput, latency/SLO/goodput summaries, trace seed and
policy name) in the same one-dict-per-run contract as the dryrun driver.
"""

import argparse
import json
import os
import sys
import time


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt2")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in req/s "
                         "(0 = submit the whole trace up front)")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length; ragged prompts draw uniformly "
                         "from [prompt-len-min, prompt-len]")
    ap.add_argument("--prompt-len-min", type=int, default=8)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens shared by every prompt (prefix-cache "
                         "reuse workload)")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--prefix-block", type=int, default=16,
                    help="prefix-cache key granularity (tokens); in paged "
                         "mode this is also the KV block size")
    ap.add_argument("--no-paged", action="store_true",
                    help="use the legacy dense (slots, max_seq) KV pool "
                         "instead of the paged block pool")
    ap.add_argument("--block-size", type=int, default=None,
                    help="paged KV block width in tokens "
                         "(default: --prefix-block)")
    ap.add_argument("--n-blocks", type=int, default=None,
                    help="paged pool capacity in blocks (default: per-slot "
                         "parity + 2 sequences of prefix-store headroom)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="per-tick prefill token budget shared across "
                         "mid-prefill requests (bounds decode stalls; "
                         "paged mode only)")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="speculative decode: draft K tokens per active "
                         "slot per tick, verify in one fused forward "
                         "(0 = off)")
    ap.add_argument("--draft", default="ngram",
                    choices=("ngram", "model"),
                    help="draft source: n-gram prompt-lookup self-draft, "
                         "or a draft model (--draft-arch)")
    ap.add_argument("--draft-arch", default=None,
                    help="arch id for --draft model (reduced to match; "
                         "default: the target model itself)")
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "priority", "edf", "fair"),
                    help="scheduling policy: fcfs (default), priority / "
                         "edf (preemptive: evict-and-requeue via the "
                         "prefix store), fair (least-served tenant first)")
    ap.add_argument("--interleave", default="chunked",
                    choices=("chunked", "decode"),
                    help="prefill/decode arbitration per tick: spend the "
                         "chunk budget every tick, or defer prefill while "
                         "any slot can decode (needs --prefill-chunk)")
    ap.add_argument("--traffic", default=None,
                    choices=("two-tenant-bursty",),
                    help="multi-tenant traffic preset from "
                         "repro.serve.traffic (overrides the uniform "
                         "Poisson trace flags)")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="replay a JSONL trace (from --save-trace) "
                         "instead of generating one")
    ap.add_argument("--save-trace", default=None, metavar="PATH",
                    help="write the materialized trace as JSONL for "
                         "exact replay")
    ap.add_argument("--chaos", default=None,
                    choices=("one-poison", "transient", "storm", "pressure"),
                    help="arm a deterministic fault-injection preset "
                         "(repro.serve.faults); recovery is asserted, not "
                         "hoped for")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos preset's fault schedule")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="hard per-request deadline stamped onto every "
                         "trace request's SLO (status 'timeout' on expiry)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory "
                         "(warm runs skip recompiles; see compile_s)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip pre-trace jit warmup (TTFT/TPOT will then "
                         "include compile time)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--pasta-tools", default="serving,kernel_freq")
    ap.add_argument("--request-tools", default="serving")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write structured per-request + fleet results")
    ap.add_argument("--seed", type=int, default=0)
    # deprecated generate()-era spelling, kept for muscle memory
    ap.add_argument("--batch", type=int, default=None,
                    help=argparse.SUPPRESS)
    return ap.parse_args()


def make_trace(args, vocab: int):
    """Ragged prompts (+ optional shared prefix) and Poisson arrival times."""
    import numpy as np
    rng = np.random.default_rng(args.seed)
    lo = min(args.prompt_len_min, args.prompt_len)
    lens = rng.integers(lo, args.prompt_len + 1, args.num_requests)
    prefix = rng.integers(0, vocab, (args.shared_prefix,), dtype=np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, vocab, (int(n),),
                                            dtype=np.int32)])
               for n in lens]
    if args.rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                             args.num_requests))
    else:
        arrivals = np.zeros(args.num_requests)
    return prompts, arrivals


def _short(data: dict) -> dict:
    return {k: v for k, v in data.items()
            if k not in ("series", "top", "by_label", "by_request")}


def main():
    args = _parse()
    if args.batch is not None:
        print("[serve] note: --batch is deprecated; the trace driver uses "
              "--num-requests/--max-slots", file=sys.stderr)
        args.num_requests = args.batch
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np

    if args.compile_cache:
        # persistent XLA compile cache: cold runs populate, warm runs skip
        # XLA entirely (min thresholds zeroed so even the small reduced
        # configs cache — the default 1s floor would skip them)
        cache_dir = os.path.abspath(args.compile_cache)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)

    import dataclasses

    import repro.configs as configs
    import repro.core as pasta
    from repro.dist.sharding import set_mesh
    from repro.models import init_params
    from repro.serve import SamplingParams, ServeEngine, SLOSpec, traffic

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model")) if d * m > 1 else None
    set_mesh(mesh)

    vocab = max(cfg.vocab_size, 2)
    trace_meta = {"seed": args.seed}
    if args.trace_file:
        trace, trace_meta = traffic.load_trace(args.trace_file)
        print(f"[serve] replaying {len(trace)} requests from "
              f"{args.trace_file} (meta={trace_meta})")
    elif args.traffic:
        trace = traffic.PRESETS[args.traffic](vocab, seed=args.seed)
    else:
        prompts, arrivals = make_trace(args, vocab)
        trace = [traffic.TraceRequest(arrival_s=float(a), prompt=p,
                                      max_new_tokens=args.max_new_tokens,
                                      slo=None)
                 for a, p in zip(arrivals, prompts)]
    if args.save_trace:
        traffic.save_trace(args.save_trace, trace, seed=args.seed,
                           meta={"preset": args.traffic,
                                 "arch": args.arch})
        print(f"[serve] wrote trace {args.save_trace}")
    if args.deadline_s is not None:
        # stamp the hard deadline onto every request's SLO (engines cancel
        # with status 'timeout' once it elapses)
        trace = [dataclasses.replace(
                     t, slo=(dataclasses.replace(t.slo,
                                                 deadline_s=args.deadline_s)
                             if t.slo is not None
                             else SLOSpec(deadline_s=args.deadline_s)))
                 for t in trace]
    if args.traffic or args.trace_file:
        max_seq = traffic.max_seq_for(trace)
    else:
        max_seq = args.shared_prefix + args.prompt_len + args.max_new_tokens

    with pasta.Session(tools=args.pasta_tools, name="serve") as session:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        paged = False if args.no_paged else None   # None = family default
        draft_cfg = None
        if args.draft_arch is not None:
            draft_cfg = configs.get(args.draft_arch)
            if args.reduced:
                draft_cfg = configs.reduced(draft_cfg)
        engine = ServeEngine(cfg, params, max_seq=max_seq,
                             max_slots=args.max_slots, session=session,
                             request_tools=args.request_tools or None,
                             prefix_cache=not args.no_prefix_cache,
                             prefix_block=args.prefix_block,
                             paged=paged, block_size=args.block_size,
                             n_blocks=args.n_blocks,
                             prefill_chunk=args.prefill_chunk,
                             spec_decode=args.spec_decode, draft=args.draft,
                             draft_cfg=draft_cfg,
                             policy=args.policy,
                             interleave=args.interleave,
                             rng_seed=args.seed,
                             faults=args.chaos, fault_seed=args.chaos_seed)
        if args.chaos:
            print(f"[serve] chaos armed: preset={args.chaos} "
                  f"seed={args.chaos_seed} "
                  f"({len(engine.faults.specs)} fault specs)")
        compile_s = 0.0
        if not args.no_warmup:
            # compile the steady-state dispatches BEFORE the trace clock
            # starts, so TTFT/TPOT percentiles measure serving latency,
            # not XLA compile time
            wu = engine.warmup(prompt_lens=[len(t.prompt) for t in trace])
            compile_s = wu["compile_s"]
            print(f"[serve] warmup: {len(wu['warmed'])} shapes compiled "
                  f"in {compile_s:.2f}s (excluded from the trace clock)")
        t0 = time.perf_counter()
        pending = [(t.arrival_s, t) for t in trace]
        rids = []
        outputs = {}            # collected at retirement (pruning-safe)
        while pending or engine.has_work:
            now = time.perf_counter() - t0
            while pending and pending[0][0] <= now:
                t = pending.pop(0)[1]
                rids.append(engine.submit(
                    t.prompt,
                    SamplingParams(max_new_tokens=t.max_new_tokens,
                                   temperature=args.temperature),
                    slo=t.slo))
            if engine.has_work:
                for rid in engine.step()["finished"]:
                    outputs[rid] = list(engine.requests[rid].tokens)
            elif pending:
                time.sleep(min(pending[0][0] - now, 0.05))
        dt = time.perf_counter() - t0
        n_tok = sum(len(t) for t in outputs.values())
        print(f"[serve] {len(rids)} requests, {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / dt:.1f} tok/s), max_slots={args.max_slots}, "
              f"policy={args.policy}, rate={args.rate or 'inf'}")
        if engine.preemptions:
            print(f"[serve] preemptions={engine.preemptions} "
                  f"parked_blocks={engine.parked_blocks} "
                  f"recovered_blocks={engine.recovered_blocks} "
                  f"(zero-recompute resume)")
        if engine.spec_k:
            acc = (engine.accepted_tokens / engine.drafted_tokens
                   if engine.drafted_tokens else 0.0)
            print(f"[serve] speculative k={engine.spec_k} "
                  f"({args.draft}): {engine.accepted_tokens}/"
                  f"{engine.drafted_tokens} drafts accepted "
                  f"({acc:.2f}), {engine.decode_steps} verify ticks")
        health = engine.health()
        if args.chaos or args.deadline_s is not None:
            print(f"[serve] health: faults={health['fault_ticks']} "
                  f"retries={health['request_retries']} "
                  f"failed={health['failed']} "
                  f"timeouts={health['timeouts']} "
                  f"isolated={health['isolated_innocents']} "
                  f"degraded_ticks={health['degraded_ticks']}")
        done_rids = [r for r in rids if r in outputs]
        if done_rids:
            print(f"[serve] sample: {outputs[done_rids[0]][:12]}")
        else:
            print("[serve] sample: <no finished requests>")
        try:
            # fleet kernel_freq etc. see the fused decode step's compiled HLO
            import jax.numpy as jnp
            if engine.paged:
                span = engine.pool.blocks_per_seq * engine.pool.block_size
                cache = engine.pool.cache_view(
                    np.full((args.max_slots,), span, np.int32))
            else:
                cache = engine.pool.cache
            if engine.spec_k:
                compiled = engine._verify.lower(
                    params, cache,
                    jnp.zeros((args.max_slots, engine.spec_k + 1),
                              jnp.int32),
                    jnp.asarray(engine._verify_idx)).compile()
            else:
                compiled = engine._decode.lower(
                    params, cache,
                    jnp.zeros((args.max_slots, 1), jnp.int32)).compile()
            session.capture_compiled(compiled, label="serve.decode",
                                     steps=max(engine.decode_steps, 1))
        except Exception as e:                              # noqa: BLE001
            print(f"[serve] decode capture skipped: {e}", file=sys.stderr)
        reports = session.reports()

    serving = reports["serving"].data if "serving" in reports else {}
    for name, rep in reports.items():
        print(f"  {name}: {_short(rep.data)}")
    per_request = []
    for req_reports in engine.request_reports:
        for name, rep in req_reports.items():
            per_request.append({"session": rep.session, "tool": name,
                                "data": rep.data})

    if args.json:
        occ = serving.get("occupancy", {})
        pc = serving.get("prefix_cache", {})
        out = {
            "driver": "serve",
            "arch": args.arch,
            "status": "ok",
            "config": {
                "reduced": args.reduced,
                "num_requests": args.num_requests,
                "rate": args.rate,
                "max_slots": args.max_slots,
                "prompt_len": [args.prompt_len_min, args.prompt_len],
                "shared_prefix": args.shared_prefix,
                "max_new_tokens": args.max_new_tokens,
                "temperature": args.temperature,
                "prefix_cache": not args.no_prefix_cache,
                "paged": engine.paged,
                "block_size": engine.block_size,
                "prefill_chunk": engine.prefill_chunk,
                "spec_decode": engine.spec_k,
                "draft": args.draft if engine.spec_k else None,
                "warmup": not args.no_warmup,
                "seed": args.seed,
                "mesh": args.mesh,
                "policy": args.policy,
                "interleave": args.interleave,
                "traffic": args.traffic,
                "trace_file": args.trace_file,
                "trace_seed": trace_meta.get("seed", args.seed),
                "chaos": args.chaos,
                "chaos_seed": args.chaos_seed,
                "deadline_s": args.deadline_s,
                "compile_cache": args.compile_cache,
            },
            "summary": {
                "wall_s": dt,
                "compile_s": compile_s,
                "generated_tokens": n_tok,
                "tok_per_s": n_tok / dt if dt > 0 else 0.0,
                "ttft_s": serving.get("ttft_s"),
                "tpot_s": serving.get("tpot_s"),
                "queue_s": serving.get("queue_s"),
                "occupancy_mean": occ.get("mean"),
                "occupancy_max": occ.get("max"),
                "decode_steps": serving.get("decode_steps"),
                "prefix_hit_rate": pc.get("hit_rate"),
                "prefix_reused_frac": pc.get("reused_frac"),
                "max_prefill_tokens_per_tick":
                    serving.get("prefill", {}).get("max_tokens_per_tick"),
                "max_prefill_stall_s":
                    serving.get("prefill", {}).get("max_stall_s"),
                "speculative": serving.get("speculative"),
                "bandwidth": serving.get("bandwidth"),
                "pool": engine.pool_stats(),
                "slo": serving.get("slo"),
                "preemption": serving.get("preemption"),
                "tenants": serving.get("tenants"),
                "health": serving.get("health"),
                "engine_health": health,
                "faults": (engine.faults.to_dict()
                           if engine.faults is not None else None),
            },
            "fleet": {name: rep.data for name, rep in reports.items()},
            "requests": per_request,
            "tokens": {int(rid): [int(t) for t in toks]
                       for rid, toks in outputs.items()},
            "request_states": {int(rid): engine.requests[rid].state.value
                               for rid in rids if rid in engine.requests},
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, default=str)
        print(f"[serve] wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
