"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs end-to-end training with the PASTA tool stack attached: AOT-compiled
train step (the compiled artifact feeds the kernel/collective event source),
step-indexed data, elastic checkpoint/restart, straggler watchdog.

``--devices N`` forces N host platform devices (debug meshes on CPU) — it is
parsed and applied to XLA_FLAGS *before* jax is imported.
"""

import argparse
import os
import sys
import time


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt2")
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving tiny config (CPU demo)")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM data×model mesh (e.g. 2x4) or PxDxM "
                         "pod×data×model (e.g. 2x2x2)")
    ap.add_argument("--overlap-sync", default="auto",
                    choices=("auto", "blocking", "overlap"),
                    help="cross-pod gradient sync on a PxDxM mesh: "
                         "partitioner-implicit (auto), explicit blocking "
                         "all-reduce at step end, or the bucketed "
                         "psum_start/psum_wait overlap pipeline")
    ap.add_argument("--sync-compressed", action="store_true",
                    help="int8 quantized reduce-scatter + all-gather for "
                         "the explicit cross-pod sync")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--pasta-tools", default="kernel_freq,timeline",
                    help="tool spec, e.g. 'kernel_freq,timeline'; knobs via "
                         "'name:knob=val'; '' disables")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--data", default="", help="token .bin file (synthetic "
                                               "if empty)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory — "
                         "warm restarts skip the train-step recompile")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np

    if args.compile_cache:
        # persistent XLA compile cache: a restarted run (same config, same
        # mesh) skips the train-step compile entirely — min thresholds
        # zeroed so the small reduced configs cache too
        cache_dir = os.path.abspath(args.compile_cache)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)

    import repro.configs as configs
    import repro.core as pasta
    from repro.dist.sharding import set_mesh
    from repro.train import (OptConfig, make_train_step, train_shardings,
                             DataConfig, make_source, LoopConfig, TrainLoop,
                             checkpoint as ckpt)
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import batch_shardings
    from repro.models import init_params

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)

    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    mesh = jax.make_mesh(dims, axes) if np.prod(dims) > 1 else None
    set_mesh(mesh)
    overlap_sync = {"auto": None, "blocking": False,
                    "overlap": True}[args.overlap_sync]

    with pasta.Session(tools=args.pasta_tools, name="train") as session:
        opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                            moment_dtype=cfg.opt_moment_dtype,
                            warmup_steps=max(2, args.steps // 20))
        step_fn = make_train_step(cfg, opt_cfg,
                                  microbatches=args.microbatches,
                                  overlap_sync=overlap_sync,
                                  sync_compressed=args.sync_compressed)

        key = jax.random.PRNGKey(args.seed)
        with pasta.region("init"):
            params = init_params(key, cfg)
            opt_state = init_opt_state(params, opt_cfg)
        if mesh is not None:
            p_sh, o_sh, _, _ = train_shardings(mesh, cfg, opt_cfg)
            params = jax.device_put(params, p_sh)
            opt_state = jax.device_put(opt_state, o_sh)
            jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
        else:
            jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=args.seed,
                          frontend=cfg.frontend, d_model=cfg.d_model)
        source = make_source(dcfg, args.data or None)

        def place_batch(b):
            return {k: jax.numpy.asarray(v) for k, v in b.items()}

        start = 0
        if args.resume and args.ckpt_dir:
            last = ckpt.latest_step(args.ckpt_dir)
            if last is not None:
                start, state = ckpt.restore(args.ckpt_dir,
                                            {"params": params,
                                             "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                print(f"[train] resumed from step {start}")

        loop = TrainLoop(LoopConfig(total_steps=args.steps,
                                    ckpt_every=args.ckpt_every,
                                    ckpt_dir=args.ckpt_dir,
                                    inject_failure_at=args.inject_failure_at),
                         jitted, source, place_batch)

        def metrics_cb(step, mx):
            print(f"[train] step {step:5d} loss {mx['loss']:.4f} "
                  f"gnorm {mx['grad_norm']:.3f} lr {mx['lr']:.2e} "
                  f"({mx['tokens']:.0f} tok)")

        with pasta.region("train"):
            params, opt_state, step = loop.run(params, opt_state, start,
                                               metrics_cb)

        # post-run: capture the compiled artifact into the event stream
        # (timed: with --compile-cache this is the warm-vs-cold signal)
        example = place_batch(source.batch_at(0))
        t_c = time.perf_counter()
        compiled = jitted.lower(params, opt_state, example).compile()
        compile_s = time.perf_counter() - t_c
        session.capture_compiled(compiled, label="train_step",
                                 default_trip=cfg.n_layers,
                                 steps=step - start)
        reports = session.reports()
    print("[pasta] tool reports:")
    for name, rep in reports.items():
        short = {k: v for k, v in rep.data.items()
                 if k not in ("series", "top", "by_label")}
        print(f"  {name}: {short}")
    if loop.stragglers:
        print(f"[train] straggler steps detected: {loop.stragglers}")
    cached = " (compile cache: " + args.compile_cache + ")" \
        if args.compile_cache else ""
    print(f"[train] train_step compile_s={compile_s:.3f}{cached}")
    print(f"[train] done at step {step}; restarts={loop.restarts}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
