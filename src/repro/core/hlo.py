"""Compiled-HLO walker — PASTA's post-AOT event source and roofline engine.

On GPUs the paper intercepts kernel launches dynamically; on TPU the compiled
XLA artifact is a *static* but exact record of every kernel (top-level HLO
instruction), collective, and loop the device will execute.  This module
parses ``compiled.as_text()`` into a structured module and rolls up:

  * executed-kernel counts          (KERNEL_LAUNCH events, Fig.-7 tool)
  * FLOPs                           (dot/conv + elementwise, ×loop trip counts)
  * HBM traffic                     (fusion-boundary operand+output bytes)
  * collective bytes by opcode      (operand bytes, ×loop trip counts)
  * collective *overlap* accounting (exposed bytes, hidden seconds, wire
    bytes — see below)

Overlap accounting pairs async collectives and credits hidden transfer time:

  * ``*-start`` / ``*-done`` pairs (TPU/GPU async collectives) — the overlap
    window is everything scheduled between the start and its matching done;
    the ``*-done`` carries no payload and is never counted as a kernel.
  * synchronous collectives (XLA:CPU emits these even for split layouts) —
    the *potential* overlap window is everything scheduled between the
    collective and its first real consumer (traced through transparent
    wrappers): exactly the slack an async runtime / latency-hiding scheduler
    exploits, computable from the static schedule.

Window compute time (flops / HBM traffic against the hardware model) hides
up to ``comm_s = bytes / ici_bw`` of the transfer; each collective instance
is stamped with ``exposed_bytes`` (the unhidden remainder), ``hidden_s``,
``overlapped``, and ``wire_bytes`` (an opcode-aware per-device wire model:
ring all-reduce moves ~2× payload, all-gather moves what it *receives*,
etc. — this is what must stay O(1) in pod count for the compressed sync).

XLA's own ``cost_analysis()`` counts ``while`` bodies exactly once (verified
empirically: a 10-iteration scan of a matmul reports the same FLOPs as one
matmul), so scan-over-layers models would be undercounted by ~n_layers.  XLA
annotates ``backend_config={"known_trip_count":{"n":...}}`` on while ops after
optimization; we multiply through the call graph using those counts.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re

from .events import COLLECTIVE_OPCODES

_DTYPE_BITS = {
    "pred": 8, "s2": 2, "u2": 2, "s4": 4, "u4": 4, "s8": 8, "u8": 8,
    "s16": 16, "u16": 16, "s32": 32, "u32": 32, "s64": 64, "u64": 64,
    "f8e4m3fn": 8, "f8e5m2": 8, "f8e4m3b11fnuz": 8, "f8e4m3fnuz": 8,
    "f8e5m2fnuz": 8, "f8e3m4": 8, "f8e4m3": 8, "f8e8m0fnu": 8,
    "bf16": 16, "f16": 16, "f32": 32, "f64": 64, "c64": 64, "c128": 128,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")

# opcodes that move no data / are layout-only at the top level
_FREE_OPCODES = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
}

# elementwise/transcendental opcodes counted as 1 flop per output element
_ARITH_OPCODES = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "sine", "cosine", "tan",
    "power", "atan2", "floor", "ceil", "round-nearest-afz", "sign",
    "remainder", "erf", "select", "clamp", "compare", "and", "or", "xor",
    "not", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}

# structural / data-movement opcodes the rollup handles generically; an
# opcode outside _FREE/_ARITH/these (and not a collective) is still
# processed as a generic kernel but counted under
# ``stats.warnings["unknown-opcode:<op>"]`` so truncated or future-XLA
# dumps degrade visibly instead of silently
_KNOWN_OPCODES = {
    "broadcast", "reshape", "transpose", "slice", "concatenate", "pad",
    "copy", "copy-start", "copy-done", "convert", "reverse", "dot",
    "convolution", "fusion", "reduce", "map", "scatter", "reduce-window",
    "select-and-scatter", "sort", "while", "call", "conditional",
    "custom-call", "rng", "rng-bit-generator", "rng-get-and-update-state",
    "dynamic-slice", "dynamic-update-slice", "gather", "domain",
    "bitcast-convert", "get-dimension-size", "set-dimension-size",
    "cholesky", "triangular-solve", "fft", "clz", "popcnt", "is-finite",
    "real", "imag", "complex", "stochastic-convert", "infeed", "outfeed",
    "send", "recv", "send-done", "recv-done", "async-start",
    "async-update", "async-done", "add-dependency",
} | _FREE_OPCODES | _ARITH_OPCODES


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        bits = _DTYPE_BITS.get(dtype)
        if bits is None or bits == 0:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:           # tolerate truncated dim lists ("2,3,")
                    numel *= int(d)
        total += numel * bits // 8
    return total


def shape_numel(shape_str: str) -> int:
    numel_total = 0
    for _dtype, dims in _SHAPE_RE.findall(shape_str):
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        numel_total += numel
    return numel_total


def _first_shape_dims(shape_str: str) -> list:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    shape: str
    operands: list
    attrs: str
    is_root: bool = False

    # ---- lazy attr helpers -------------------------------------------------
    def called_computations(self) -> list:
        out = []
        for key in ("calls", "body", "condition", "to_apply"):
            m = re.search(rf"{key}=%?([\w\.\-]+)", self.attrs)
            if m:
                out.append(m.group(1))
        m = re.search(r"branch_computations=\{([^}]*)\}", self.attrs)
        if m:
            out += [c.strip().lstrip("%") for c in m.group(1).split(",") if c.strip()]
        m = re.search(r"called_computations=\{([^}]*)\}", self.attrs)
        if m:
            out += [c.strip().lstrip("%") for c in m.group(1).split(",") if c.strip()]
        return out

    def trip_count(self) -> int | None:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', self.attrs)
        return int(m.group(1)) if m else None

    def replica_group_size(self) -> int | None:
        # e.g. replica_groups=[32,16]<=[512] → 16 participants per group
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", self.attrs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([^}]*)\}", self.attrs)
        if m:
            return len(m.group(1).split(","))
        groups = self.replica_groups()      # multi-dim iota (T-form) source
        return len(groups[0]) if groups else None

    def replica_groups(self) -> list | None:
        """Explicit device-id groups, decoding both the literal
        ``{{0,4},{1,5}}`` and the iota ``[4,2]<=[8]T(1,0)`` forms."""
        m = re.search(r"replica_groups=\{\{([^=]*?)\}\}", self.attrs)
        if m:
            return [[int(d) for d in grp.split(",") if d.strip()]
                    for grp in m.group(1).split("},{")]
        m = re.search(
            r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
            self.attrs)
        if m:
            import numpy as _np
            rows, cols = int(m.group(1)), int(m.group(2))
            dims = [int(d) for d in m.group(3).split(",")]
            ids = _np.arange(int(_np.prod(dims))).reshape(dims)
            if m.group(4):
                ids = ids.transpose([int(p) for p in m.group(4).split(",")])
            return ids.reshape(rows, cols).tolist()
        return None

    def out_bytes(self) -> int:
        return shape_bytes(self.shape)


@dataclasses.dataclass
class Computation:
    name: str
    instructions: dict          # name -> Instruction
    order: list                 # instruction names in program order

    def shape_of(self, operand: str) -> str:
        ins = self.instructions.get(operand.lstrip("%"))
        return ins.shape if ins else ""


@dataclasses.dataclass
class HloModule:
    computations: dict          # name -> Computation
    entry: str
    #: param numbers donated via the module's input_output_alias header
    aliased_params: set = dataclasses.field(default_factory=set)
    #: counted parser warnings (malformed lines skipped, never raised)
    parse_warnings: dict = dataclasses.field(default_factory=dict)

    def entry_computation(self) -> Computation:
        return self.computations[self.entry]


#: ``input_output_alias={ {0}: (0, {}, may-alias), ... }`` header entries:
#: capture (output index tuple, parameter number)
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+)")


def _split_balanced(s: str, opener: str = "(", closer: str = ")") -> tuple:
    """Return (inside, rest) for the first balanced paren group in ``s``."""
    depth = 0
    start = None
    for i, ch in enumerate(s):
        if ch == opener:
            if depth == 0:
                start = i
            depth += 1
        elif ch == closer:
            depth -= 1
            if depth == 0:
                return s[start + 1:i], s[i + 1:]
    return "", s


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def parse_hlo(text: str) -> HloModule:
    computations: dict = {}
    entry = None
    cur: Computation | None = None
    aliased: set = set()
    warnings: dict = {}

    def warn(key: str) -> None:
        warnings[key] = warnings.get(key, 0) + 1

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if cur is None and line.lstrip().startswith("HloModule"):
            m = re.search(r"input_output_alias=\{(.*?)\}\s*(?:,|$)",
                          line)
            if m is None:
                m = re.search(r"input_output_alias=\{(.*)", line)
            if m:
                aliased.update(int(p) for p in
                               _ALIAS_ENTRY_RE.findall(m.group(1)))
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and " = " not in line.split("{")[0]:
            cur = Computation(hdr.group(2), {}, [])
            computations[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        is_root, name, rhs = bool(m.group(1)), m.group(2), m.group(3)
        # rhs = SHAPE opcode(operands), attrs
        rhs = rhs.strip()
        if rhs.startswith("("):
            shape, rest = _split_balanced(rhs)
            shape = "(" + shape + ")"
        else:
            sp = rhs.find(" ")
            if sp < 0:                      # truncated line: no opcode part
                warn("malformed-instruction")
                continue
            shape, rest = rhs[:sp], rhs[sp:]
        rest = rest.strip()
        sp = rest.find("(")
        if sp < 0:
            warn("malformed-instruction")
            continue
        opcode = rest[:sp].strip()
        inside, attrs = _split_balanced(rest[sp - 1:] if rest[sp - 1] == "(" else rest)
        operands = []
        depth = 0
        tok = ""
        for ch in inside:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                operands.append(tok.strip())
                tok = ""
            else:
                tok += ch
        if tok.strip():
            operands.append(tok.strip())
        # operand tokens are either plain %names or "<shape> %name" (compiled
        # HLO prints inline operand shapes); keep the name part — dropping the
        # shape here is what lets dot/fusion costs resolve their operand
        # shapes (and hence contraction dims) through while-body computations
        op_names = []
        for o in operands:
            mm = re.match(r"^%?([\w\.\-]+)$", o)
            if mm is None:
                mm = re.search(r"%([\w\.\-]+)\s*$", o)
            op_names.append(mm.group(1) if mm else o)
        ins = Instruction(name, opcode, shape, op_names, attrs.strip(", "),
                          is_root=is_root)
        cur.instructions[name] = ins
        cur.order.append(name)
    if entry is None:
        # fall back: computation named main-ish, else last one
        for cname in computations:
            if "main" in cname:
                entry = cname
        if entry is None and computations:
            entry = list(computations)[-1]
    if not computations:
        warn("empty-module")
    return HloModule(computations, entry, aliased_params=aliased,
                     parse_warnings=warnings)


# --------------------------------------------------------------------------
# rollups
# --------------------------------------------------------------------------

def _base_collective(opcode: str) -> str | None:
    op = opcode[:-6] if opcode.endswith("-start") else opcode
    return op if op in COLLECTIVE_OPCODES else None


def _is_collective_done(opcode: str) -> bool:
    return opcode.endswith("-done") and opcode[:-5] in COLLECTIVE_OPCODES


#: hardware model used for overlap credit when the caller supplies none
#: (kept in sync with repro.core.tools.roofline.V5E, imported lazily to
#: avoid a tools→hlo→tools import cycle at module load)
def _default_hw() -> dict:
    from repro.core.tools.roofline import V5E
    return V5E


def collective_wire_bytes(opcode: str, op_bytes: float, out_bytes: float,
                          group_size: int | None) -> float:
    """Per-device *wire* bytes of one collective — what actually crosses the
    interconnect, unlike the raw operand-bytes proxy.  Ring algorithms:
    all-reduce moves ~2× payload, all-gather / reduce-scatter move the
    shards they receive / retire, all-to-all keeps (N−1)/N of the payload
    on the wire."""
    frac = (group_size - 1) / group_size if group_size else 1.0
    if opcode == "all-gather":
        return max(out_bytes - op_bytes, 0.0)
    if opcode == "reduce-scatter":
        return max(op_bytes - out_bytes, 0.0)
    if opcode == "all-reduce":
        return 2.0 * op_bytes * frac
    if opcode in ("all-to-all", "ragged-all-to-all"):
        return op_bytes * frac
    return float(op_bytes)          # collective-permute / broadcast


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_wire_bytes: dict = dataclasses.field(default_factory=dict)
    collective_instances: list = dataclasses.field(default_factory=list)
    kernel_counts: dict = dataclasses.field(default_factory=dict)
    kernel_meta: dict = dataclasses.field(default_factory=dict)
    hw: dict = dataclasses.field(default_factory=dict)
    #: counted analysis warnings (parser skips, unknown opcodes,
    #: per-instruction visit errors) — populated, never raised
    warnings: dict = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.collective_wire_bytes.values()))

    @property
    def exposed_collective_bytes(self) -> float:
        """Wire bytes NOT hidden behind the overlap windows (exposure is
        priced on the wire model so split collective layouts compare
        fairly with the fused ones they replace)."""
        return float(sum(i["exposed_bytes"] * i["mult"]
                         for i in self.collective_instances))

    @property
    def hidden_collective_s(self) -> float:
        """Seconds of collective time credited as overlapped."""
        return float(sum(i["hidden_s"] * i["mult"]
                         for i in self.collective_instances))

    @property
    def collective_comm_s(self) -> float:
        """Total alpha-beta collective seconds (wire + per-message
        latency, on each collective's link)."""
        return float(sum(i["comm_s"] * i["mult"]
                         for i in self.collective_instances))

    @property
    def exposed_collective_s(self) -> float:
        """Collective seconds NOT hidden behind concurrent work."""
        return float(sum(max(i["comm_s"] - i["hidden_s"], 0.0) * i["mult"]
                         for i in self.collective_instances))


def _dot_flops(comp: Computation, ins: Instruction) -> float:
    out_numel = shape_numel(ins.shape)
    lhs_shape = comp.shape_of(ins.operands[0]) if ins.operands else ""
    lhs_dims = _first_shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    k = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d:
                di = int(d)
                if di < len(lhs_dims):
                    k *= lhs_dims[di]
    return 2.0 * out_numel * k


def _conv_flops(comp: Computation, ins: Instruction) -> float:
    out_numel = shape_numel(ins.shape)
    rhs_shape = comp.shape_of(ins.operands[1]) if len(ins.operands) > 1 else ""
    k = max(1, shape_numel(rhs_shape) // max(1, _first_shape_dims(rhs_shape)[-1]
                                             if _first_shape_dims(rhs_shape) else 1))
    return 2.0 * out_numel * k


def _computation_flops(module: HloModule, comp: Computation, memo: dict) -> float:
    """FLOPs of one execution of ``comp``, recursing into calls (not whiles —
    whiles handled by the walker with their trip counts)."""
    if comp.name in memo:
        return memo[comp.name]
    total = 0.0
    memo[comp.name] = 0.0   # guard cycles
    for iname in comp.order:
        ins = comp.instructions[iname]
        if ins.opcode == "dot":
            total += _dot_flops(comp, ins)
        elif ins.opcode == "convolution":
            total += _conv_flops(comp, ins)
        elif ins.opcode in _ARITH_OPCODES:
            total += shape_numel(ins.shape)
        elif ins.opcode in ("fusion", "call", "map", "reduce", "reduce-window",
                            "scatter", "select-and-scatter", "sort"):
            for c in ins.called_computations():
                sub = module.computations.get(c)
                if sub is not None:
                    total += _computation_flops(module, sub, memo)
        elif ins.opcode == "while":
            # handled by walker; don't count here
            pass
        elif ins.opcode == "conditional":
            branches = [module.computations.get(c)
                        for c in ins.called_computations()]
            branches = [b for b in branches if b is not None]
            if branches:
                total += max(_computation_flops(module, b, memo)
                             for b in branches)
    memo[comp.name] = total
    return total


#: ops that neither move independent data nor block in-place analysis —
#: uses/roots are traced *through* them (XLA:CPU's bf16 legalization wraps
#: everything in convert pairs; on TPU those buffers stay bf16/aliased).
_TRANSPARENT = {"convert", "bitcast", "reshape", "copy"}


def _fusion_io_bytes(module: HloModule, comp: Computation,
                     ins: Instruction) -> tuple:
    """(in_bytes, out_bytes) for a fusion, with slicing-aware accounting:

      * a fused parameter consumed ONLY by dynamic-slice/gather ops (possibly
        through convert/bitcast chains) contributes the sliced bytes, not the
        full operand (scan-stacked weights!);
      * a parameter consumed ONLY as the in-place target (operand 0) of
        dynamic-update-slice contributes nothing (aliased, not read);
      * a dynamic-update-slice root (again through transparent chains)
        writes/reads the update region only.
    """
    subs = [module.computations.get(c) for c in ins.called_computations()]
    sub = next((s for s in subs if s is not None), None)
    if sub is None:
        in_b = sum(shape_bytes(comp.shape_of(o)) for o in ins.operands)
        return in_b, ins.out_bytes()
    param_of: dict = {}
    for iname in sub.order:
        si = sub.instructions[iname]
        if si.opcode == "parameter" and si.operands:
            try:
                param_of[iname] = int(si.operands[0])
            except ValueError:
                pass
    # forward def->use edges
    users: dict = {}
    root_name = None
    for iname in sub.order:
        si = sub.instructions[iname]
        if si.is_root:
            root_name = iname
        for pos, o in enumerate(si.operands):
            users.setdefault(o.lstrip("%"), []).append((si, pos))

    def terminal_uses(name: str, seen=None) -> list:
        seen = seen or set()
        out = []
        for si, pos in users.get(name, ()):
            if si.opcode in _TRANSPARENT:
                if si.name in seen:
                    continue
                seen.add(si.name)
                if si.name == root_name:
                    out.append(("__root__", shape_bytes(si.shape), 0))
                out += terminal_uses(si.name, seen)
            else:
                out.append((si.opcode, shape_bytes(si.shape), pos))
        if name == root_name and not users.get(name):
            out.append(("__root__", 0, 0))
        return out

    in_b = 0
    for pname, idx in param_of.items():
        opnd = ins.operands[idx] if idx < len(ins.operands) else ""
        full = shape_bytes(comp.shape_of(opnd))
        u = terminal_uses(pname)
        if u and all(op in ("dynamic-slice", "gather") for op, _b, _p in u):
            in_b += min(full, sum(b for _op, b, _p in u))
        elif u and all(op == "dynamic-update-slice" and p == 0
                       for op, _b, p in u):
            in_b += 0
        else:
            in_b += full
    # operands without a parsed parameter (defensive): count full
    for idx, opnd in enumerate(ins.operands):
        if idx not in param_of.values():
            in_b += shape_bytes(comp.shape_of(opnd))

    def effective(name: str) -> Instruction | None:
        si = sub.instructions.get(name.lstrip("%"))
        hops = 0
        while si is not None and si.opcode in _TRANSPARENT and si.operands \
                and hops < 16:
            si = sub.instructions.get(si.operands[0].lstrip("%"))
            hops += 1
        return si

    def _out_bytes_of(name: str, declared: int) -> int:
        r = effective(name)
        if r is not None and r.opcode == "dynamic-update-slice" \
                and len(r.operands) > 1:
            upd = sub.shape_of(r.operands[1])
            return 2 * shape_bytes(upd)          # read update + write region
        return declared

    out_b = ins.out_bytes()
    if root_name is not None:
        root = sub.instructions[root_name]
        if root.opcode == "tuple":
            out_b = sum(_out_bytes_of(o, shape_bytes(sub.shape_of(o)))
                        for o in root.operands)
        else:
            out_b = _out_bytes_of(root_name, out_b)
    return in_b, out_b


# ------------------------------------------------------- overlap accounting
def _instr_hbm_bytes(module: HloModule, comp: Computation,
                     ins: Instruction) -> float:
    """HBM traffic of one top-level-style instruction (same rules as the
    kernel rollup), used to price overlap windows."""
    if ins.opcode == "fusion":
        in_b, out_b = _fusion_io_bytes(module, comp, ins)
        return float(in_b + out_b)
    if ins.opcode in ("dynamic-slice", "gather"):
        return 2.0 * ins.out_bytes()
    if ins.opcode == "dynamic-update-slice":
        upd = shape_bytes(comp.shape_of(ins.operands[1])
                          if len(ins.operands) > 1 else "")
        return 2.0 * upd
    return float(sum(shape_bytes(comp.shape_of(o)) for o in ins.operands)
                 + ins.out_bytes())


def _collective_window(comp: Computation, ins: Instruction,
                       pos: dict) -> tuple:
    """``(window_instruction_names, done_name | None)`` for one collective.

    Async ``*-start``: the window spans to the matching ``*-done`` (the
    instruction of the paired opcode consuming the start's value).  Sync
    collective: the window spans to the first real consumer, tracing
    through transparent wrappers (convert/bitcast/reshape/copy and
    get-tuple-element); no consumer in this computation ⇒ empty window
    (conservative — the value escapes and we credit nothing).
    """
    i = pos[ins.name]
    order = comp.order
    if ins.opcode.endswith("-start"):
        done_op = ins.opcode[:-6] + "-done"
        for j in range(i + 1, len(order)):
            cand = comp.instructions[order[j]]
            if cand.opcode == done_op and ins.name in cand.operands:
                return order[i + 1:j], cand.name
        return [], None
    # The value is traced element-precisely through tuples, optimization
    # barriers, and get-tuple-element, so a pipeline pinned with
    # lax.optimization_barrier (the bucketed overlapped sync) resolves to
    # the *true* consumer, not the barrier plumbing.
    alias: dict = {ins.name: None}      # name -> tuple element carrying it
    for j in range(i + 1, len(order)):
        cand = comp.instructions[order[j]]
        hit = next(((o, p) for p, o in enumerate(cand.operands)
                    if o in alias), None)
        if hit is None:
            continue
        src, opos = hit
        elem = alias[src]
        if cand.opcode in _TRANSPARENT and elem is None:
            alias[cand.name] = None
            continue
        if cand.opcode == "tuple" and elem is None:
            alias[cand.name] = opos
            continue
        if cand.opcode == "opt-barrier":
            alias[cand.name] = elem
            continue
        if cand.opcode == "get-tuple-element":
            m = re.search(r"index=(\d+)", cand.attrs)
            k = int(m.group(1)) if m else None
            if elem is None or k is None or k == elem:
                alias[cand.name] = None
            continue                    # wrong element ⇒ not our value
        return order[i + 1:j], None
    return [], None


def _instr_cost(module: HloModule, comp: Computation, ins: Instruction,
                flop_memo: dict) -> tuple:
    """``(flops, hbm_bytes)`` of one instruction's computable work.
    Collectives (and their ``-done`` halves) contend for the interconnect,
    so they contribute nothing; free/transparent ops cost nothing."""
    if ins.opcode in _FREE_OPCODES or ins.opcode in _TRANSPARENT:
        return 0.0, 0.0
    if _base_collective(ins.opcode) is not None \
            or _is_collective_done(ins.opcode):
        return 0.0, 0.0
    wf = 0.0
    if ins.opcode == "while":
        trip = ins.trip_count() or 1
        for c in ins.called_computations():
            sub = module.computations.get(c)
            if sub is not None:
                wf += _computation_flops(module, sub, flop_memo) * trip
        return wf, 0.0
    if ins.opcode == "dot":
        wf = _dot_flops(comp, ins)
    elif ins.opcode == "convolution":
        wf = _conv_flops(comp, ins)
    elif ins.opcode in _ARITH_OPCODES:
        wf = float(shape_numel(ins.shape))
    elif ins.opcode in ("fusion", "call", "map", "reduce", "reduce-window",
                        "scatter", "select-and-scatter", "sort"):
        for c in ins.called_computations():
            sub = module.computations.get(c)
            if sub is not None:
                wf += _computation_flops(module, sub, flop_memo)
    return wf, _instr_hbm_bytes(module, comp, ins)


def _window_cost(module: HloModule, comp: Computation, names,
                 flop_memo: dict) -> tuple:
    """``(flops, hbm_bytes)`` of the computable work inside an overlap
    window."""
    wf = 0.0
    wb = 0.0
    for nm in names:
        f, b = _instr_cost(module, comp, comp.instructions[nm], flop_memo)
        wf += f
        wb += b
    return wf, wb


def _crosses_pods(ins: Instruction, n_devices: int, pods: int) -> bool:
    """Whether any replica group spans two pods (pod = leading mesh axis ⇒
    pod id = device_id // (n_devices // pods))."""
    groups = ins.replica_groups()
    if not groups:
        return False
    per_pod = max(n_devices // pods, 1)
    return any(len({d // per_pod for d in g}) > 1 for g in groups)


def _merged_intervals(*interval_lists) -> list:
    out = sorted(iv for lst in interval_lists for iv in lst)
    merged: list = []
    for b0, b1 in out:
        if merged and b0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], b1))
        else:
            merged.append((b0, b1))
    return merged


def _simulate_async_runtime(module: HloModule, comp: Computation,
                            hw: dict, flop_memo: dict,
                            pods: int | None = None,
                            n_devices: int | None = None) -> dict:
    """Async-runtime overlap model for a *synchronous* schedule.

    XLA:CPU never emits ``*-start``/``*-done`` pairs — every collective is
    scheduled immediately before its consumer, so the committed schedule
    carries zero overlap slack even for layouts (like the bucketed pod-sync
    pipeline) a latency-hiding scheduler would overlap.  This list-schedules
    the computation onto concurrent serial resources — a compute unit
    (per-instruction ``max(flops/peak, hbm/bw)``), the intra-pod ICI link,
    and (when ``pods`` is given) the inter-pod DCI link (each collective
    alpha-beta priced: ``ici_latency + wire/link_bw``) — respecting data
    dependences, backfilling each resource as soon as dependences allow.  A
    collective is hidden wherever its transfer runs concurrently with
    *other-resource* work (compute or the other link); the remainder is
    exposed.  Message aggregation falls out of the alpha term: many small
    collectives pay many latencies.

    Returns ``{collective_name: (hidden_s, dur_s, link)}`` for the
    computation's sync collectives.
    """
    # the simulation is O(V^2) worst case; a computation with no sync
    # collectives (single-device artifacts, the common capture) has
    # nothing to re-derive — skip it entirely
    if not any(_base_collective(ins.opcode) is not None
               and not ins.opcode.endswith("-start")
               for ins in comp.instructions.values()):
        return {}
    alpha = hw.get("ici_latency", 0.0)
    peak = hw.get("peak_flops", 0.0)
    hbm_bw = hw.get("hbm_bw", 0.0)
    bw = {"ici": hw.get("ici_bw", 0.0),
          "dci": hw.get("dci_bw", hw.get("ici_bw", 0.0))}
    finish: dict = {}
    busy: list = []                     # compute intervals, kept sorted
    links: dict = {"ici": [], "dci": []}
    spans: dict = {}                    # name -> (start, end, link)

    def place(intervals: list, ready: float, dur: float) -> tuple:
        """Backfill onto a serial resource: the earliest gap at or after
        ``ready`` that fits ``dur`` (an async runtime issues out of program
        order as soon as dependences allow)."""
        t = ready
        for b0, b1 in intervals:
            if t + dur <= b0:
                break
            t = max(t, b1)
        intervals.append((t, t + dur))
        intervals.sort()
        return t, t + dur

    for iname in comp.order:                # program order is topological
        ins = comp.instructions[iname]
        ready = max((finish.get(o.lstrip("%"), 0.0) for o in ins.operands),
                    default=0.0)
        if _is_collective_done(ins.opcode):
            finish[iname] = ready
            continue
        base = _base_collective(ins.opcode)
        if base is not None and bw["ici"]:
            op_bytes = sum(shape_bytes(comp.shape_of(o))
                           for o in ins.operands) or ins.out_bytes()
            wire = collective_wire_bytes(base, op_bytes, ins.out_bytes(),
                                         ins.replica_group_size())
            lk = ("dci" if pods and n_devices
                  and _crosses_pods(ins, n_devices, pods) else "ici")
            start, end = place(links[lk], ready, alpha + wire / bw[lk])
            finish[iname] = end
            if not ins.opcode.endswith("-start"):
                spans[iname] = (start, end, lk)
            continue
        f, b = _instr_cost(module, comp, ins, flop_memo)
        dur = max(f / peak if peak else 0.0, b / hbm_bw if hbm_bw else 0.0)
        if dur <= 0.0:
            finish[iname] = ready
            continue
        _start, end = place(busy, ready, dur)
        finish[iname] = end

    out: dict = {}
    other = {"ici": "dci", "dci": "ici"}
    merged = {lk: _merged_intervals(busy, links[other[lk]])
              for lk in ("ici", "dci")}
    for name, (s0, s1, lk) in spans.items():
        hidden = 0.0
        for b0, b1 in merged[lk]:
            if b1 <= s0:
                continue
            if b0 >= s1:
                break
            hidden += min(b1, s1) - max(b0, s0)
        out[name] = (hidden, s1 - s0, lk)
    return out


def analyze(module: HloModule, default_trip: int = 1,
            hw: dict | None = None, pods: int | None = None,
            n_devices: int | None = None) -> HloStats:
    """Roll up executed stats from the entry computation.

    ``default_trip`` is used for while loops without a known_trip_count.
    ``hw`` is the hardware model used for overlap credit (defaults to the
    roofline TPU v5e constants).  ``pods``/``n_devices`` classify
    collectives whose replica groups cross a pod boundary onto the slower
    inter-pod DCI link in the overlap model (pod = leading mesh axis).
    """
    if hw is None:
        hw = _default_hw()
    stats = HloStats(hw=dict(hw))

    def warn(key: str, n: int = 1) -> None:
        stats.warnings[key] = stats.warnings.get(key, 0) + n

    for k, v in getattr(module, "parse_warnings", {}).items():
        warn(k, v)
    if not module.computations or module.entry not in module.computations:
        if "empty-module" not in stats.warnings:
            warn("empty-module")
        return stats
    flop_memo: dict = {}
    pos_memo: dict = {}
    window_memo: dict = {}

    def overlap_of(comp: Computation, ins: Instruction,
                   wire: float) -> dict:
        # exposure is priced against *wire* bytes (what actually crosses the
        # link), so a split reduce-scatter + all-gather layout compares
        # apples-to-apples with the single all-reduce it replaces
        key = (comp.name, ins.name)
        if key not in window_memo:
            if comp.name not in pos_memo:
                pos_memo[comp.name] = {n: i for i, n
                                       in enumerate(comp.order)}
            window, done = _collective_window(comp, ins,
                                              pos_memo[comp.name])
            wf, wb = _window_cost(module, comp, window, flop_memo)
            window_memo[key] = (wf, wb, done)
        wf, wb, done = window_memo[key]
        comm_s = (hw.get("ici_latency", 0.0) + wire / hw["ici_bw"]
                  if hw.get("ici_bw") else 0.0)
        hide_s = max(wf / hw["peak_flops"] if hw.get("peak_flops") else 0.0,
                     wb / hw["hbm_bw"] if hw.get("hbm_bw") else 0.0)
        hidden_s = min(comm_s, hide_s)
        exposed = (wire * (1.0 - hidden_s / comm_s)
                   if comm_s > 0 else float(wire))
        return {"window_flops": wf, "window_hbm_bytes": wb,
                "comm_s": comm_s, "link": "ici",
                "hidden_s": hidden_s, "exposed_bytes": exposed,
                "overlapped": hidden_s > 0.0,
                "async": ins.opcode.endswith("-start"), "done": done}

    def visit(comp: Computation, mult: float, top_level: bool):
        for iname in comp.order:
            ins = comp.instructions[iname]
            try:
                visit_one(comp, ins, mult, top_level)
            except Exception:                               # noqa: BLE001
                # a malformed instruction must not sink the whole rollup —
                # skip it, count it, keep walking
                warn(f"instr-error:{ins.opcode}")

    def visit_one(comp: Computation, ins: Instruction, mult: float,
                  top_level: bool):
        if _is_collective_done(ins.opcode):
            return              # paired with its *-start; no payload, free
        base = _base_collective(ins.opcode)
        if base is None and ins.opcode not in _KNOWN_OPCODES:
            warn(f"unknown-opcode:{ins.opcode}")
        if base is not None:
            op_bytes = sum(shape_bytes(comp.shape_of(o)) for o in ins.operands)
            if op_bytes == 0:                 # e.g. unresolved operand
                op_bytes = ins.out_bytes()
            stats.collective_bytes[base] = (
                stats.collective_bytes.get(base, 0.0) + op_bytes * mult)
            group = ins.replica_group_size()
            wire = collective_wire_bytes(base, op_bytes,
                                         ins.out_bytes(), group)
            stats.collective_wire_bytes[base] = (
                stats.collective_wire_bytes.get(base, 0.0) + wire * mult)
            mo = re.search(r'op_name="([^"]*)"', ins.attrs)
            stats.collective_instances.append({
                "opcode": base, "name": ins.name, "bytes": op_bytes,
                "mult": mult, "group_size": group,
                "computation": comp.name, "wire_bytes": wire,
                "op_name": mo.group(1) if mo else "",
                **overlap_of(comp, ins, wire),
            })
        if ins.opcode == "while":
            trip = ins.trip_count() or default_trip
            for c in ins.called_computations():
                sub = module.computations.get(c)
                if sub is not None:
                    visit(sub, mult * trip, top_level)
            return
        if ins.opcode in ("call", "conditional", "async-start"):
            for c in ins.called_computations():
                sub = module.computations.get(c)
                if sub is not None:
                    visit(sub, mult, top_level)
            # fall through to count this op's traffic too (cheap)
        if top_level:
            if ins.opcode not in _FREE_OPCODES and base is None \
                    and ins.opcode not in ("while",):
                stats.kernel_counts[ins.name] = (
                    stats.kernel_counts.get(ins.name, 0) + mult)
                if ins.opcode == "fusion":
                    in_bytes, ob = _fusion_io_bytes(module, comp, ins)
                    stats.hbm_bytes += (in_bytes + ob) * mult
                elif ins.opcode in ("dynamic-slice", "gather"):
                    in_bytes = ins.out_bytes()
                    stats.hbm_bytes += 2 * in_bytes * mult
                elif ins.opcode == "dynamic-update-slice":
                    upd = shape_bytes(comp.shape_of(ins.operands[1])
                                      if len(ins.operands) > 1 else "")
                    in_bytes = upd
                    stats.hbm_bytes += 2 * upd * mult
                else:
                    in_bytes = sum(shape_bytes(comp.shape_of(o))
                                   for o in ins.operands)
                    stats.hbm_bytes += (in_bytes + ins.out_bytes()) * mult
                if ins.name not in stats.kernel_meta:
                    mo = re.search(r'op_name="([^"]*)"', ins.attrs)
                    stats.kernel_meta[ins.name] = {
                        "opcode": ins.opcode,
                        "op_name": mo.group(1) if mo else "",
                        "bytes": in_bytes + ins.out_bytes(),
                    }
            if ins.opcode == "dot":
                stats.flops += _dot_flops(comp, ins) * mult
            elif ins.opcode == "convolution":
                stats.flops += _conv_flops(comp, ins) * mult
            elif ins.opcode in _ARITH_OPCODES:
                stats.flops += shape_numel(ins.shape) * mult
            elif ins.opcode in ("fusion", "reduce", "map", "scatter",
                                "reduce-window", "sort"):
                for c in ins.called_computations():
                    sub = module.computations.get(c)
                    if sub is not None:
                        stats.flops += _computation_flops(
                            module, sub, flop_memo) * mult

    visit(module.entry_computation(), 1.0, True)

    # Synchronous schedules (XLA:CPU) expose no committed overlap windows —
    # re-derive sync collectives' exposure at the entry level from the
    # async-runtime model, keeping explicit *-start/*-done spans where the
    # artifact already committed to an async schedule.
    entry = module.entry_computation()
    try:
        sim = _simulate_async_runtime(module, entry, hw, flop_memo,
                                      pods=pods, n_devices=n_devices)
    except Exception:                                       # noqa: BLE001
        warn("sim-error")
        sim = {}
    for inst in stats.collective_instances:
        if inst["computation"] != entry.name or inst["async"]:
            continue
        hidden, dur, lk = sim.get(inst["name"], (None, None, None))
        if dur is None:
            continue
        inst["hidden_s"] = hidden
        inst["comm_s"] = dur
        inst["link"] = lk
        inst["overlapped"] = hidden > 0.0
        inst["exposed_bytes"] = (inst["wire_bytes"]
                                 * max(0.0, 1.0 - hidden / dur)
                                 if dur > 0 else 0.0)
    return stats


def analyze_text(text: str, default_trip: int = 1, hw: dict | None = None,
                 pods: int | None = None,
                 n_devices: int | None = None) -> HloStats:
    """``parse_hlo`` + ``analyze`` with a no-raise guarantee: a dump the
    parser cannot make sense of yields empty stats with
    ``warnings={"parse-error": 1}`` instead of an exception."""
    try:
        module = parse_hlo(text)
    except Exception:                                       # noqa: BLE001
        stats = HloStats(hw=dict(hw) if hw is not None else _default_hw())
        stats.warnings["parse-error"] = 1
        return stats
    return analyze(module, default_trip=default_trip, hw=hw,
                   pods=pods, n_devices=n_devices)
