"""PASTA-JAX core — the paper's contribution as a composable JAX module.

Public surface (``import repro.core as pasta``):

  * annotations: ``pasta.start / pasta.end / pasta.region`` (paper Listing 1)
  * attachment:  ``pasta.attach()`` (per-process injection analogue)
  * modules:     EventHandler → EventProcessor → tool collection
  * memory:      MemoryPool (caching-allocator model)
  * artifacts:   hlo (compiled-HLO walker), tools.roofline
"""

from .annotate import start, end, region, GridIdFilter, current_region
from .events import (Event, EventBatch, EventKind, EventRing,
                     COLLECTIVE_OPCODES, take_seqs)
from .handler import EventHandler, attach, default_handler
from .pool import MemoryPool, MemoryObject, TensorHandle, CHUNK_ALIGN
from .processor import (EventProcessor, analyze_access_trace,
                        analyze_hotness_trace, analyze_trace_fused)
from . import hlo
from . import tools
from .tools import (PastaTool, KernelFrequencyTool, WorkingSetTool,
                    HotnessTool, MemoryTimelineTool, LocatorTool,
                    RooflineTool, make_tools)
from .tools import offload, roofline

__all__ = [
    "start", "end", "region", "GridIdFilter", "current_region",
    "Event", "EventBatch", "EventKind", "EventRing", "COLLECTIVE_OPCODES",
    "take_seqs", "EventHandler", "attach", "default_handler",
    "MemoryPool", "MemoryObject", "TensorHandle", "CHUNK_ALIGN",
    "EventProcessor", "analyze_access_trace", "analyze_hotness_trace",
    "analyze_trace_fused", "hlo", "tools", "PastaTool",
    "KernelFrequencyTool", "WorkingSetTool", "HotnessTool",
    "MemoryTimelineTool", "LocatorTool", "RooflineTool", "make_tools",
    "offload", "roofline",
]
