"""PASTA-JAX core — the paper's contribution as a composable JAX module.

Public surface (``import repro.core as pasta``):

  * session:     ``pasta.Session`` — the unified facade: scoped attachment,
                 tool registry, structured ``Report``s (paper §III's
                 "unified interface to capture and analyze runtime events")
  * annotations: ``pasta.start / pasta.end / pasta.region`` (paper Listing 1)
                 — route to the innermost active session
  * modules:     EventHandler → EventProcessor → tool collection (owned by a
                 Session; still composable by hand)
  * memory:      MemoryPool (caching-allocator model)
  * artifacts:   hlo (compiled-HLO walker), tools.roofline

Deprecated (shims over the implicit root session): ``pasta.attach()``,
``pasta.default_handler()``, ``pasta.make_tools()``.
"""

from .annotate import start, end, region, GridIdFilter, current_region
from .events import (Event, EventBatch, EventKind, EventRing,
                     COLLECTIVE_OPCODES, take_seqs)
from .handler import EventHandler, attach, default_handler
from .pool import MemoryPool, MemoryObject, TensorHandle, CHUNK_ALIGN
from .processor import (EventProcessor, analyze_access_trace,
                        analyze_hotness_trace, analyze_trace_fused)
from .session import (Session, Report, Reports, active_session,
                      current_session, current_handler, root_session)
from . import hlo
from . import tools
from .tools import (PastaTool, KernelFrequencyTool, WorkingSetTool,
                    HotnessTool, MemoryTimelineTool, LocatorTool,
                    RooflineTool, ServingTool, TOOL_REGISTRY, register,
                    parse_tool_spec,
                    resolve_tools, make_tools)
from .tools import offload, roofline

__all__ = [
    "Session", "Report", "Reports", "active_session", "current_session",
    "current_handler", "root_session",
    "start", "end", "region", "GridIdFilter", "current_region",
    "Event", "EventBatch", "EventKind", "EventRing", "COLLECTIVE_OPCODES",
    "take_seqs", "EventHandler", "attach", "default_handler",
    "MemoryPool", "MemoryObject", "TensorHandle", "CHUNK_ALIGN",
    "EventProcessor", "analyze_access_trace", "analyze_hotness_trace",
    "analyze_trace_fused", "hlo", "tools", "PastaTool",
    "KernelFrequencyTool", "WorkingSetTool", "HotnessTool",
    "MemoryTimelineTool", "LocatorTool", "RooflineTool", "ServingTool",
    "TOOL_REGISTRY",
    "register", "parse_tool_spec", "resolve_tools", "make_tools",
    "offload", "roofline",
]
