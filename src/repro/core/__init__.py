"""PASTA-JAX core — the paper's contribution as a composable JAX module.

Public surface (``import repro.core as pasta``):

  * annotations: ``pasta.start / pasta.end / pasta.region`` (paper Listing 1)
  * attachment:  ``pasta.attach()`` (per-process injection analogue)
  * modules:     EventHandler → EventProcessor → tool collection
  * memory:      MemoryPool (caching-allocator model)
  * artifacts:   hlo (compiled-HLO walker), tools.roofline
"""

from .annotate import start, end, region, GridIdFilter, current_region
from .events import Event, EventKind, COLLECTIVE_OPCODES
from .handler import EventHandler, attach, default_handler
from .pool import MemoryPool, MemoryObject, TensorHandle, CHUNK_ALIGN
from .processor import (EventProcessor, analyze_access_trace,
                        analyze_hotness_trace)
from . import hlo
from . import tools
from .tools import (PastaTool, KernelFrequencyTool, WorkingSetTool,
                    HotnessTool, MemoryTimelineTool, LocatorTool, make_tools)
from .tools import offload, roofline

__all__ = [
    "start", "end", "region", "GridIdFilter", "current_region",
    "Event", "EventKind", "COLLECTIVE_OPCODES",
    "EventHandler", "attach", "default_handler",
    "MemoryPool", "MemoryObject", "TensorHandle", "CHUNK_ALIGN",
    "EventProcessor", "analyze_access_trace", "analyze_hotness_trace",
    "hlo", "tools", "PastaTool", "KernelFrequencyTool", "WorkingSetTool",
    "HotnessTool", "MemoryTimelineTool", "LocatorTool", "make_tools",
    "offload", "roofline",
]
