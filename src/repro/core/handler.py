"""PASTA event handler (paper §III-B).

Abstracts the platform's event sources behind one ``emit``/``subscribe``
surface.  Sources on TPU/JAX:

  * **framework callbacks** — the trainer/server/model code calls
    ``operator_start/operator_end``, the :class:`~repro.core.pool.MemoryPool`
    emits tensor/object memory events, ``pasta.start/end`` emit region events;
  * **compiled-artifact capture** — :func:`EventHandler.capture_compiled`
    walks a compiled XLA executable and emits one aggregated KERNEL_LAUNCH /
    COLLECTIVE event per executed instruction (with per-step multiplicities),
    the static-but-exact TPU analogue of launch interception;
  * **device trace buffers** — instrumented Pallas kernels append access
    records to device-resident buffers, surfaced as TRACE_BUFFER events and
    aggregated on device by the event processor.

Handlers are deliberately tiny: a dict of subscriber lists.  The paper's
low-overhead principle — do almost nothing at event time, aggregate in the
processor (on device where volumes are large).
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Iterable

from .annotate import GridIdFilter, current_region
from .events import Event, EventKind
from . import hlo as hlo_mod


class EventHandler:
    def __init__(self, device: tuple = ()):
        self._subs: dict = collections.defaultdict(list)
        self.enabled = True
        self.device = device
        self.grid_filter = GridIdFilter()
        self._grid_id = 0
        self._step = -1

    # ------------------------------------------------------------ subscribe
    def subscribe(self, fn: Callable[[Event], None],
                  kinds: Iterable = ("*",)) -> None:
        for k in kinds:
            key = k if isinstance(k, str) else k.value
            self._subs[key].append(fn)

    def unsubscribe_all(self) -> None:
        self._subs.clear()

    # ----------------------------------------------------------------- emit
    def emit(self, ev: Event) -> None:
        if not self.enabled:
            return
        if ev.step < 0:
            ev.step = self._step
        if not ev.region:
            ev.region = current_region()
        if not ev.device:
            ev.device = self.device
        for fn in self._subs.get(ev.kind.value, ()):
            fn(ev)
        for fn in self._subs.get("*", ()):
            fn(ev)

    # ------------------------------------------------- framework-side hooks
    def operator_start(self, name: str, **attrs) -> Event:
        ev = Event(EventKind.OPERATOR_START, name=name, attrs=attrs)
        self.emit(ev)
        return ev

    def operator_end(self, name: str, **attrs) -> Event:
        ev = Event(EventKind.OPERATOR_END, name=name, attrs=attrs)
        self.emit(ev)
        return ev

    def step_start(self, step: int) -> None:
        self._step = step
        self.emit(Event(EventKind.STEP_START, name=f"step{step}", step=step))

    def step_end(self, step: int, **attrs) -> None:
        self.emit(Event(EventKind.STEP_END, name=f"step{step}", step=step,
                        attrs=attrs))

    def sync(self, name: str = "sync") -> None:
        self.emit(Event(EventKind.SYNC, name=name))

    def memcpy(self, nbytes: int, direction: str, name: str = "") -> None:
        self.emit(Event(EventKind.MEMCPY, name=name or f"memcpy_{direction}",
                        size=nbytes, attrs={"direction": direction}))

    def trace_buffer(self, records, name: str = "", **attrs) -> None:
        """Surface a device access-record buffer (fine-grained tier)."""
        self.emit(Event(EventKind.TRACE_BUFFER, name=name,
                        attrs={"records": records, **attrs}))

    # ------------------------------------------- compiled-artifact capture
    def capture_compiled(self, compiled, label: str = "",
                         default_trip: int = 1, steps: int = 1,
                         cost_analysis: dict | None = None):
        """Walk a compiled executable (or HLO text) and emit kernel/collective
        events.  Returns the :class:`repro.core.hlo.HloStats` rollup."""
        text = compiled if isinstance(compiled, str) else compiled.as_text()
        t0 = time.perf_counter()
        stats = hlo_mod.analyze_text(text, default_trip=default_trip)
        parse_s = time.perf_counter() - t0
        self.emit(Event(EventKind.COMPILE, name=label,
                        attrs={"parse_s": parse_s,
                               "cost_analysis": cost_analysis or {}}))
        for kname, count in stats.kernel_counts.items():
            gid = self._grid_id
            self._grid_id += 1
            if not self.grid_filter(gid):
                continue
            meta = stats.kernel_meta.get(kname, {})
            self.emit(Event(EventKind.KERNEL_LAUNCH, name=kname,
                            attrs={"count": count * steps, "grid_id": gid,
                                   "label": label,
                                   "op_name": meta.get("op_name", ""),
                                   "bytes": meta.get("bytes", 0)}))
        for inst in stats.collective_instances:
            self.emit(Event(EventKind.COLLECTIVE, name=inst["name"],
                            size=int(inst["bytes"]),
                            attrs={"opcode": inst["opcode"],
                                   "mult": inst["mult"] * steps,
                                   "group_size": inst["group_size"],
                                   "label": label}))
        return stats


_default: EventHandler | None = None


def default_handler() -> EventHandler:
    global _default
    if _default is None:
        _default = EventHandler()
    return _default


def attach(handler: EventHandler | None = None) -> EventHandler:
    """Install ``handler`` as the process-global default (the TPU analogue of
    the paper's per-process LD_PRELOAD injection)."""
    global _default
    _default = handler or EventHandler()
    return _default
