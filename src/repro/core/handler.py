"""PASTA event handler (paper §III-B).

Abstracts the platform's event sources behind one ``emit``/``subscribe``
surface.  Sources on TPU/JAX:

  * **framework callbacks** — the trainer/server/model code calls
    ``operator_start/operator_end``, the :class:`~repro.core.pool.MemoryPool`
    emits tensor/object memory events, ``pasta.start/end`` emit region events;
  * **compiled-artifact capture** — :func:`EventHandler.capture_compiled`
    walks a compiled XLA executable and emits one aggregated KERNEL_LAUNCH /
    COLLECTIVE event per executed instruction (with per-step multiplicities),
    the static-but-exact TPU analogue of launch interception;
  * **device trace buffers** — instrumented Pallas kernels append access
    records to device-resident buffers, surfaced as TRACE_BUFFER events and
    aggregated on device by the event processor.

The dispatch spine is columnar: every emission flows through
:class:`~repro.core.events.EventBatch` dispatch.  ``emit(Event)`` is a thin
compatibility shim that wraps a one-row batch; ``emit_row`` appends to the
SoA ring without constructing an Event; ``emit_batch`` hands a whole
producer-built batch to the subscribers.  With buffering enabled, rows
accumulate in the ring and flush at capacity, at step boundaries, or on an
explicit ``flush()`` — the paper's low-overhead principle: do almost nothing
at event time, aggregate in the processor (on device where volumes are
large).
"""

from __future__ import annotations

import collections
import contextlib
import time
from typing import Callable, Iterable

import numpy as np

from .annotate import GridIdFilter, current_region
from . import events as events_mod
from .events import (Event, EventBatch, EventKind, EventRing, KIND_CODE,
                     KIND_LIST)
from . import hlo as hlo_mod


class EventHandler:
    def __init__(self, device: tuple = (), buffer_capacity: int = 4096,
                 buffered: bool = False):
        self._subs: dict = collections.defaultdict(list)   # scalar fns
        self._batch_subs: list = []                        # batch fns
        self.enabled = True
        self.device = device
        self.grid_filter = GridIdFilter()
        self._grid_id = 0
        self._step = -1
        self.buffer_capacity = buffer_capacity
        self._buffered = buffered
        self._ring = EventRing(buffer_capacity)

    # ------------------------------------------------------------ subscribe
    def subscribe(self, fn: Callable[[Event], None],
                  kinds: Iterable = ("*",)) -> None:
        """Subscribe a scalar per-event callback (compatibility surface)."""
        for k in kinds:
            key = k if isinstance(k, str) else k.value
            self._subs[key].append(fn)

    def subscribe_batch(self, fn: Callable[[EventBatch], None]) -> None:
        """Subscribe a columnar consumer; called once per EventBatch, before
        any scalar subscribers (so normalization lands first)."""
        self._batch_subs.append(fn)

    def unsubscribe(self, fn) -> None:
        """Remove ``fn`` wherever it is subscribed (scalar or batch)."""
        while fn in self._batch_subs:
            self._batch_subs.remove(fn)
        for subs in self._subs.values():
            while fn in subs:
                subs.remove(fn)

    def unsubscribe_all(self) -> None:
        self._subs.clear()
        self._batch_subs.clear()

    # ------------------------------------------------------------ buffering
    @property
    def buffered(self) -> bool:
        return self._buffered

    def set_buffered(self, on: bool) -> None:
        """Toggle ring buffering; disabling flushes pending rows first."""
        if self._buffered and not on:
            self.flush()
        self._buffered = on

    @contextlib.contextmanager
    def buffering(self):
        """Scoped ring buffering: rows batch up inside, flush on exit."""
        prev = self._buffered
        self._buffered = True
        try:
            yield self
        finally:
            self.flush()
            self._buffered = prev

    def flush(self) -> None:
        """Dispatch whatever is pending in the ring as one batch."""
        batch = self._ring.flush()
        if batch is not None:
            self._dispatch(batch)

    # ----------------------------------------------------------------- emit
    def emit(self, ev: Event) -> None:
        """Scalar emit — compatibility shim over the columnar spine: fills
        defaults, then either appends to the ring (buffered) or dispatches a
        one-row batch wrapping this very object."""
        if not self.enabled:
            return
        if ev.step < 0:
            ev.step = self._step
        if not ev.region:
            ev.region = current_region()
        if not ev.device:
            ev.device = self.device
        if self._buffered:
            if self._ring.append(KIND_CODE[ev.kind], ev.name, ev.step,
                                 ev.time, ev.size, ev.addr, ev.seq, ev.attrs,
                                 ev.device, ev.region, event=ev):
                self.flush()
            return
        self._dispatch(EventBatch.from_events((ev,)))

    def emit_row(self, kind: EventKind, name: str = "", step: int = -1,
                 time_: float | None = None, size: int = 0, addr: int = 0,
                 device: tuple | None = None, region: tuple | None = None,
                 attrs: dict | None = None, seq: int | None = None) -> int:
        """Allocation-light emit: appends one row to the ring (or dispatches
        a one-row batch when buffering is off) without constructing an Event.
        Returns the row's sequence number.  Pass a pre-reserved ``seq``
        (:func:`repro.core.events.next_seq`) when the producer must stamp
        its own bookkeeping before subscribers run."""
        if seq is None:
            seq = next(events_mod._seq)
        if not self.enabled:
            return seq
        if step < 0:
            step = self._step
        if time_ is None:
            time_ = time.perf_counter()
        if not device:
            device = self.device
        if region is None:
            region = current_region()
        if self._buffered:
            if self._ring.append(KIND_CODE[kind], name, step, time_, size,
                                 addr, seq, attrs, device, region):
                self.flush()
            return seq
        batch = EventBatch.of(
            kind, names=(name,), steps=(step,), times=(time_,),
            sizes=(size,), addrs=(addr,), seqs=(seq,),
            attrs=None if attrs is None else [attrs],
            device=device, region=region)
        self._dispatch(batch)
        return seq

    def emit_batch(self, batch: EventBatch) -> None:
        """Dispatch a producer-built columnar batch.  Pending ring rows are
        flushed first so cross-path event order is preserved."""
        if not self.enabled:
            return
        if self._buffered:
            self.flush()
        neg = batch.steps < 0
        if neg.any():
            batch.steps = np.where(neg, self._step, batch.steps)
        if isinstance(batch.devices, tuple) and not batch.devices:
            batch.devices = self.device
        if isinstance(batch.regions, tuple) and not batch.regions:
            batch.regions = current_region()
        self._dispatch(batch)

    # ------------------------------------------------------------- dispatch
    def _dispatch(self, batch: EventBatch) -> None:
        for fn in tuple(self._batch_subs):
            fn(batch)
        if not self._subs:
            return
        if len(batch) == 1:
            ev = batch.event(0)
            for fn in self._subs.get(ev.kind.value, ()):
                fn(ev)
            for fn in self._subs.get("*", ()):
                fn(ev)
            return
        star = self._subs.get("*", ())
        if star:
            idx = range(len(batch))
        else:
            wanted = [c for c in np.unique(batch.kinds)
                      if self._subs.get(KIND_LIST[c].value)]
            if not wanted:
                return
            idx = np.nonzero(np.isin(batch.kinds, np.asarray(
                wanted, dtype=np.int16)))[0]
        for i in idx:
            ev = batch.event(int(i))
            for fn in self._subs.get(ev.kind.value, ()):
                fn(ev)
            for fn in star:
                fn(ev)

    # ------------------------------------------------- framework-side hooks
    def operator_start(self, name: str, **attrs) -> Event:
        ev = Event(EventKind.OPERATOR_START, name=name, attrs=attrs)
        self.emit(ev)
        return ev

    def operator_end(self, name: str, **attrs) -> Event:
        ev = Event(EventKind.OPERATOR_END, name=name, attrs=attrs)
        self.emit(ev)
        return ev

    def step_start(self, step: int) -> None:
        """Step edge: a flush boundary for the buffered path."""
        self._step = step
        self.emit_row(EventKind.STEP_START, name=f"step{step}", step=step)
        if self._buffered:
            self.flush()

    def step_end(self, step: int, **attrs) -> None:
        self.emit_row(EventKind.STEP_END, name=f"step{step}", step=step,
                      attrs=attrs)
        if self._buffered:
            self.flush()

    def sync(self, name: str = "sync") -> None:
        self.emit_row(EventKind.SYNC, name=name)

    def memcpy(self, nbytes: int, direction: str, name: str = "") -> None:
        self.emit_row(EventKind.MEMCPY, name=name or f"memcpy_{direction}",
                      size=nbytes, attrs={"direction": direction})

    def trace_buffer(self, records, name: str = "", **attrs) -> None:
        """Surface a device access-record buffer (fine-grained tier).
        Trace rows are rare and HEAVY (raw access records): they bypass the
        ring and dispatch immediately, so the processor reduces them to
        O(#objects) aggregates right away instead of the ring pinning raw
        buffers until the next flush boundary."""
        if self._buffered:
            self.flush()                 # keep cross-row ordering
            self._buffered = False
            try:
                self.emit_row(EventKind.TRACE_BUFFER, name=name,
                              attrs={"records": records, **attrs})
            finally:
                self._buffered = True
            return
        self.emit_row(EventKind.TRACE_BUFFER, name=name,
                      attrs={"records": records, **attrs})

    # ------------------------------------------- compiled-artifact capture
    def capture_compiled(self, compiled, label: str = "",
                         default_trip: int = 1, steps: int = 1,
                         cost_analysis: dict | None = None):
        """Walk a compiled executable (or HLO text) and emit kernel/collective
        events.  Returns the :class:`repro.core.hlo.HloStats` rollup."""
        text = compiled if isinstance(compiled, str) else compiled.as_text()
        t0 = time.perf_counter()
        stats = hlo_mod.analyze_text(text, default_trip=default_trip)
        parse_s = time.perf_counter() - t0
        self.emit(Event(EventKind.COMPILE, name=label,
                        attrs={"parse_s": parse_s,
                               "cost_analysis": cost_analysis or {}}))
        for kname, count in stats.kernel_counts.items():
            gid = self._grid_id
            self._grid_id += 1
            if not self.grid_filter(gid):
                continue
            meta = stats.kernel_meta.get(kname, {})
            self.emit(Event(EventKind.KERNEL_LAUNCH, name=kname,
                            attrs={"count": count * steps, "grid_id": gid,
                                   "label": label,
                                   "op_name": meta.get("op_name", ""),
                                   "bytes": meta.get("bytes", 0)}))
        for inst in stats.collective_instances:
            self.emit(Event(EventKind.COLLECTIVE, name=inst["name"],
                            size=int(inst["bytes"]),
                            attrs={"opcode": inst["opcode"],
                                   "mult": inst["mult"] * steps,
                                   "group_size": inst["group_size"],
                                   "label": label,
                                   "overlapped": inst["overlapped"],
                                   "exposed_bytes": inst["exposed_bytes"],
                                   "hidden_s": inst["hidden_s"],
                                   "wire_bytes": inst["wire_bytes"]}))
        return stats


# ---------------------------------------------------------------------------
# Deprecated process-global surface — thin shims over the implicit root
# session (see repro.core.session).  New code uses pasta.Session /
# repro.core.session.current_handler().
# ---------------------------------------------------------------------------

def default_handler() -> EventHandler:
    """Deprecated: the old process-global handler accessor.  Now resolves
    the *current session's* handler (innermost active session, falling back
    to the implicit root session)."""
    import warnings
    warnings.warn(
        "pasta.default_handler() is deprecated; use pasta.Session (scoped "
        "pipelines) or repro.core.session.current_handler()",
        DeprecationWarning, stacklevel=2)
    from .session import current_handler
    return current_handler()


def attach(handler: EventHandler | None = None) -> EventHandler:
    """Deprecated: install ``handler`` as the process-global default (the
    TPU analogue of the paper's per-process LD_PRELOAD injection).  Now
    replaces the implicit root session's handler; scoped ``with
    pasta.Session(...)`` blocks are the supported interface."""
    import warnings
    warnings.warn(
        "pasta.attach() is deprecated; use `with pasta.Session(...)` — "
        "scoped sessions replace the process-global handler",
        DeprecationWarning, stacklevel=2)
    from .session import _attach_root
    return _attach_root(handler)
