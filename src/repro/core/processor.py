"""PASTA event processor (paper §III-B) — normalize, preprocess, dispatch.

Two trace-analysis execution models, mirroring the paper's Fig. 2:

  * **host-resident** (Fig. 2a, the conventional baseline): raw access
    records are copied to the host and folded one-by-one by a single Python
    thread — the model used by Compute-Sanitizer-MemoryTracker / NVBit
    MemTrace style tools.  Kept as the overhead-comparison baseline.
  * **device-resident** (Fig. 2b, PASTA's contribution): records are reduced
    *where they were produced* by vectorized device code — the Pallas TPU
    kernels in :mod:`repro.kernels` (with an XLA fallback off-TPU) — and only
    O(#objects) aggregates are transferred.  When both per-object counts and
    the hotness map are requested, the fused ``trace_aggregate`` kernel
    produces both in a single stream over the trace (one device round-trip).

The coarse-grained tier is columnar end-to-end: the processor subscribes a
*batch* callback, ``normalize_batch`` fixes cross-backend inconsistencies
with masked vector ops (the paper's example: deallocation sizes reported as
negative deltas), and tools consume whole batches through their ``on_batch``
template method.
"""

from __future__ import annotations

import bisect
import time

import numpy as np

from .events import (Event, EventBatch, EventKind, KIND_CODE, _SIGNED_CODES,
                     _SIGNED_SIZE_KINDS)
from .handler import EventHandler

_KC_KERNEL = int(KIND_CODE[EventKind.KERNEL_LAUNCH])
_KC_TRACE = int(KIND_CODE[EventKind.TRACE_BUFFER])


class EventProcessor:
    def __init__(self, handler: EventHandler | None = None, tools=(),
                 device_analysis: bool = True, hotness: dict | None = None):
        """``hotness``: optional {"base","n_blocks","n_tbins","t_max"} — when
        set, trace buffers are additionally reduced to time×block hotness
        maps (Fig. 13) alongside per-object counts."""
        if handler is None:
            from .session import current_handler
            handler = current_handler()
        self.handler = handler
        self.tools = list(tools)
        self.device_analysis = device_analysis
        self.hotness = hotness
        self.closed = False
        self.handler.subscribe_batch(self._on_batch)
        for t in self.tools:
            t.processor = self

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Detach from the handler (undo the ``__init__`` subscription).
        Without this, constructing two processors against the process-global
        handler double-dispatches every event."""
        if not self.closed:
            self.handler.unsubscribe(self._on_batch)
            self.closed = True

    def __enter__(self) -> "EventProcessor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ normalize
    @staticmethod
    def normalize(ev: Event) -> Event:
        """Scalar normalization (compatibility path for direct callers)."""
        if ev.normalized:
            return ev
        # sign conventions: some runtimes report frees as negative deltas
        if ev.kind in _SIGNED_SIZE_KINDS and ev.size < 0:
            ev.size = -ev.size
        # kernel-launch metadata extraction (grid config normalization)
        if ev.kind is EventKind.KERNEL_LAUNCH and "count" not in ev.attrs:
            ev.attrs["count"] = 1
        if ev.kind is EventKind.MEMCPY:
            ev.attrs.setdefault("direction", "d2d")
        ev.normalized = True
        return ev

    @staticmethod
    def normalize_batch(batch: EventBatch) -> EventBatch:
        """Vectorized normalization over a columnar batch: masked negation
        for the signed-size kinds and a materialized ``counts`` column for
        kernel launches.  Fully columnar — one ``attr_column`` gather
        instead of per-row attrs loops (this sits on the hot dispatch path
        for every batch that carries attrs); default attrs (``count``,
        memcpy ``direction``) are supplied by :meth:`EventBatch.event` at
        scalar materialization rather than written back per row."""
        if batch.normalized:
            return batch
        kinds = batch.kinds
        signed = np.isin(kinds, _SIGNED_CODES)
        if signed.any():
            batch.sizes = np.where(signed & (batch.sizes < 0),
                                   -batch.sizes, batch.sizes)
        counts = np.ones(len(batch), dtype=np.int64)
        kidx = np.nonzero(kinds == _KC_KERNEL)[0]
        if kidx.size and batch.attrs is not None:
            counts[kidx] = batch.attr_column("count", 1, rows=kidx,
                                             dtype=np.int64)
        batch.counts = counts
        batch.normalized = True
        return batch

    # -------------------------------------------------------------- dispatch
    def _on_batch(self, batch: EventBatch) -> None:
        if len(batch) == 1:
            # scalar fast path: one-row batches (the ``emit`` compat shim)
            # skip the vectorized machinery and use the per-event hooks —
            # the golden equivalence tests pin both paths to the same output
            ev = batch.event(0)
            self.normalize(ev)
            batch.sizes[0] = ev.size
            # keep the columnar view consistent with normalize_batch: batch
            # consumers must see the counts column on normalized batches
            batch.counts = np.asarray([int(ev.attrs.get("count", 1))],
                                      dtype=np.int64)
            batch.normalized = True
            if ev.kind is EventKind.TRACE_BUFFER:
                self._preprocess_trace(ev)
            for tool in self.tools:
                if tool.wants(ev.kind):
                    tool.on_event(ev)
            return
        self.normalize_batch(batch)
        tmask = batch.kinds == _KC_TRACE
        if tmask.any():
            for i in np.nonzero(tmask)[0]:
                self._preprocess_trace(batch.event(int(i)))
        if not self.tools:
            return
        present = batch.present_kinds()
        for tool in self.tools:
            if any(tool.wants(k) for k in present):
                tool.on_batch(batch)

    def _on_event(self, ev: Event) -> None:
        """Scalar compatibility shim — wraps a one-row batch."""
        self._on_batch(EventBatch.from_events((ev,)))

    def add_tool(self, tool) -> None:
        tool.processor = self
        self.tools.append(tool)

    def finalize(self) -> dict:
        self.handler.flush()
        return {type(t).__name__: t.finalize() for t in self.tools}

    # ------------------------------------------------------- trace analysis
    def _preprocess_trace(self, ev: Event) -> None:
        """Aggregate a raw access-record buffer; attach the aggregate to the
        event so tools see small, structured data (never raw records)."""
        records = ev.attrs.get("records")
        objects = ev.attrs.get("objects")
        if records is None:
            return
        mode = "device" if self.device_analysis else "host"
        elapsed = 0.0
        hp = self.hotness
        fusable = False
        if objects is not None and hp is not None and mode == "device":
            from repro.kernels import ops as kops
            fusable = kops.can_fuse(len(objects), hp["n_blocks"],
                                    hp["n_tbins"])
        if fusable:
            # fused path: per-object counts AND the hotness map in one
            # device round-trip over the shared trace stream
            t = ev.attrs.get("time", 0.0)
            times = np.full(len(records), t)
            counts, hot, elapsed = analyze_trace_fused(
                records, times, objects, hp["base"], hp["n_blocks"],
                hp["n_tbins"], hp["t_max"],
                block_shift=hp.get("block_shift"))
            ev.attrs["object_counts"] = counts
            ev.attrs["hotness_map"] = hot
        else:
            if objects is not None:
                counts, elapsed = analyze_access_trace(records, objects,
                                                       mode=mode)
                ev.attrs["object_counts"] = counts
            if hp is not None:
                t = ev.attrs.get("time", 0.0)
                times = np.full(len(records), t)
                hot, el2 = analyze_hotness_trace(
                    records, times, hp["base"], hp["n_blocks"],
                    hp["n_tbins"], hp["t_max"], mode=mode,
                    block_shift=hp.get("block_shift"))
                ev.attrs["hotness_map"] = hot
                elapsed += el2
        ev.attrs["analysis_s"] = elapsed
        ev.attrs["analysis_mode"] = mode
        ev.attrs.pop("records", None)   # aggregates only past this point


# ---------------------------------------------------------------------------
# Trace-analysis execution models
# ---------------------------------------------------------------------------

def analyze_access_trace(addrs, objects, mode: str = "device"):
    """Fold raw access records into per-object access counts.

    ``addrs``: int64 array of accessed byte addresses (one record per access).
    ``objects``: list of (start, end) half-open address ranges, sorted.
    Returns ``(counts ndarray[len(objects)], elapsed_seconds)``.
    """
    starts = np.asarray([o[0] for o in objects], dtype=np.int64)
    ends = np.asarray([o[1] for o in objects], dtype=np.int64)
    t0 = time.perf_counter()
    if mode == "host":
        counts = _host_analyze(addrs, starts, ends)
    elif mode == "device":
        from repro.kernels import ops as kops
        counts = np.asarray(kops.object_histogram(np.asarray(addrs), starts,
                                                  ends))
    else:
        raise ValueError(f"unknown analysis mode {mode!r}")
    return counts, time.perf_counter() - t0


def _host_analyze(addrs, starts, ends) -> np.ndarray:
    """Fig. 2a baseline: one host thread, one record at a time."""
    counts = np.zeros(len(starts), dtype=np.int64)
    starts_l = starts.tolist()
    ends_l = ends.tolist()
    for a in np.asarray(addrs).tolist():
        i = bisect.bisect_right(starts_l, a) - 1
        if i >= 0 and a < ends_l[i]:
            counts[i] += 1
    return counts


def analyze_hotness_trace(addrs, times, base_addr: int, n_blocks: int,
                          n_tbins: int, t_max: float, mode: str = "device",
                          block_shift: int | None = None):
    """Fold (addr, time) records into a [time_bin, block] hotness map
    (default block = 2 MiB, the UVM page-group granularity)."""
    from repro.kernels import ops as kops
    if block_shift is None:
        block_shift = kops.BLOCK_SHIFT
    t0 = time.perf_counter()
    if mode == "host":
        hot = np.zeros((n_tbins, n_blocks), dtype=np.int64)
        block = 512 << block_shift
        for a, t in zip(np.asarray(addrs).tolist(), np.asarray(times).tolist()):
            b = (a - base_addr) // block
            tb = min(int(t / t_max * n_tbins), n_tbins - 1)
            if 0 <= b < n_blocks:
                hot[tb, b] += 1
    else:
        hot = np.asarray(kops.hotness_histogram(
            np.asarray(addrs), np.asarray(times), base_addr, n_blocks,
            n_tbins, t_max, block_shift=block_shift))
    return hot, time.perf_counter() - t0


def analyze_trace_fused(addrs, times, objects, base_addr: int, n_blocks: int,
                        n_tbins: int, t_max: float,
                        block_shift: int | None = None):
    """Fused device-resident reduction: per-object counts and the
    [time_bin, block] hotness map from ONE pass over the trace (the
    ``trace_aggregate`` kernel — shared addr tiles, two accumulators).
    Returns ``(counts, hotness, elapsed_seconds)``."""
    from repro.kernels import ops as kops
    if block_shift is None:
        block_shift = kops.BLOCK_SHIFT
    starts = np.asarray([o[0] for o in objects], dtype=np.int64)
    ends = np.asarray([o[1] for o in objects], dtype=np.int64)
    t0 = time.perf_counter()
    counts, hot = kops.trace_aggregate(
        np.asarray(addrs), np.asarray(times), starts, ends, base_addr,
        n_blocks, n_tbins, t_max, block_shift=block_shift)
    return counts, hot, time.perf_counter() - t0
