"""Unified PASTA session facade — scoped attach, tool registry, reports.

The paper promises "a unified interface to capture and analyze runtime
events at multiple levels"; :class:`Session` is that interface.  One object
owns the whole pipeline — ``EventHandler`` → ``EventProcessor`` → tool
collection — plus its event sources (the :class:`EagerInstrumenter`
framework hooks, the virtual :class:`MemoryPool`, compiled-artifact
capture)::

    with pasta.Session(tools="kernel_freq,timeline", buffered=True) as s:
        run_workload()
        s.capture_compiled(compiled, label="train_step")
    for name, report in s.reports().items():
        print(name, report.data)

Attachment is *scoped*, not ambient: the innermost active session is carried
in a :mod:`contextvars` variable, so nested sessions compose (region events
route to the innermost scope) and concurrent sessions — per test, per
serving request, per thread — stay fully isolated.  ``pasta.region`` /
``pasta.start`` / ``pasta.end`` and a handler-less :class:`MemoryPool`
resolve the current session dynamically at emit time.

The old process-global surface survives as thin deprecation shims over an
implicit *root* session: ``pasta.attach()`` replaces the root session's
handler, ``pasta.default_handler()`` returns the current session's handler.
New code should never need either.
"""

from __future__ import annotations

import contextvars
import dataclasses
import itertools as _itertools
import json
from typing import Iterator, Mapping

import numpy as np

from .handler import EventHandler
from .processor import EventProcessor
from .tools.base import resolve_tools


# ---------------------------------------------------------------------------
# Structured reports
# ---------------------------------------------------------------------------

def _json_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, set):
        return sorted(o)
    return str(o)


@dataclasses.dataclass
class Report:
    """One tool's ``finalize()`` result, keyed by registry name.

    ``tool`` is the registry key (``"kernel_freq"``), ``tool_class`` the
    implementing class name, ``session`` the owning session's name, and
    ``data`` the tool's report dict.  Mapping-style access delegates to
    ``data`` so existing ``report["working_set_mb"]`` call sites keep
    working.
    """

    tool: str
    tool_class: str
    session: str
    data: dict

    def __getitem__(self, key):
        return self.data[key]

    def __contains__(self, key) -> bool:
        return key in self.data

    def get(self, key, default=None):
        return self.data.get(key, default)

    def keys(self):
        return self.data.keys()

    def asdict(self) -> dict:
        return {"tool": self.tool, "tool_class": self.tool_class,
                "session": self.session, "data": self.data}

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.asdict(), default=_json_default,
                          indent=indent)


class Reports(Mapping):
    """Immutable mapping of registry name → :class:`Report` with JSON and
    JSONL export (one report per line — streamable into any log pipeline)."""

    __slots__ = ("_reports",)

    def __init__(self, reports: dict):
        self._reports = dict(reports)

    def __getitem__(self, key: str) -> Report:
        return self._reports[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._reports)

    def __len__(self) -> int:
        return len(self._reports)

    def __repr__(self) -> str:
        return f"Reports({list(self._reports)})"

    @property
    def data(self) -> dict:
        """Plain ``{registry_name: report_data_dict}`` view (goldens,
        equality tests)."""
        return {k: r.data for k, r in self._reports.items()}

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps({k: r.asdict() for k, r in self._reports.items()},
                          default=_json_default, indent=indent)

    def to_jsonl(self, dest) -> int:
        """Stream one JSON line per report to ``dest`` (a path or a
        writable file object).  Returns the number of lines written."""
        if hasattr(dest, "write"):
            for rep in self._reports.values():
                dest.write(rep.to_json() + "\n")
        else:
            with open(dest, "w") as f:
                for rep in self._reports.values():
                    f.write(rep.to_json() + "\n")
        return len(self._reports)


# ---------------------------------------------------------------------------
# The session facade
# ---------------------------------------------------------------------------

_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "pasta_current_session", default=None)

_root: "Session | None" = None


class Session:
    """One isolated PASTA pipeline: handler → processor → tools + sources.

    Parameters
    ----------
    tools:
        Tool spec — a registry string (``"kernel_freq,timeline"``, knobs via
        ``"hotness:n_tbins=8"``), a list mixing instances / classes / names,
        or ``None`` (falls back to the ``PASTA_TOOL`` environment variable).
    buffered / buffer_capacity:
        Ring-buffer coarse events through the handler's SoA ring (flushed at
        capacity, step boundaries, session exit, and ``reports()``).
    hotness / device_analysis:
        Forwarded to :class:`EventProcessor` (trace-reduction configuration).
    instrument / fine / stride / pool_chunk / pool_align / time_source:
        When ``instrument=True`` the session owns an
        :class:`~repro.core.instrument.EagerInstrumenter` (entered and
        exited with the session) wired to the session handler.
    forward_to:
        Internal — a parent handler that receives every batch this session
        dispatches (used by :meth:`child` so per-request sessions still feed
        their parent's aggregate tools).

    Entering the session (``with session:``) makes it the *current* session
    for the enclosed scope: ``pasta.region``/``start``/``end``, handler-less
    ``MemoryPool``s, and the deprecation shims all resolve to it.  Sessions
    nest — the innermost active one wins — and concurrent sessions in other
    threads/contexts are unaffected (contextvars scoping).
    """

    def __init__(self, tools=None, *, name: str = "session",
                 handler: EventHandler | None = None,
                 buffered: bool = False, buffer_capacity: int = 4096,
                 device: tuple = (), hotness: dict | None = None,
                 device_analysis: bool = True, instrument: bool = False,
                 fine: bool = False, stride: int = 512,
                 pool_chunk: int = 32 * 1024 * 1024,
                 pool_align: int | None = None, time_source=None,
                 forward_to: EventHandler | None = None,
                 _bare: bool = False):
        self.name = name
        self.handler = handler if handler is not None else EventHandler(
            device=device, buffer_capacity=buffer_capacity,
            buffered=buffered)
        self.parent: "Session | None" = None
        self.children: list = []
        self._child_ids = _itertools.count()
        self.closed = False
        self.processor: EventProcessor | None = None
        self.instrumenter = None
        self._hotness = hotness
        self._device_analysis = device_analysis
        self._tokens: list = []
        self._pool = None
        self._forward_handler = None
        if _bare:
            # compatibility mode (implicit root / attach() shim): handler
            # only — the caller hand-wires processors, exactly like the old
            # process-global default_handler() surface
            self.tools = []
            return
        self.tools = resolve_tools(tools)
        self.processor = EventProcessor(
            self.handler, tools=self.tools,
            device_analysis=device_analysis, hotness=hotness)
        if instrument:
            from .instrument import EagerInstrumenter
            self.instrumenter = EagerInstrumenter(
                self.handler, fine=fine, stride=stride,
                pool_chunk=pool_chunk, pool_align=pool_align,
                time_source=time_source)
        if forward_to is not None:
            if forward_to is self.handler:
                raise ValueError("session cannot forward to its own handler")
            self._forward_handler = forward_to
            # subscribed after the processor: batches forward already
            # normalized, so the parent pipeline never re-normalizes
            self.handler.subscribe_batch(self._forward)

    # ------------------------------------------------------------ scoping
    def __enter__(self) -> "Session":
        if self.closed:
            raise RuntimeError(f"session {self.name!r} is closed")
        self._tokens.append(_CURRENT.set(self))
        if self.instrumenter is not None:
            self.instrumenter.__enter__()
        return self

    def __exit__(self, *exc) -> None:
        if self.instrumenter is not None:
            self.instrumenter.__exit__(*exc)
        self.handler.flush()
        if self._tokens:            # close() inside the block already reset
            _CURRENT.reset(self._tokens.pop())

    def child(self, tools=(), *, name: str | None = None,
              forward: bool = True, **kw) -> "Session":
        """A nested session with its own pipeline (isolated tools/reports),
        inheriting this session's processor configuration.  With ``forward=
        True`` (default) every batch the child dispatches is mirrored to
        this session's handler, so parent aggregates still see the events —
        the per-request pattern: one child per served request, parent keeps
        the fleet-wide view.  ``tools`` defaults to *none* (children never
        implicitly inherit the ``PASTA_TOOL`` environment default).  Closing
        a child drops it from ``children``, so long-lived parents don't
        accumulate request pipelines."""
        c = Session(
            tools=tools,
            name=name or f"{self.name}/child{next(self._child_ids)}",
            buffered=kw.pop("buffered", self.handler.buffered),
            device=kw.pop("device", self.handler.device),
            hotness=kw.pop("hotness", self._hotness),
            device_analysis=kw.pop("device_analysis", self._device_analysis),
            forward_to=self.handler if forward else None, **kw)
        c.parent = self
        self.children.append(c)
        return c

    def _forward(self, batch) -> None:
        self._forward_handler.emit_batch(batch)

    # ------------------------------------------------------------- sources
    @property
    def pool(self):
        """The session's memory pool: the instrumenter's when instrumenting,
        else a lazily-created pool bound to the session handler."""
        if self.instrumenter is not None:
            return self.instrumenter.pool
        if self._pool is None:
            from .pool import MemoryPool
            self._pool = MemoryPool(self.handler)
        return self._pool

    def capture_compiled(self, compiled, label: str = "",
                         default_trip: int = 1, steps: int = 1,
                         cost_analysis: dict | None = None):
        """Compiled-artifact capture through this session's handler."""
        return self.handler.capture_compiled(
            compiled, label=label, default_trip=default_trip, steps=steps,
            cost_analysis=cost_analysis)

    def add_tool(self, tool) -> None:
        if self.processor is None:
            raise RuntimeError("bare (compatibility) session has no "
                               "processor; construct pasta.Session(...) "
                               "instead")
        self.processor.add_tool(tool)
        self.tools = self.processor.tools

    # ------------------------------------------------------------- reports
    def reports(self) -> Reports:
        """Flush pending events and collect every tool's report, keyed by
        registry name (``REGISTRY_NAME``; class name for unregistered
        tools, ``#2``-suffixed on collisions)."""
        from .tools.base import TOOL_REGISTRY
        self.handler.flush()
        out: dict = {}
        for t in (self.processor.tools if self.processor is not None else ()):
            base = getattr(t, "REGISTRY_NAME", None)
            if base is None or TOOL_REGISTRY.get(base) is not type(t):
                # unregistered subclasses inherit REGISTRY_NAME from their
                # registered base — key those by their own class name
                base = type(t).__name__
            key, i = base, 2
            while key in out:
                key = f"{base}#{i}"
                i += 1
            out[key] = Report(tool=key, tool_class=type(t).__name__,
                              session=self.name, data=t.finalize())
        return Reports(out)

    # ----------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Detach the pipeline.  Idempotent — the serving engine (and any
        ``with`` + explicit-close pattern) may close a session that already
        exited its context, or close it twice.  Pending ring rows flush
        BEFORE the processor detaches, so a buffered session closed without
        exiting its context still delivers every event to its tools (and
        forwards them to its parent).  Reports stay readable after close;
        closed children drop out of their parent's ``children`` list
        (long-lived parents never accumulate per-request pipelines)."""
        if self.closed:
            return
        self.handler.flush()
        while self._tokens:
            _CURRENT.reset(self._tokens.pop())
        if self._forward_handler is not None:
            self.handler.unsubscribe(self._forward)
        if self.processor is not None:
            self.processor.close()
        if self.parent is not None and self in self.parent.children:
            self.parent.children.remove(self)
        self.closed = True

    def __repr__(self) -> str:
        state = "closed" if self.closed else "open"
        return (f"Session({self.name!r}, tools="
                f"{[type(t).__name__ for t in self.tools]}, {state})")


# ---------------------------------------------------------------------------
# Current-session resolution (contextvars-scoped)
# ---------------------------------------------------------------------------

def active_session() -> Session | None:
    """The innermost active session, or ``None`` when no session is
    entered in this context (no implicit-root fallback)."""
    s = _CURRENT.get()
    return None if s is None or s.closed else s


def root_session() -> Session:
    """The process's implicit root session (created on first use) — the
    compatibility fallback the deprecation shims attach to.  It is *bare*:
    handler only, no processor, exactly the old ``default_handler()``
    semantics."""
    global _root
    if _root is None:
        _root = Session(name="root", _bare=True)
    return _root


def current_session() -> Session:
    """The innermost active session, falling back to the implicit root."""
    return active_session() or root_session()


def current_handler() -> EventHandler:
    """The current session's handler — what ``pasta.region``/``start``/
    ``end`` and handler-less ``MemoryPool``s emit through."""
    return current_session().handler


def _attach_root(handler: EventHandler | None = None) -> EventHandler:
    """Back-end of the deprecated ``pasta.attach()``: replace the implicit
    root session with a bare one wrapping ``handler``."""
    global _root
    if _root is not None:
        _root.close()
    _root = Session(name="root", handler=handler, _bare=True)
    return _root.handler


def reset_state() -> None:
    """Drop the implicit root session and any leaked current-session
    binding (test isolation)."""
    global _root
    if _root is not None:
        _root.close()
    _root = None
    if _CURRENT.get() is not None:
        _CURRENT.set(None)
