"""Range-specific analysis support (paper §III-F1).

Mirrors the paper's minimal, non-intrusive annotation API::

    import repro.core as pasta

    pasta.start("linear1")
    y = linear1(x)
    pasta.end("linear1")

    with pasta.region("backward"):
        ...

plus the environment-variable grid-id filters ``START_GRID_ID`` /
``END_GRID_ID`` that restrict which kernel launches are analyzed.

The region stack is recorded into every event emitted while a region is
open, enabling layer-level / forward-vs-backward / custom-range breakdowns.
"""

from __future__ import annotations

import contextlib
import os
import threading

_state = threading.local()


def _stack() -> list:
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


def current_region() -> tuple:
    """Snapshot of the open annotation regions, outermost first."""
    return tuple(_stack())


def start(name: str) -> None:
    """Open an analysis region (paper Listing 1, ``pasta.start``).  The
    region event routes to the innermost active :class:`~repro.core.Session`
    (falling back to the implicit root session)."""
    from .session import current_handler
    from .events import Event, EventKind

    _stack().append(name)
    current_handler().emit(Event(EventKind.REGION_START, name=name,
                                 region=current_region()))


def end(name: str | None = None) -> None:
    """Close the innermost analysis region (paper Listing 1, ``pasta.end``)."""
    from .session import current_handler
    from .events import Event, EventKind

    stack = _stack()
    if not stack:
        raise RuntimeError("pasta.end() without matching pasta.start()")
    top = stack[-1]
    if name is not None and name != top:
        raise RuntimeError(f"pasta.end({name!r}) does not match open region {top!r}")
    stack.pop()
    current_handler().emit(Event(EventKind.REGION_END, name=top,
                                 region=current_region()))


@contextlib.contextmanager
def region(name: str):
    """Context-manager convenience over start/end."""
    start(name)
    try:
        yield
    finally:
        end(name)


class GridIdFilter:
    """Restrict analysis to a subset of kernel launches.

    Reads ``START_GRID_ID`` / ``END_GRID_ID`` (inclusive range), matching the
    paper's environment-variable interface for standard GPU applications.
    """

    def __init__(self, start_id: int | None = None, end_id: int | None = None):
        env_s = os.environ.get("START_GRID_ID")
        env_e = os.environ.get("END_GRID_ID")
        self.start_id = start_id if start_id is not None else (
            int(env_s) if env_s else 0)
        self.end_id = end_id if end_id is not None else (
            int(env_e) if env_e else 2 ** 62)

    def __call__(self, grid_id: int) -> bool:
        return self.start_id <= grid_id <= self.end_id
