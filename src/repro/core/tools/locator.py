"""Inefficiency-location utilities (paper §III-F2, Fig. 4).

Cross-level context: the knob-selected kernel (e.g. the most
memory-referenced one) is reported together with

  * its low-level HLO ``op_name`` metadata — XLA's equivalent of the C++
    backtrace: the full jit/while/remat scope path down to the jax primitive;
  * the high-level Python stack captured at the enclosing operator/region —
    the paper's CPython-frame side of the cross-layer stack.

Knobs: ``MAX_MEM_REFERENCED_KERNEL`` (default) and ``MAX_CALLED_KERNEL``;
users add custom knobs by overriding :meth:`score`.

NOTE: this tool captures the live Python stack at operator/region dispatch,
so it should run against an *unbuffered* handler (the default).  Under ring
buffering the batch reaches the tool at flush time and the captured stack
would reflect the flush site, not the emitting frame — the template's
loop-over-rows ``on_batch`` fallback still dispatches correctly, but the
cross-layer context is weaker.
"""

from __future__ import annotations

import traceback

from ..events import EventKind
from .base import PastaTool, register


@register("locator")
class LocatorTool(PastaTool):
    EVENTS = (EventKind.KERNEL_LAUNCH, EventKind.OPERATOR_START,
              EventKind.REGION_START)
    KNOBS = {"MAX_MEM_REFERENCED_KERNEL": True, "MAX_CALLED_KERNEL": False,
             "capture_python_stack": True}

    def __init__(self, **knobs):
        super().__init__(**knobs)
        self.best = None          # (score, event attrs snapshot)
        self._last_py_stack: list = []

    # custom knobs override this
    def score(self, ev) -> float:
        if self.knobs.get("MAX_CALLED_KERNEL"):
            return float(ev.attrs.get("count", 1))
        # default: most memory-referenced = bytes moved × invocations
        return float(ev.attrs.get("bytes", 0)) * float(ev.attrs.get("count", 1))

    def on_region_start(self, ev):
        self._capture_stack()

    def on_operator_start(self, ev):
        self._capture_stack()

    def _capture_stack(self):
        if self.knobs.get("capture_python_stack"):
            self._last_py_stack = [
                f"{f.filename}:{f.lineno} {f.name}"
                for f in traceback.extract_stack()[:-3]
                if "/repro/core/" not in f.filename.replace("\\", "/")
            ][-12:]

    def on_kernel_launch(self, ev):
        s = self.score(ev)
        if self.best is None or s > self.best[0]:
            self.best = (s, {
                "kernel": ev.name,
                "score": s,
                "count": ev.attrs.get("count", 1),
                "bytes": ev.attrs.get("bytes", 0),
                "hlo_op_name": ev.attrs.get("op_name", ""),
                "python_stack": list(self._last_py_stack),
                "region": list(ev.region),
            })

    def finalize(self) -> dict:
        return self.best[1] if self.best else {}
