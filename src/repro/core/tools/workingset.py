"""Memory-characterization / working-set tool (paper §V-B2, Table V).

Working set of a workload = max over kernels of the bytes *actually accessed*
by that kernel.  Two sources, in fidelity order:

  1. TRACE_BUFFER events whose aggregated ``object_counts`` prove which
     tensors were touched (the paper's access-verified path — operands passed
     but never read are excluded);
  2. OPERATOR_START events carrying declared operand tensors (fallback when
     fine-grained tracing is off).

Footprint (pool bytes obtained from the driver) comes from ALLOC events, and
live-tensor accounting from TENSOR_ALLOC/FREE.
"""

from __future__ import annotations

import numpy as np

from ..events import EventKind, KIND_CODE
from .base import PastaTool, register

_KC_KERNEL = int(KIND_CODE[EventKind.KERNEL_LAUNCH])
_KC_ALLOC = int(KIND_CODE[EventKind.ALLOC])


@register("workingset")
class WorkingSetTool(PastaTool):
    EVENTS = (EventKind.TENSOR_ALLOC, EventKind.TENSOR_FREE, EventKind.ALLOC,
              EventKind.OPERATOR_START, EventKind.OPERATOR_END,
              EventKind.TRACE_BUFFER, EventKind.KERNEL_LAUNCH)
    KNOBS = {"MAX_MEM_REFERENCED_KERNEL": True, "MAX_CALLED_KERNEL": False}

    def __init__(self, **knobs):
        super().__init__(**knobs)
        self.live: dict = {}           # tensor_id -> (addr, size, name)
        self.footprint = 0             # driver-level pool bytes
        self.peak_live = 0
        self.cur_live = 0
        self.kernel_ws: list = []      # per-kernel accessed bytes
        self.kernel_names: list = []
        self.kernel_count = 0
        self._max_ref = (None, -1)     # (kernel, bytes) — locator knob

    # ------------------------------------------------------------- memory
    def on_alloc(self, ev):
        self.footprint += ev.size

    def on_tensor_alloc(self, ev):
        self.live[ev.attrs["tensor_id"]] = (ev.addr, ev.size, ev.name)
        self.cur_live += ev.size
        self.peak_live = max(self.peak_live, self.cur_live)

    def on_tensor_free(self, ev):
        t = self.live.pop(ev.attrs["tensor_id"], None)
        if t is not None:
            self.cur_live -= t[1]

    # ------------------------------------------------------------ kernels
    def on_kernel_launch(self, ev):
        self.kernel_count += int(ev.attrs.get("count", 1))

    # ------------------------------------------------------------ batched
    def on_batch(self, batch):
        """Vectorized consumption of the hot columns (kernel invocation
        totals via the normalized ``counts`` column, pool footprint via a
        masked size sum); the attr-dependent memory/operator/trace rows are
        rare and fall back to ordered per-row dispatch (their peak/live
        accounting is order-sensitive)."""
        kinds = batch.kinds
        kmask = kinds == _KC_KERNEL
        if kmask.any():
            if batch.counts is not None:
                self.kernel_count += int(batch.counts[kmask].sum())
            else:
                self.kernel_count += int(kmask.sum())
        amask = kinds == _KC_ALLOC
        if amask.any():
            self.footprint += int(batch.sizes[amask].sum())
        for ev in batch.iter_events((EventKind.TENSOR_ALLOC,
                                     EventKind.TENSOR_FREE,
                                     EventKind.OPERATOR_START,
                                     EventKind.TRACE_BUFFER)):
            self.on_event(ev)

    def on_operator_start(self, ev):
        tensors = ev.attrs.get("tensors")
        if tensors is None or ev.attrs.get("traced"):
            return          # fine-grained trace supersedes declared operands
        ws = sum(sz for (_a, sz) in tensors)
        self._record(ev.name, ws)

    def on_trace_buffer(self, ev):
        counts = ev.attrs.get("object_counts")
        obj_sizes = ev.attrs.get("object_sizes")
        if counts is None or obj_sizes is None:
            return
        touched = int(np.sum(np.where(np.asarray(counts) > 0,
                                      np.asarray(obj_sizes), 0)))
        self._record(ev.attrs.get("kernel", ev.name), touched)

    def _record(self, name: str, ws: int) -> None:
        self.kernel_ws.append(ws)
        self.kernel_names.append(name)
        if self.knobs.get("MAX_MEM_REFERENCED_KERNEL") and ws > self._max_ref[1]:
            self._max_ref = (name, ws)

    # ------------------------------------------------------------ report
    def finalize(self) -> dict:
        ws = np.asarray(self.kernel_ws, dtype=np.float64)
        if ws.size == 0:
            ws = np.zeros(1)
        mb = 1024.0 ** 2
        return {
            "kernel_count": self.kernel_count or len(self.kernel_ws),
            "operator_count": len(self.kernel_ws),
            "footprint_mb": self.footprint / mb,
            "peak_live_mb": self.peak_live / mb,
            "working_set_mb": float(ws.max()) / mb,
            "min_ws_mb": float(ws.min()) / mb,
            "avg_ws_mb": float(ws.mean()) / mb,
            "median_ws_mb": float(np.median(ws)) / mb,
            "p90_ws_mb": float(np.percentile(ws, 90)) / mb,
            "max_mem_referenced_kernel": self._max_ref[0],
        }
