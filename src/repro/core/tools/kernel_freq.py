"""Kernel-invocation frequency tool (paper §V-B1, Fig. 7).

Counts executed kernels (top-level HLO instructions × loop trip counts ×
steps).  The paper's insight — a small subset of kernels dominates invocation
counts — falls out of ``finalize()['top']``.
"""

from __future__ import annotations

import collections

from ..events import EventKind
from .base import PastaTool


class KernelFrequencyTool(PastaTool):
    EVENTS = (EventKind.KERNEL_LAUNCH,)

    def __init__(self, top_k: int = 20, **knobs):
        super().__init__(**knobs)
        self.top_k = top_k
        self.counts: collections.Counter = collections.Counter()
        self.by_label: dict = collections.defaultdict(collections.Counter)

    def on_kernel_launch(self, ev):
        n = int(ev.attrs.get("count", 1))
        # collapse ssa suffixes: fusion.123 -> fusion ; keep op_name flavor
        base = ev.name.split(".")[0]
        self.counts[base] += n
        self.counts[ev.name] += 0      # keep exact names discoverable
        label = ev.attrs.get("label", "")
        if label:
            self.by_label[label][base] += n

    def finalize(self) -> dict:
        total = sum(self.counts.values())
        top = self.counts.most_common(self.top_k)
        return {
            "total_invocations": total,
            "distinct_kernels": sum(1 for c in self.counts.values() if c > 0),
            "top": top,
            "by_label": {k: dict(v.most_common(self.top_k))
                         for k, v in self.by_label.items()},
        }
