"""Kernel-invocation frequency tool (paper §V-B1, Fig. 7).

Counts executed kernels (top-level HLO instructions × loop trip counts ×
steps).  The paper's insight — a small subset of kernels dominates invocation
counts — falls out of ``finalize()['top']``.

Batch consumption is vectorized: per-batch invocation sums come from one
``np.bincount`` over the dictionary-encoded name column; the Counter is then
updated per *unique* name in first-appearance order, which reproduces the
scalar path's insertion order exactly (so ``most_common`` tie-breaks — and
therefore the report — are byte-identical under scalar and batched emission).
"""

from __future__ import annotations

import collections

import numpy as np

from ..events import EventKind
from .base import PastaTool, register


@register("kernel_freq")
class KernelFrequencyTool(PastaTool):
    EVENTS = (EventKind.KERNEL_LAUNCH,)

    def __init__(self, top_k: int = 20, **knobs):
        super().__init__(**knobs)
        self.top_k = top_k
        self.counts: collections.Counter = collections.Counter()
        self.by_label: dict = collections.defaultdict(collections.Counter)

    # ------------------------------------------------------------- scalar
    def on_kernel_launch(self, ev):
        n = int(ev.attrs.get("count", 1))
        # collapse ssa suffixes: fusion.123 -> fusion ; keep op_name flavor
        base = ev.name.split(".")[0]
        self.counts[base] += n
        self.counts[ev.name] += 0      # keep exact names discoverable
        label = ev.attrs.get("label", "")
        if label:
            self.by_label[label][base] += n

    # ------------------------------------------------------------ batched
    def on_batch(self, batch):
        idx = batch.rows(EventKind.KERNEL_LAUNCH)
        if idx.size == 0:
            return
        nid = batch.name_ids[idx]
        cnt = (batch.counts[idx] if batch.counts is not None
               else np.ones(idx.size, dtype=np.int64))
        sums = np.bincount(nid, weights=cnt,
                           minlength=len(batch.name_table)).astype(np.int64)
        uniq, first = np.unique(nid, return_index=True)
        for t in uniq[np.argsort(first)]:
            name = batch.name_table[t]
            self.counts[name.split(".")[0]] += int(sums[t])
            self.counts[name] += 0
        if batch.attrs is not None:
            for i in idx:
                a = batch.attrs[i]
                if a:
                    label = a.get("label", "")
                    if label:
                        base = batch.name_table[batch.name_ids[i]].split(
                            ".")[0]
                        self.by_label[label][base] += int(a.get("count", 1))

    def finalize(self) -> dict:
        total = sum(self.counts.values())
        top = self.counts.most_common(self.top_k)
        return {
            "total_invocations": total,
            "distinct_kernels": sum(1 for c in self.counts.values() if c > 0),
            "top": top,
            "by_label": {k: dict(v.most_common(self.top_k))
                         for k, v in self.by_label.items()},
        }
