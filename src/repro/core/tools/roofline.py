"""Roofline tool — derives the three roofline terms from dry-run artifacts,
plus a batch-consuming :class:`RooflineTool` that accumulates the same terms
live from the columnar event stream.

Terms (per the assignment; the compiled SPMD module is the *per-device*
program, so parsed FLOPs/bytes are already per-chip and divide by per-chip
peaks — algebraically identical to global/(chips×peak)):

    compute    = HLO_FLOPs_per_chip    / peak_FLOP/s
    memory     = HLO_bytes_per_chip    / HBM_bw
    collective = coll_bytes_per_chip   / link_bw

Hardware constants: TPU v5e.
"""

from __future__ import annotations

import dataclasses

V5E = {
    "peak_flops": 197e12,      # bf16 FLOP/s per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per ICI link (intra-pod)
    "dci_bw": 12.5e9,          # bytes/s inter-pod (DCI — the slow link the
                               # compressed/overlapped pod sync targets)
    "ici_latency": 1e-6,       # per-collective launch/sync latency (alpha)
    "hbm_bytes": 16 * 1024**3, # capacity per chip
}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_chip: float = 0.0
    hlo_flops_per_chip: float = 0.0

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound step time:
        useful-FLOPs/chip / peak / step_time."""
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops_per_chip / V5E["peak_flops"]) / self.step_time_s

    @property
    def useful_flops_ratio(self) -> float:
        if self.hlo_flops_per_chip <= 0:
            return 0.0
        return self.model_flops_per_chip / self.hlo_flops_per_chip

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline(flops_per_chip: float, hbm_bytes_per_chip: float,
             coll_bytes_per_chip: float, model_flops_per_chip: float = 0.0,
             hw: dict = V5E) -> Roofline:
    return Roofline(
        compute_s=flops_per_chip / hw["peak_flops"],
        memory_s=hbm_bytes_per_chip / hw["hbm_bw"],
        collective_s=coll_bytes_per_chip / hw["ici_bw"],
        model_flops_per_chip=model_flops_per_chip,
        hlo_flops_per_chip=flops_per_chip,
    )


def model_flops(n_params: float, n_tokens: float, training: bool = True,
                n_active_params: float | None = None) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference fwd); MoE uses
    N_active."""
    n = n_active_params if n_active_params is not None else n_params
    return (6.0 if training else 2.0) * n * n_tokens


# ---------------------------------------------------------------------------
# Event-stream roofline accumulator (columnar tool)
# ---------------------------------------------------------------------------

import numpy as np                                        # noqa: E402

from ..events import EventKind                            # noqa: E402
from .base import PastaTool, register                     # noqa: E402


@register("roofline")
class RooflineTool(PastaTool):
    """Accumulates the three roofline terms from the event stream itself:
    per-chip HBM traffic from KERNEL_LAUNCH batches (``bytes × count``),
    wire bytes from COLLECTIVE batches (``size × mult``), and FLOPs from the
    COMPILE event's cost analysis.  Batch consumption is vectorized over the
    size/count columns; attrs are only touched on the (few) rows that carry
    them."""

    EVENTS = (EventKind.KERNEL_LAUNCH, EventKind.COLLECTIVE,
              EventKind.COMPILE)

    def __init__(self, hw: dict = V5E, model_flops_per_chip: float = 0.0,
                 **knobs):
        super().__init__(**knobs)
        self.hw = dict(hw)
        self.model_flops_per_chip = model_flops_per_chip
        self.flops = 0.0
        self.hbm_bytes = 0.0
        self.coll_bytes = 0.0
        self.kernel_invocations = 0

    # scalar hooks — kept equivalent to on_batch (single-row fast path)
    def on_kernel_launch(self, ev):
        n = int(ev.attrs.get("count", 1))
        self.kernel_invocations += n
        self.hbm_bytes += float(ev.attrs.get("bytes", 0)) * n

    def on_collective(self, ev):
        self.coll_bytes += float(ev.size) * float(ev.attrs.get("mult", 1))

    def on_compile(self, ev):
        ca = ev.attrs.get("cost_analysis") or {}
        self.flops += float(ca.get("flops", 0.0))

    def on_batch(self, batch):
        kidx = batch.rows(EventKind.KERNEL_LAUNCH)
        if kidx.size:
            counts = (batch.counts[kidx] if batch.counts is not None
                      else np.ones(kidx.size, dtype=np.int64))
            self.kernel_invocations += int(counts.sum())
            byts = batch.attr_column("bytes", 0, rows=kidx, dtype=np.float64)
            self.hbm_bytes += float((byts * counts).sum())
        cidx = batch.rows(EventKind.COLLECTIVE)
        if cidx.size:
            mult = batch.attr_column("mult", 1, rows=cidx, dtype=np.float64)
            self.coll_bytes += float((batch.sizes[cidx] * mult).sum())
        for i in batch.rows(EventKind.COMPILE):
            a = batch.attrs_at(int(i))
            if a:
                ca = a.get("cost_analysis") or {}
                self.flops += float(ca.get("flops", 0.0))

    def finalize(self) -> dict:
        rl = roofline(self.flops, self.hbm_bytes, self.coll_bytes,
                      model_flops_per_chip=self.model_flops_per_chip,
                      hw=self.hw)
        out = rl.as_dict()
        out.update(kernel_invocations=self.kernel_invocations,
                   hbm_bytes=self.hbm_bytes, coll_bytes=self.coll_bytes,
                   flops=self.flops)
        return out
