"""Built-in PASTA tool collection + registry.

Tool selection follows the paper's CLI/environment interface: set
``PASTA_TOOL=<name>[,<name>...]`` or pass names to :func:`make_tools`.
"""

from __future__ import annotations

import os

from .base import PastaTool
from .kernel_freq import KernelFrequencyTool
from .workingset import WorkingSetTool
from .hotness import HotnessTool
from .timeline import MemoryTimelineTool
from .locator import LocatorTool
from .roofline import RooflineTool
from . import offload
from . import roofline

REGISTRY = {
    "kernel_freq": KernelFrequencyTool,
    "workingset": WorkingSetTool,
    "hotness": HotnessTool,
    "timeline": MemoryTimelineTool,
    "locator": LocatorTool,
    "roofline": RooflineTool,
}


def make_tools(names: str | list | None = None, **kw) -> list:
    """Instantiate tools by name; default from ``PASTA_TOOL`` env var."""
    if names is None:
        names = os.environ.get("PASTA_TOOL", "")
    if isinstance(names, str):
        names = [n.strip() for n in names.split(",") if n.strip()]
    out = []
    for n in names:
        if n not in REGISTRY:
            raise KeyError(f"unknown PASTA tool {n!r}; known: {sorted(REGISTRY)}")
        out.append(REGISTRY[n](**kw.get(n, {})))
    return out


__all__ = ["PastaTool", "KernelFrequencyTool", "WorkingSetTool",
           "HotnessTool", "MemoryTimelineTool", "LocatorTool",
           "RooflineTool", "offload", "roofline", "REGISTRY", "make_tools"]
