"""Built-in PASTA tool collection + string-keyed registry.

Tools register themselves with the :func:`~repro.core.tools.base.register`
decorator and are selected by spec string — ``pasta.Session(tools=
"kernel_freq,timeline")``, knobs via ``"hotness:n_tbins=8,hot_frac=0.75"``,
or the ``PASTA_TOOL`` environment variable (the paper's CLI interface).
"""

from __future__ import annotations

import warnings

from .base import (PastaTool, TOOL_REGISTRY, register, parse_tool_spec,
                   resolve_tools)
from .kernel_freq import KernelFrequencyTool
from .workingset import WorkingSetTool
from .hotness import HotnessTool
from .timeline import MemoryTimelineTool
from .locator import LocatorTool
from .roofline import RooflineTool
from .serving import ServingTool
from . import offload
from . import roofline

#: compatibility alias — the registry is populated by @register decorators
REGISTRY = TOOL_REGISTRY


def make_tools(names: str | list | None = None, **kw) -> list:
    """Deprecated: instantiate tools by name (old hardcoded-table surface).
    Use ``pasta.Session(tools=...)`` or :func:`resolve_tools` instead."""
    warnings.warn(
        "pasta.make_tools() is deprecated; pass a tool spec to "
        "pasta.Session(tools=...) or use repro.core.tools.resolve_tools()",
        DeprecationWarning, stacklevel=2)
    return resolve_tools(names, overrides=kw)


__all__ = ["PastaTool", "KernelFrequencyTool", "WorkingSetTool",
           "HotnessTool", "MemoryTimelineTool", "LocatorTool",
           "RooflineTool", "ServingTool", "offload", "roofline", "REGISTRY",
           "TOOL_REGISTRY", "register", "parse_tool_spec", "resolve_tools",
           "make_tools"]
