"""Host-offload planner — the TPU adaptation of the paper's tensor-aware UVM
prefetcher (paper §V-C1, Figs. 11–12).

TPUs have no page-faulting UVM; the analogous memory-expansion mechanism is
scheduled host-DRAM offload over the host link.  The planning question is
identical to the paper's: *at which granularity* (pool memory object vs.
individual tensor) should data be prefetched/evicted, and the answer flips
with memory pressure exactly as in the paper:

  * no oversubscription → object-level slightly wins (fewer, larger DMAs;
    per-transfer latency amortized);
  * oversubscription (footprint > capacity) → object-level thrashes (objects
    carry never-accessed tensors that evict hot data), tensor-level wins.

The simulator executes a kernel schedule against an LRU-resident device
memory with a lookahead-1 prefetcher overlapped with compute, under an
analytic DMA cost model.  Inputs come from the working-set/trace analyses
(which tensors each kernel *actually* accesses — the access-verified sets).
"""

from __future__ import annotations

import collections
import dataclasses

# host-link cost model (per-direction); tuned to PCIe-4 x16-class links used
# by the paper's systems — see DESIGN.md §2 for the TPU host-DMA mapping.
LINK_BW = 16e9                 # bytes/s
XFER_LAT = 30e-6               # per-DMA fixed latency (fault/driver overhead)
PAGE = 2 * 1024 * 1024


@dataclasses.dataclass
class KernelAccess:
    """One kernel's access-verified data needs."""
    name: str
    compute_s: float
    tensors: list              # [(tensor_id, size, object_id)]

    def tensor_units(self):
        return [(("t", tid), sz) for tid, sz, _oid in self.tensors]

    def object_units(self, object_sizes):
        oids = {oid for _t, _s, oid in self.tensors}
        return [(("o", oid), object_sizes[oid]) for oid in sorted(oids)]


class _Resident:
    """LRU-managed device residency at arbitrary unit granularity."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.units: collections.OrderedDict = collections.OrderedDict()
        self.used = 0
        self.evicted_bytes = 0

    def touch(self, unit, size) -> bool:
        """Ensure unit resident; return True if it was already present."""
        if unit in self.units:
            self.units.move_to_end(unit)
            return True
        self._make_room(size)
        self.units[unit] = size
        self.used += size
        return False

    def _make_room(self, size):
        while self.used + size > self.capacity and self.units:
            _u, s = self.units.popitem(last=False)
            self.used -= s
            self.evicted_bytes += s


def _xfer_time(nbytes: int, n_xfers: int = 1) -> float:
    return nbytes / LINK_BW + n_xfers * XFER_LAT


def simulate(schedule, object_sizes, capacity: int,
             policy: str = "none") -> dict:
    """Run the schedule under one residency policy.

    policy:
      * ``none``   — on-demand migration (paper baseline): misses stall.
      * ``object`` — lookahead-1 prefetch of whole memory objects, overlapped.
      * ``tensor`` — lookahead-1 prefetch of accessed tensors, overlapped.
    """
    res = _Resident(capacity)
    total = 0.0
    stall = 0.0
    migrated = 0
    inflight = 0.0             # prefetch time still outstanding

    def units_for(k: KernelAccess):
        if policy == "object":
            return k.object_units(object_sizes)
        return k.tensor_units()

    for i, k in enumerate(schedule):
        # 1) whatever this kernel needs and is absent must migrate NOW (stall)
        miss_bytes = 0
        miss_n = 0
        for unit, size in units_for(k):
            if not res.touch(unit, size):
                miss_bytes += size
                miss_n += 1
        demand = _xfer_time(miss_bytes, miss_n) if miss_bytes else 0.0
        migrated += miss_bytes
        # outstanding prefetch must finish before dependent compute (if the
        # missed units were being prefetched we already charged them; model
        # keeps it simple: demand migration and prefetch share the link)
        t_step = k.compute_s + demand + max(0.0, inflight - k.compute_s)
        stall += demand + max(0.0, inflight - k.compute_s)
        inflight = 0.0
        # 2) overlap: prefetch next kernel's units during this one
        if policy in ("object", "tensor") and i + 1 < len(schedule):
            nxt = schedule[i + 1]
            pf_bytes = 0
            pf_n = 0
            for unit, size in units_for(nxt):
                if not res.touch(unit, size):
                    pf_bytes += size
                    pf_n += 1
            migrated += pf_bytes
            inflight = _xfer_time(pf_bytes, pf_n) if pf_bytes else 0.0
        total += t_step
    return {"policy": policy, "time_s": total, "stall_s": stall,
            "migrated_bytes": migrated, "evicted_bytes": res.evicted_bytes}


def plan(schedule, object_sizes, footprint: int,
         oversubscription: float = 1.0) -> dict:
    """Compare policies at ``capacity = footprint / oversubscription``."""
    min_unit = max((sz for k in schedule for _t, sz, _o in k.tensors),
                   default=PAGE)
    capacity = max(min_unit, int(footprint / max(oversubscription, 1e-9)))
    out = {"capacity_bytes": capacity, "oversubscription": oversubscription}
    for policy in ("none", "object", "tensor"):
        out[policy] = simulate(schedule, object_sizes, capacity, policy)
    base = out["none"]["time_s"]
    for policy in ("object", "tensor"):
        out[policy]["speedup_vs_none"] = (
            base / out[policy]["time_s"] if out[policy]["time_s"] else 0.0)
    return out
