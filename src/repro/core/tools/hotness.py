"""Time-series hotness tool (paper §V-C2, Fig. 13).

Accumulates access hotness in (time-bin × 2 MiB virtual-memory block) space.
The heavy reduction happens on device (event processor, Fig. 2b model); the
tool only sums the small per-buffer aggregates and classifies blocks:

  * long-lived hot blocks (accessed across most of the run — e.g. params):
    pin / prefetch candidates;
  * bursty blocks (hot in narrow windows — e.g. activations, KV blocks):
    proactive-eviction candidates.
"""

from __future__ import annotations

import numpy as np

from ..events import EventKind
from .base import PastaTool, register


@register("hotness")
class HotnessTool(PastaTool):
    EVENTS = (EventKind.TRACE_BUFFER,)

    def __init__(self, n_tbins: int = 64, n_blocks: int = 1024,
                 hot_frac: float = 0.5, **knobs):
        super().__init__(**knobs)
        self.n_tbins = n_tbins
        self.n_blocks = n_blocks
        self.hot_frac = hot_frac
        self.hot = np.zeros((n_tbins, n_blocks), dtype=np.int64)

    def on_trace_buffer(self, ev):
        h = ev.attrs.get("hotness_map")
        if h is None:
            return
        h = np.asarray(h)
        tb, nb = h.shape
        self.hot[:tb, :nb] += h

    def on_batch(self, batch):
        """Sum the per-buffer device aggregates straight off the attrs side
        table — no scalar Event materialization on the batch path."""
        for i in batch.rows(EventKind.TRACE_BUFFER):
            a = batch.attrs_at(int(i))
            h = None if a is None else a.get("hotness_map")
            if h is None:
                continue
            h = np.asarray(h)
            tb, nb = h.shape
            self.hot[:tb, :nb] += h

    def classify(self, hot_frac: float = 0.5):
        """Split blocks into persistent-hot vs bursty vs cold."""
        touched = self.hot > 0
        presence = touched.mean(axis=0)            # fraction of time bins hot
        total = self.hot.sum(axis=0)
        persistent = np.where((presence >= hot_frac) & (total > 0))[0]
        bursty = np.where((presence < hot_frac) & (total > 0))[0]
        return {"persistent_blocks": persistent.tolist(),
                "bursty_blocks": bursty.tolist(),
                "cold_blocks": int((total == 0).sum())}

    def finalize(self) -> dict:
        out = self.classify(self.hot_frac)
        out["total_accesses"] = int(self.hot.sum())
        out["hot_matrix_shape"] = list(self.hot.shape)
        out["peak_bin"] = (int(np.argmax(self.hot.max(axis=1)))
                           if self.hot.size else -1)
        return out
