"""PASTA tool-collection template (paper §III-B "Tool collection").

A tool is written by subclassing :class:`PastaTool` and overriding only the
``on_<event-kind>`` methods it cares about — the paper's "simply overriding
functions in the PASTA tool collection template".  ``EVENTS`` narrows which
kinds are routed to the tool at all (low-overhead: uninteresting events never
reach user code).  ``KNOBS`` is the paper's predefined-knob mechanism for the
inefficiency-location utilities (e.g. ``MAX_MEM_REFERENCED_KERNEL``).
"""

from __future__ import annotations

from ..events import Event, EventKind


class PastaTool:
    #: event kinds of interest; ("*",) means all
    EVENTS: tuple = ("*",)
    #: named knobs (environment-overridable selective controls)
    KNOBS: dict = {}

    def __init__(self, **knobs):
        self.knobs = dict(self.KNOBS)
        self.knobs.update(knobs)
        self.processor = None       # set by EventProcessor.add_tool

    # ------------------------------------------------------------- routing
    def wants(self, kind: EventKind) -> bool:
        return "*" in self.EVENTS or kind in self.EVENTS \
            or kind.value in self.EVENTS

    def on_event(self, ev: Event) -> None:
        fn = getattr(self, f"on_{ev.kind.value}", None)
        if fn is not None:
            fn(ev)

    # ------------------------------------------------------------ template
    def finalize(self) -> dict:
        """Produce the tool's report. Override."""
        return {}

    # default no-op hooks (subset shown; any on_<kind> name is dispatched)
    def on_kernel_launch(self, ev: Event) -> None: ...
    def on_tensor_alloc(self, ev: Event) -> None: ...
    def on_tensor_free(self, ev: Event) -> None: ...
    def on_operator_start(self, ev: Event) -> None: ...
    def on_operator_end(self, ev: Event) -> None: ...
    def on_trace_buffer(self, ev: Event) -> None: ...
