"""PASTA tool-collection template + string-keyed tool registry.

A tool is written by subclassing :class:`PastaTool` and overriding only the
``on_<event-kind>`` methods it cares about — the paper's "simply overriding
functions in the PASTA tool collection template".  ``EVENTS`` narrows which
kinds are routed to the tool at all (low-overhead: uninteresting events never
reach user code).  ``KNOBS`` is the paper's predefined-knob mechanism for the
inefficiency-location utilities (e.g. ``MAX_MEM_REFERENCED_KERNEL``).

Dispatch is columnar: the processor hands each tool a whole
:class:`~repro.core.events.EventBatch` through :meth:`PastaTool.on_batch`.
The default implementation is a loop-over-rows fallback that materializes
scalar Events and dispatches to the ``on_<kind>`` hooks, so existing
subclasses keep working unchanged; hot tools override ``on_batch`` with true
vectorized consumption (``np.bincount`` / ``np.add.at`` over the columns).

Tools register under a string key with the :func:`register` decorator::

    @register("launch_bytes")
    class LaunchBytesTool(PastaTool): ...

and are then selectable by spec string anywhere a tool list is accepted
(``pasta.Session(tools="kernel_freq,timeline")``, the ``PASTA_TOOL``
environment variable, the launch drivers' ``--pasta-tools``).  A spec entry
may carry constructor knobs: ``"name:knob=val,knob2=val2"`` — values parse
as int/float/bool where possible, else string.
"""

from __future__ import annotations

import os

from ..events import Event, EventBatch, EventKind


class PastaTool:
    #: event kinds of interest; ("*",) means all
    EVENTS: tuple = ("*",)
    #: named knobs (environment-overridable selective controls)
    KNOBS: dict = {}

    def __init__(self, **knobs):
        self.knobs = dict(self.KNOBS)
        self.knobs.update(knobs)
        self.processor = None       # set by EventProcessor.add_tool

    # ------------------------------------------------------------- routing
    def wants(self, kind: EventKind) -> bool:
        return "*" in self.EVENTS or kind in self.EVENTS \
            or kind.value in self.EVENTS

    def on_batch(self, batch: EventBatch) -> None:
        """Consume a columnar batch.  Default: materialize matching rows and
        dispatch them to the scalar ``on_<kind>`` hooks (compatibility
        fallback).  Vectorized tools override this — but must keep their
        scalar hooks equivalent, because one-row (scalar-emit) dispatch
        takes the ``on_<kind>`` fast path; the golden batch-vs-scalar tests
        pin both paths to identical reports."""
        for ev in batch.iter_events(self.EVENTS):
            self.on_event(ev)

    def on_event(self, ev: Event) -> None:
        fn = getattr(self, f"on_{ev.kind.value}", None)
        if fn is not None:
            fn(ev)

    # ------------------------------------------------------------ template
    def finalize(self) -> dict:
        """Produce the tool's report. Override."""
        return {}

    # default no-op hooks (subset shown; any on_<kind> name is dispatched)
    def on_kernel_launch(self, ev: Event) -> None: ...
    def on_tensor_alloc(self, ev: Event) -> None: ...
    def on_tensor_free(self, ev: Event) -> None: ...
    def on_operator_start(self, ev: Event) -> None: ...
    def on_operator_end(self, ev: Event) -> None: ...
    def on_trace_buffer(self, ev: Event) -> None: ...


# ---------------------------------------------------------------------------
# String-keyed tool registry
# ---------------------------------------------------------------------------

#: registry name -> PastaTool subclass (populated by @register)
TOOL_REGISTRY: dict = {}


def register(name: str):
    """Class decorator: make a tool selectable by ``name`` in tool specs.

    The name becomes the tool's key in :meth:`repro.core.Session.reports`
    (exposed on the class as ``REGISTRY_NAME``).  Re-registering the same
    class under the same name is a no-op; stealing a taken name raises.
    """
    def deco(cls):
        prev = TOOL_REGISTRY.get(name)
        if prev is not None and prev is not cls:
            raise ValueError(
                f"tool name {name!r} is already registered to "
                f"{prev.__name__}")
        TOOL_REGISTRY[name] = cls
        cls.REGISTRY_NAME = name
        return cls
    return deco


def _parse_knob_value(raw: str):
    low = raw.lower()
    if low in ("true", "yes", "on"):
        return True
    if low in ("false", "no", "off"):
        return False
    for cast in (int, float):
        try:
            return cast(raw)
        except ValueError:
            pass
    return raw


def parse_tool_spec(spec: str) -> list:
    """Parse ``"name[:knob=val[,knob=val...]][,name...]"`` into
    ``[(name, {knob: value}), ...]``.

    A ``:`` after a tool name opens its knob list; subsequent ``key=val``
    comma segments bind to that tool until a segment without ``=`` starts
    the next tool.  Values parse as bool/int/float where possible.
    """
    entries: list = []
    open_knobs = False
    for seg in spec.split(","):
        seg = seg.strip()
        if not seg:
            continue
        if ":" in seg:
            name, first = seg.split(":", 1)
            name = name.strip()
            if not name:
                raise ValueError(f"empty tool name in spec segment {seg!r}")
            knobs: dict = {}
            entries.append((name, knobs))
            open_knobs = True
            if first.strip():
                k, eq, v = first.partition("=")
                if not eq:
                    raise ValueError(
                        f"expected knob=value after {name!r}:, got {first!r}")
                knobs[k.strip()] = _parse_knob_value(v.strip())
        elif "=" in seg:
            if not open_knobs:
                raise ValueError(
                    f"knob {seg!r} without a preceding 'tool:' entry")
            k, _eq, v = seg.partition("=")
            entries[-1][1][k.strip()] = _parse_knob_value(v.strip())
        else:
            entries.append((seg, {}))
            open_knobs = False
    return entries


def resolve_tools(spec=None, overrides: dict | None = None) -> list:
    """Instantiate tools from a spec.

    ``spec`` may be ``None`` (falls back to the ``PASTA_TOOL`` environment
    variable, the paper's CLI interface), a spec string (see
    :func:`parse_tool_spec`), or a list mixing :class:`PastaTool` instances,
    classes, registry names, and ``(name, kwargs)`` pairs.  ``overrides``
    optionally maps registry names to extra constructor kwargs.
    """
    if spec is None:
        spec = os.environ.get("PASTA_TOOL", "")
    if isinstance(spec, PastaTool):
        return [spec]
    overrides = overrides or {}

    def build(name: str, knobs: dict):
        if name not in TOOL_REGISTRY:
            raise KeyError(f"unknown PASTA tool {name!r}; "
                           f"known: {sorted(TOOL_REGISTRY)}")
        kw = dict(knobs)
        kw.update(overrides.get(name, {}))
        return TOOL_REGISTRY[name](**kw)

    if isinstance(spec, str):
        return [build(n, k) for n, k in parse_tool_spec(spec)]
    out = []
    for item in spec:
        if isinstance(item, PastaTool):
            out.append(item)
        elif isinstance(item, type) and issubclass(item, PastaTool):
            out.append(item())
        elif isinstance(item, str):
            out.extend(build(n, k) for n, k in parse_tool_spec(item))
        elif isinstance(item, tuple) and len(item) == 2:
            out.append(build(item[0], dict(item[1])))
        else:
            raise TypeError(f"cannot resolve tool spec item {item!r}")
    return out
