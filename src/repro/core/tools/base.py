"""PASTA tool-collection template (paper §III-B "Tool collection").

A tool is written by subclassing :class:`PastaTool` and overriding only the
``on_<event-kind>`` methods it cares about — the paper's "simply overriding
functions in the PASTA tool collection template".  ``EVENTS`` narrows which
kinds are routed to the tool at all (low-overhead: uninteresting events never
reach user code).  ``KNOBS`` is the paper's predefined-knob mechanism for the
inefficiency-location utilities (e.g. ``MAX_MEM_REFERENCED_KERNEL``).

Dispatch is columnar: the processor hands each tool a whole
:class:`~repro.core.events.EventBatch` through :meth:`PastaTool.on_batch`.
The default implementation is a loop-over-rows fallback that materializes
scalar Events and dispatches to the ``on_<kind>`` hooks, so existing
subclasses keep working unchanged; hot tools override ``on_batch`` with true
vectorized consumption (``np.bincount`` / ``np.add.at`` over the columns).
"""

from __future__ import annotations

from ..events import Event, EventBatch, EventKind


class PastaTool:
    #: event kinds of interest; ("*",) means all
    EVENTS: tuple = ("*",)
    #: named knobs (environment-overridable selective controls)
    KNOBS: dict = {}

    def __init__(self, **knobs):
        self.knobs = dict(self.KNOBS)
        self.knobs.update(knobs)
        self.processor = None       # set by EventProcessor.add_tool

    # ------------------------------------------------------------- routing
    def wants(self, kind: EventKind) -> bool:
        return "*" in self.EVENTS or kind in self.EVENTS \
            or kind.value in self.EVENTS

    def on_batch(self, batch: EventBatch) -> None:
        """Consume a columnar batch.  Default: materialize matching rows and
        dispatch them to the scalar ``on_<kind>`` hooks (compatibility
        fallback).  Vectorized tools override this — but must keep their
        scalar hooks equivalent, because one-row (scalar-emit) dispatch
        takes the ``on_<kind>`` fast path; the golden batch-vs-scalar tests
        pin both paths to identical reports."""
        for ev in batch.iter_events(self.EVENTS):
            self.on_event(ev)

    def on_event(self, ev: Event) -> None:
        fn = getattr(self, f"on_{ev.kind.value}", None)
        if fn is not None:
            fn(ev)

    # ------------------------------------------------------------ template
    def finalize(self) -> dict:
        """Produce the tool's report. Override."""
        return {}

    # default no-op hooks (subset shown; any on_<kind> name is dispatched)
    def on_kernel_launch(self, ev: Event) -> None: ...
    def on_tensor_alloc(self, ev: Event) -> None: ...
    def on_tensor_free(self, ev: Event) -> None: ...
    def on_operator_start(self, ev: Event) -> None: ...
    def on_operator_end(self, ev: Event) -> None: ...
    def on_trace_buffer(self, ev: Event) -> None: ...
