"""Memory-timeline tool (paper §V-D, Figs. 14–15).

Tracks live bytes over event order, per device, with region context — the
ramp-up / peak / ramp-down picture of a training iteration, and the per-device
asymmetries under DP/TP/PP that the paper's multi-GPU case study shows.

Batch consumption is vectorized: per-device live-byte series come from one
``np.cumsum`` over the signed size deltas (alloc +, free −) instead of a
Python callback per event; the resulting series/peaks are identical to the
scalar path because cumsum preserves row order.
"""

from __future__ import annotations

import collections

import numpy as np

from ..events import EventKind, KIND_CODE
from .base import PastaTool, register

_KC_TA = int(KIND_CODE[EventKind.TENSOR_ALLOC])
_KC_TF = int(KIND_CODE[EventKind.TENSOR_FREE])


@register("timeline")
class MemoryTimelineTool(PastaTool):
    EVENTS = (EventKind.TENSOR_ALLOC, EventKind.TENSOR_FREE,
              EventKind.ALLOC, EventKind.FREE, EventKind.STEP_START,
              EventKind.STEP_END)

    def __init__(self, **knobs):
        super().__init__(**knobs)
        self.live: dict = collections.defaultdict(int)      # device -> bytes
        self.series: dict = collections.defaultdict(list)   # device -> [(seq, bytes, region)]
        self.alloc_events: dict = collections.defaultdict(int)
        self.free_events: dict = collections.defaultdict(int)
        self.peak: dict = collections.defaultdict(int)

    # ------------------------------------------------------------- scalar
    def _mark(self, dev, seq, region):
        self.series[dev].append((seq, self.live[dev], "/".join(region)))
        self.peak[dev] = max(self.peak[dev], self.live[dev])

    def on_tensor_alloc(self, ev):
        self.live[ev.device] += ev.size
        self.alloc_events[ev.device] += 1
        self._mark(ev.device, ev.seq, ev.region)

    def on_tensor_free(self, ev):
        self.live[ev.device] -= ev.size
        self.free_events[ev.device] += 1
        self._mark(ev.device, ev.seq, ev.region)

    # ------------------------------------------------------------ batched
    def on_batch(self, batch):
        kinds = batch.kinds
        sel = (kinds == _KC_TA) | (kinds == _KC_TF)
        idx = np.nonzero(sel)[0]
        if idx.size == 0:
            return
        deltas = np.where(kinds[idx] == _KC_TA, batch.sizes[idx],
                          -batch.sizes[idx])
        if isinstance(batch.devices, tuple):
            groups = [(batch.devices, np.arange(idx.size))]
        else:
            by_dev: dict = {}
            for j, i in enumerate(idx):
                by_dev.setdefault(batch.devices[i], []).append(j)
            groups = [(d, np.asarray(js)) for d, js in by_dev.items()]
        for dev, js in groups:
            rows = idx[js]
            lives = self.live[dev] + np.cumsum(deltas[js])
            self.live[dev] = int(lives[-1])
            n_alloc = int((kinds[rows] == _KC_TA).sum())
            self.alloc_events[dev] += n_alloc
            self.free_events[dev] += rows.size - n_alloc
            if isinstance(batch.regions, tuple):
                rg = "/".join(batch.regions)
                regions = [rg] * rows.size
            else:
                regions = ["/".join(batch.regions[i]) for i in rows]
            self.series[dev].extend(
                zip(batch.seqs[rows].tolist(), lives.tolist(), regions))
            self.peak[dev] = max(self.peak[dev], int(lives.max()))

    def finalize(self) -> dict:
        devs = sorted(self.series)
        return {
            "devices": [str(d) for d in devs],
            "peak_bytes": {str(d): self.peak[d] for d in devs},
            "alloc_events": {str(d): self.alloc_events[d] for d in devs},
            "free_events": {str(d): self.free_events[d] for d in devs},
            "series": {str(d): self.series[d] for d in devs},
        }
