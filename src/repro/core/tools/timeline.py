"""Memory-timeline tool (paper §V-D, Figs. 14–15).

Tracks live bytes over event order, per device, with region context — the
ramp-up / peak / ramp-down picture of a training iteration, and the per-device
asymmetries under DP/TP/PP that the paper's multi-GPU case study shows.
"""

from __future__ import annotations

import collections

from ..events import EventKind
from .base import PastaTool


class MemoryTimelineTool(PastaTool):
    EVENTS = (EventKind.TENSOR_ALLOC, EventKind.TENSOR_FREE,
              EventKind.ALLOC, EventKind.FREE, EventKind.STEP_START,
              EventKind.STEP_END)

    def __init__(self, **knobs):
        super().__init__(**knobs)
        self.live: dict = collections.defaultdict(int)      # device -> bytes
        self.series: dict = collections.defaultdict(list)   # device -> [(seq, bytes, region)]
        self.alloc_events: dict = collections.defaultdict(int)
        self.free_events: dict = collections.defaultdict(int)
        self.peak: dict = collections.defaultdict(int)

    def _mark(self, dev, seq, region):
        self.series[dev].append((seq, self.live[dev], "/".join(region)))
        self.peak[dev] = max(self.peak[dev], self.live[dev])

    def on_tensor_alloc(self, ev):
        self.live[ev.device] += ev.size
        self.alloc_events[ev.device] += 1
        self._mark(ev.device, ev.seq, ev.region)

    def on_tensor_free(self, ev):
        self.live[ev.device] -= ev.size
        self.free_events[ev.device] += 1
        self._mark(ev.device, ev.seq, ev.region)

    def finalize(self) -> dict:
        devs = sorted(self.series)
        return {
            "devices": [str(d) for d in devs],
            "peak_bytes": {str(d): self.peak[d] for d in devs},
            "alloc_events": {str(d): self.alloc_events[d] for d in devs},
            "free_events": {str(d): self.free_events[d] for d in devs},
            "series": {str(d): self.series[d] for d in devs},
        }
