"""Serving-lifecycle tool: TTFT/TPOT, batch occupancy, prefix-hit rate.

Consumes the operator events the request-lifecycle :class:`~repro.serve.
engine.ServeEngine` emits — per-request lifecycle markers
(``serve.request.submit`` / ``.admit`` / ``.first_token`` / ``.finish``,
emitted through each request's child session and forwarded to the parent)
and fused phase spans (``serve.prefill`` / ``serve.decode`` on the engine
session) — and reduces them to the serving quantities a continuous-batching
deployment is judged on:

  * **TTFT** (time to first token: submit → first sampled token) and
    **TPOT** (time per output token over the decode tail), as mean/p50/p90,
  * the **batch-occupancy timeline** (active slots per fused decode tick)
    and its mean — decode goodput relative to the slot budget,
  * **prefix-cache reuse**: hit rate over admissions and the fraction of
    prompt tokens skipped at prefill (admissions and cache lookups are 1:1
    in the engine, so this hit rate and ``PrefixCache.stats()`` share the
    same denominator),
  * **prefill-stall accounting**: the prefill work inserted between
    consecutive fused decode ticks, as tokens and seconds — chunked prefill
    exists to bound exactly this quantity,
  * **paged-pool utilization**: block-pool occupancy sampled at every
    decode tick, plus the bytes duplicated for the prefix store (zero on
    the paged path, where the store aliases pool blocks),
  * **speculative-decode accounting**: draft acceptance rate, committed
    tokens per fused decode tick (the quantity speculation exists to raise
    above one-per-slot), and host-side draft overhead seconds,
  * an **analytic bandwidth estimate**: each decode dispatch streams the
    params once plus every active row's touched KV blocks, so
    ``(decode_steps * params_bytes + kv_read_bytes) / committed_tokens``
    is the modeled bytes per generated token — the decode-roofline
    denominator acceptance-rate gains are supposed to shrink,
  * **SLO / multi-tenant accounting**: per-request ``SLOSpec`` tags
    (tenant, priority, TTFT/TPOT targets) arrive on the submit event; the
    report adds per-tenant rollups (requests, tokens, TTFT percentiles,
    attainment), fleet **SLO attainment** (finished requests meeting
    every stated target), **goodput** (tokens from SLO-meeting requests
    per wall second — requests with no targets trivially meet), and the
    **Jain fairness index** over per-tenant generated tokens,
  * **preemption accounting**: ``serve.request.preempt`` events count
    evictions and blocks parked into the prefix store; resumed
    admissions report how many parked blocks aliased back with zero
    recompute (``recovered_blocks``),
  * **health / fault accounting**: every ``serve.fault`` (transient tick
    retries, blamed requests, bisection probes, isolated innocents),
    ``serve.degrade`` (shed/restore ladder moves, degraded ticks),
    ``serve.request.retry`` / ``.timeout`` / ``.failed`` / ``.reject``
    terminal outcome, and the recovered-vs-recomputed token split on
    resumed admissions — the report's ``health`` section accounts for
    every fault-tolerance event the engine emitted.

Attached to the engine's parent session it reports the fleet view; attached
to a request's child session (``request_tools="serving"``) it reports that
one request's lifecycle.
"""

from __future__ import annotations

import numpy as np

from ..events import EventKind
from .base import PastaTool, register


def _pctl(xs: list) -> dict | None:
    if not xs:
        return None
    a = np.asarray(xs, np.float64)
    return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)), "max": float(a.max())}


@register("serving")
class ServingTool(PastaTool):
    EVENTS = (EventKind.OPERATOR_START, EventKind.OPERATOR_END)

    def __init__(self, timeline_limit: int = 512, **knobs):
        super().__init__(**knobs)
        self.timeline_limit = timeline_limit
        self.req: dict = {}                # rid -> lifecycle dict
        self.decode_steps = 0
        self.occupancy_sum = 0
        self.occupancy_max = 0
        self.slots = 0
        self.prefill_events = 0
        self.prefill_tokens = 0
        self.chunked_events = 0
        self.cached_tokens = 0
        # admission-EVENT counters: 1:1 with prefix-cache lookups even when
        # preemption re-admits a request more than once
        self.admit_events = 0
        self.hit_events = 0
        self.admit_prompt_tokens = 0
        # preemption lifecycle: evictions, blocks parked into the prefix
        # store, resumed admissions and the blocks they aliased back
        self.preempt_events = 0
        self.parked_blocks = 0
        self.resumed_admits = 0
        self.recovered_blocks = 0
        # per-tick prefill stall: prefill work inside one scheduler tick
        # (the engine's serve.tick boundary event closes the window)
        self._tick_prefill_tokens = 0
        self._tick_prefill_s = 0.0
        self._prefill_start: float | None = None
        self.max_prefill_tokens_per_tick = 0
        self.max_prefill_stall_s = 0.0
        # paged-pool samples from decode-tick attrs
        self.pool_n_blocks = 0
        self.pool_util_max = 0.0
        self.pool_store_blocks_max = 0
        self.duplicate_copy_bytes = 0
        # speculative decode + analytic bandwidth (decode-end attrs)
        self.spec_k = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.committed_tokens = 0
        self.draft_s = 0.0
        self.params_bytes = 0
        self.kv_read_bytes = 0
        # fault-tolerance accounting (serve.fault / serve.degrade / the
        # terminal retry|timeout|failed|reject lifecycle events)
        self.fault_events = 0
        self.transient_faults = 0
        self.blamed_requests = 0
        self.isolated_innocents = 0
        self.probes = 0
        self.retry_events = 0
        self.timeout_events = 0
        self.failed_events = 0
        self.reject_events = 0
        self.degrade_shed = 0
        self.degrade_restore = 0
        self.degrade_level_max = 0
        self.degraded_ticks = 0
        self.recovered_tokens = 0
        self.recomputed_tokens = 0
        self.timeline: list = []           # (time, phase, active)
        self._t0: float | None = None

    # ------------------------------------------------------------- lifecycle
    def _entry(self, rid) -> dict:
        return self.req.setdefault(int(rid), {})

    def on_operator_start(self, ev):
        a = ev.attrs
        if self._t0 is None:
            self._t0 = ev.time
        name = ev.name
        if name == "serve.request.submit":
            e = self._entry(a["rid"])
            e["submit"] = ev.time
            e["prompt_len"] = int(a.get("prompt_len", 0))
            if "tenant" in a:
                e["tenant"] = a["tenant"]
                e["priority"] = int(a.get("priority", 0))
                e["ttft_target"] = a.get("ttft_target_s")
                e["tpot_target"] = a.get("tpot_target_s")
        elif name == "serve.request.admit":
            e = self._entry(a["rid"])
            # queue_s / TTFT anchor on the FIRST admission; later (resumed)
            # admissions only update the reuse/recovery counters
            e.setdefault("admit", ev.time)
            e["cached"] = int(a.get("cached_tokens", 0))
            e["slot"] = a.get("slot")
            self.admit_events += 1
            self.hit_events += int(a.get("cached_tokens", 0)) > 0
            self.admit_prompt_tokens += int(a.get("prompt_len", 0))
            if a.get("resumed"):
                self.resumed_admits += 1
                rec = int(a.get("recovered_blocks", 0))
                self.recovered_blocks += rec
                e["recovered_blocks"] = e.get("recovered_blocks", 0) + rec
                self.recovered_tokens += int(a.get("cached_tokens", 0))
                self.recomputed_tokens += int(a.get("recomputed_tokens", 0))
        elif name == "serve.request.retry":
            e = self._entry(a["rid"])
            e["retries"] = int(a.get("retries", 0))
            self.retry_events += 1
        elif name == "serve.request.timeout":
            self._entry(a["rid"])["status"] = "timeout"
            self.timeout_events += 1
        elif name == "serve.request.failed":
            self._entry(a["rid"])["status"] = "failed"
            self.failed_events += 1
        elif name == "serve.request.reject":
            self._entry(a["rid"])["status"] = "rejected"
            self.reject_events += 1
        elif name == "serve.fault":
            self.fault_events += 1
            self.transient_faults += bool(a.get("transient", False))
            self.blamed_requests += len(a.get("blamed", ()))
            self.isolated_innocents += len(a.get("isolated", ()))
            self.probes += int(a.get("probes", 0))
        elif name == "serve.degrade":
            if a.get("direction") == "shed":
                self.degrade_shed += 1
                self.degrade_level_max = max(self.degrade_level_max,
                                             int(a.get("level", 0)))
            else:
                self.degrade_restore += 1
        elif name == "serve.request.preempt":
            e = self._entry(a["rid"])
            e["preempts"] = e.get("preempts", 0) + 1
            self.preempt_events += 1
            self.parked_blocks += int(a.get("parked_blocks", 0))
        elif name == "serve.request.first_token":
            self._entry(a["rid"])["first"] = ev.time
        elif name == "serve.request.finish":
            e = self._entry(a["rid"])
            e["finish"] = ev.time
            e["n_tokens"] = int(a.get("n_tokens", 0))
            e["drafted"] = int(a.get("drafted", 0))
            e["accepted"] = int(a.get("accepted", 0))
        elif name == "serve.decode":
            active = int(a.get("active", 0))
            self.decode_steps += 1
            self.occupancy_sum += active
            self.occupancy_max = max(self.occupancy_max, active)
            self.slots = int(a.get("slots", self.slots))
            if "utilization" in a:
                self.pool_n_blocks = int(a.get("n_blocks", 0))
                self.pool_util_max = max(self.pool_util_max,
                                         float(a["utilization"]))
                self.pool_store_blocks_max = max(
                    self.pool_store_blocks_max, int(a.get("store_blocks", 0)))
            if len(self.timeline) < self.timeline_limit:
                self.timeline.append((ev.time - self._t0, "decode", active))
        elif name == "serve.prefill":
            self.prefill_events += 1
            n = int(a.get("n_tokens", 0))
            self.prefill_tokens += n
            self._tick_prefill_tokens += n
            self._prefill_start = ev.time
            self.chunked_events += bool(a.get("chunked", False))
            self.cached_tokens += int(a.get("cached", 0))
            if len(self.timeline) < self.timeline_limit:
                self.timeline.append((ev.time - self._t0, "prefill",
                                      int(a.get("group", 1))))
        elif name == "serve.tick":
            self.degraded_ticks += int(a.get("degrade_level", 0)) > 0
            self._close_tick()

    def on_operator_end(self, ev):
        if ev.name == "serve.prefill":
            if self._prefill_start is not None:
                self._tick_prefill_s += ev.time - self._prefill_start
                self._prefill_start = None
            self.duplicate_copy_bytes += int(
                ev.attrs.get("copied_bytes", 0))
        elif ev.name == "serve.decode":
            a = ev.attrs
            # non-speculative ticks commit one token per active slot
            self.committed_tokens += int(a.get("committed",
                                               a.get("active", 0)))
            self.spec_k = max(self.spec_k, int(a.get("spec_k", 0)))
            self.drafted_tokens += int(a.get("drafted", 0))
            self.accepted_tokens += int(a.get("accepted", 0))
            self.draft_s += float(a.get("draft_s", 0.0))
            self.params_bytes = int(a.get("params_bytes", self.params_bytes))
            self.kv_read_bytes += int(a.get("kv_read_bytes", 0))

    def _close_tick(self) -> None:
        """Fold the prefill work accumulated since the last decode dispatch
        into the per-tick stall maxima."""
        self.max_prefill_tokens_per_tick = max(
            self.max_prefill_tokens_per_tick, self._tick_prefill_tokens)
        self.max_prefill_stall_s = max(self.max_prefill_stall_s,
                                       self._tick_prefill_s)
        self._tick_prefill_tokens = 0
        self._tick_prefill_s = 0.0

    # -------------------------------------------------------------- finalize
    def finalize(self) -> dict:
        self._close_tick()       # a trailing prefill-only tick still counts
        ttft, tpot, queue, per_request = [], [], [], {}
        finished = 0
        generated = 0
        good_tokens = 0
        slo_met_n = 0
        tenants: dict = {}
        t_last = self._t0 or 0.0
        for rid, e in sorted(self.req.items()):
            tenant = e.get("tenant", "default")
            row = {"prompt_len": e.get("prompt_len", 0),
                   "cached_tokens": e.get("cached", 0),
                   "n_tokens": e.get("n_tokens", 0),
                   "drafted": e.get("drafted", 0),
                   "accepted": e.get("accepted", 0),
                   "tenant": tenant,
                   "preempts": e.get("preempts", 0),
                   "retries": e.get("retries", 0),
                   "status": e.get("status",
                                   "finished" if "finish" in e
                                   else "incomplete")}
            tn = tenants.setdefault(tenant, {
                "requests": 0, "finished": 0, "generated_tokens": 0,
                "good_tokens": 0, "slo_met": 0, "preempts": 0,
                "_ttft": [], "_queue": []})
            tn["requests"] += 1
            tn["preempts"] += row["preempts"]
            if "admit" in e and "submit" in e:
                row["queue_s"] = e["admit"] - e["submit"]
                queue.append(row["queue_s"])
                tn["_queue"].append(row["queue_s"])
            if "first" in e and "submit" in e:
                row["ttft_s"] = e["first"] - e["submit"]
                ttft.append(row["ttft_s"])
                tn["_ttft"].append(row["ttft_s"])
            if "finish" in e:
                finished += 1
                tn["finished"] += 1
                generated += e.get("n_tokens", 0)
                tn["generated_tokens"] += e.get("n_tokens", 0)
                t_last = max(t_last, e["finish"])
                if "first" in e and e.get("n_tokens", 0) > 1:
                    row["tpot_s"] = (e["finish"] - e["first"]) \
                        / (e["n_tokens"] - 1)
                    tpot.append(row["tpot_s"])
                # a finished request meets its SLO iff every STATED target
                # holds; untagged/targetless requests trivially meet, so
                # goodput degenerates to throughput without SLOs
                met = True
                tt = e.get("ttft_target")
                if tt is not None and row.get("ttft_s", 0.0) > tt:
                    met = False
                pt = e.get("tpot_target")
                if pt is not None and row.get("tpot_s", 0.0) > pt:
                    met = False
                row["slo_met"] = met
                if met:
                    slo_met_n += 1
                    tn["slo_met"] += 1
                    good_tokens += e.get("n_tokens", 0)
                    tn["good_tokens"] += e.get("n_tokens", 0)
            per_request[rid] = row
        span = max(t_last - (self._t0 or 0.0), 0.0)
        by_tenant = {}
        for name, tn in sorted(tenants.items()):
            by_tenant[name] = {
                "requests": tn["requests"],
                "finished": tn["finished"],
                "generated_tokens": tn["generated_tokens"],
                "ttft_s": _pctl(tn["_ttft"]),
                "queue_s": _pctl(tn["_queue"]),
                "slo_attainment": (tn["slo_met"] / tn["finished"]
                                   if tn["finished"] else None),
                "goodput_tok_per_s": (tn["good_tokens"] / span
                                      if span > 0 else 0.0),
                "preemptions": tn["preempts"],
            }
        # Jain's index over per-tenant generated tokens: 1.0 = perfectly
        # even service, 1/n = one tenant got everything
        shares = [tn["generated_tokens"] for tn in tenants.values()
                  if tn["finished"]]
        jain = ((sum(shares) ** 2 / (len(shares) * sum(x * x
                                                       for x in shares)))
                if shares and any(shares) else None)
        return {
            "requests": len(self.req),
            "finished": finished,
            "generated_tokens": generated,
            "tok_per_s": generated / span if span > 0 else 0.0,
            "ttft_s": _pctl(ttft),
            "tpot_s": _pctl(tpot),
            "queue_s": _pctl(queue),
            "decode_steps": self.decode_steps,
            "occupancy": {
                "mean": (self.occupancy_sum / self.decode_steps
                         if self.decode_steps else 0.0),
                "max": self.occupancy_max,
                "slots": self.slots,
            },
            "prefill": {"events": self.prefill_events,
                        "tokens": self.prefill_tokens,
                        "chunked_events": self.chunked_events,
                        "max_tokens_per_tick":
                            self.max_prefill_tokens_per_tick,
                        "max_stall_s": self.max_prefill_stall_s},
            "pool": {"n_blocks": self.pool_n_blocks,
                     "utilization_max": self.pool_util_max,
                     "store_blocks_max": self.pool_store_blocks_max,
                     "duplicate_copy_bytes": self.duplicate_copy_bytes},
            "speculative": {
                "spec_k": self.spec_k,
                "drafted_tokens": self.drafted_tokens,
                "accepted_tokens": self.accepted_tokens,
                "acceptance_rate": (self.accepted_tokens
                                    / self.drafted_tokens
                                    if self.drafted_tokens else 0.0),
                "committed_tokens": self.committed_tokens,
                "tokens_per_tick": (self.committed_tokens
                                    / self.decode_steps
                                    if self.decode_steps else 0.0),
                "draft_overhead_s": self.draft_s,
            },
            "bandwidth": {
                "params_bytes": self.params_bytes,
                "kv_read_bytes": self.kv_read_bytes,
                "analytic_bytes_per_token": (
                    (self.decode_steps * self.params_bytes
                     + self.kv_read_bytes) / self.committed_tokens
                    if self.committed_tokens else 0.0),
            },
            "prefix_cache": {
                "admits": self.admit_events,
                "hits": self.hit_events,
                "hit_rate": (self.hit_events / self.admit_events
                             if self.admit_events else 0.0),
                "reused_tokens": self.cached_tokens,
                "reused_frac": (self.cached_tokens
                                / self.admit_prompt_tokens
                                if self.admit_prompt_tokens else 0.0),
            },
            "slo": {
                "attainment": (slo_met_n / finished if finished else None),
                "good_tokens": good_tokens,
                "goodput_tok_per_s": (good_tokens / span
                                      if span > 0 else 0.0),
                "jain_fairness": jain,
            },
            "preemption": {
                "count": self.preempt_events,
                "parked_blocks": self.parked_blocks,
                "resumed": self.resumed_admits,
                "recovered_blocks": self.recovered_blocks,
            },
            "health": {
                "fault_events": self.fault_events,
                "transient_faults": self.transient_faults,
                "blamed_requests": self.blamed_requests,
                "isolated_innocents": self.isolated_innocents,
                "probes": self.probes,
                "retries": self.retry_events,
                "timeouts": self.timeout_events,
                "failed": self.failed_events,
                "rejections": self.reject_events,
                "degrade": {"shed_events": self.degrade_shed,
                            "restore_events": self.degrade_restore,
                            "level_max": self.degrade_level_max,
                            "degraded_ticks": self.degraded_ticks},
                "recovered_tokens": self.recovered_tokens,
                "recomputed_tokens": self.recomputed_tokens,
            },
            "tenants": by_tenant,
            "by_request": per_request,
            "series": self.timeline,
        }
