"""Serving-lifecycle tool: TTFT/TPOT, batch occupancy, prefix-hit rate.

Consumes the operator events the request-lifecycle :class:`~repro.serve.
engine.ServeEngine` emits — per-request lifecycle markers
(``serve.request.submit`` / ``.admit`` / ``.first_token`` / ``.finish``,
emitted through each request's child session and forwarded to the parent)
and fused phase spans (``serve.prefill`` / ``serve.decode`` on the engine
session) — and reduces them to the serving quantities a continuous-batching
deployment is judged on:

  * **TTFT** (time to first token: submit → first sampled token) and
    **TPOT** (time per output token over the decode tail), as mean/p50/p90,
  * the **batch-occupancy timeline** (active slots per fused decode tick)
    and its mean — decode goodput relative to the slot budget,
  * **prefix-cache reuse**: hit rate over admissions and the fraction of
    prompt tokens skipped at prefill (admissions and cache lookups are 1:1
    in the engine, so this hit rate and ``PrefixCache.stats()`` share the
    same denominator),
  * **prefill-stall accounting**: the prefill work inserted between
    consecutive fused decode ticks, as tokens and seconds — chunked prefill
    exists to bound exactly this quantity,
  * **paged-pool utilization**: block-pool occupancy sampled at every
    decode tick, plus the bytes duplicated for the prefix store (zero on
    the paged path, where the store aliases pool blocks),
  * **speculative-decode accounting**: draft acceptance rate, committed
    tokens per fused decode tick (the quantity speculation exists to raise
    above one-per-slot), and host-side draft overhead seconds,
  * an **analytic bandwidth estimate**: each decode dispatch streams the
    params once plus every active row's touched KV blocks, so
    ``(decode_steps * params_bytes + kv_read_bytes) / committed_tokens``
    is the modeled bytes per generated token — the decode-roofline
    denominator acceptance-rate gains are supposed to shrink.

Attached to the engine's parent session it reports the fleet view; attached
to a request's child session (``request_tools="serving"``) it reports that
one request's lifecycle.
"""

from __future__ import annotations

import numpy as np

from ..events import EventKind
from .base import PastaTool, register


def _pctl(xs: list) -> dict | None:
    if not xs:
        return None
    a = np.asarray(xs, np.float64)
    return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)), "max": float(a.max())}


@register("serving")
class ServingTool(PastaTool):
    EVENTS = (EventKind.OPERATOR_START, EventKind.OPERATOR_END)

    def __init__(self, timeline_limit: int = 512, **knobs):
        super().__init__(**knobs)
        self.timeline_limit = timeline_limit
        self.req: dict = {}                # rid -> lifecycle dict
        self.decode_steps = 0
        self.occupancy_sum = 0
        self.occupancy_max = 0
        self.slots = 0
        self.prefill_events = 0
        self.prefill_tokens = 0
        self.chunked_events = 0
        self.cached_tokens = 0
        # per-tick prefill stall: prefill work inside one scheduler tick
        # (the engine's serve.tick boundary event closes the window)
        self._tick_prefill_tokens = 0
        self._tick_prefill_s = 0.0
        self._prefill_start: float | None = None
        self.max_prefill_tokens_per_tick = 0
        self.max_prefill_stall_s = 0.0
        # paged-pool samples from decode-tick attrs
        self.pool_n_blocks = 0
        self.pool_util_max = 0.0
        self.pool_store_blocks_max = 0
        self.duplicate_copy_bytes = 0
        # speculative decode + analytic bandwidth (decode-end attrs)
        self.spec_k = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.committed_tokens = 0
        self.draft_s = 0.0
        self.params_bytes = 0
        self.kv_read_bytes = 0
        self.timeline: list = []           # (time, phase, active)
        self._t0: float | None = None

    # ------------------------------------------------------------- lifecycle
    def _entry(self, rid) -> dict:
        return self.req.setdefault(int(rid), {})

    def on_operator_start(self, ev):
        a = ev.attrs
        if self._t0 is None:
            self._t0 = ev.time
        name = ev.name
        if name == "serve.request.submit":
            e = self._entry(a["rid"])
            e["submit"] = ev.time
            e["prompt_len"] = int(a.get("prompt_len", 0))
        elif name == "serve.request.admit":
            e = self._entry(a["rid"])
            e["admit"] = ev.time
            e["cached"] = int(a.get("cached_tokens", 0))
            e["slot"] = a.get("slot")
        elif name == "serve.request.first_token":
            self._entry(a["rid"])["first"] = ev.time
        elif name == "serve.request.finish":
            e = self._entry(a["rid"])
            e["finish"] = ev.time
            e["n_tokens"] = int(a.get("n_tokens", 0))
            e["drafted"] = int(a.get("drafted", 0))
            e["accepted"] = int(a.get("accepted", 0))
        elif name == "serve.decode":
            active = int(a.get("active", 0))
            self.decode_steps += 1
            self.occupancy_sum += active
            self.occupancy_max = max(self.occupancy_max, active)
            self.slots = int(a.get("slots", self.slots))
            if "utilization" in a:
                self.pool_n_blocks = int(a.get("n_blocks", 0))
                self.pool_util_max = max(self.pool_util_max,
                                         float(a["utilization"]))
                self.pool_store_blocks_max = max(
                    self.pool_store_blocks_max, int(a.get("store_blocks", 0)))
            if len(self.timeline) < self.timeline_limit:
                self.timeline.append((ev.time - self._t0, "decode", active))
        elif name == "serve.prefill":
            self.prefill_events += 1
            n = int(a.get("n_tokens", 0))
            self.prefill_tokens += n
            self._tick_prefill_tokens += n
            self._prefill_start = ev.time
            self.chunked_events += bool(a.get("chunked", False))
            self.cached_tokens += int(a.get("cached", 0))
            if len(self.timeline) < self.timeline_limit:
                self.timeline.append((ev.time - self._t0, "prefill",
                                      int(a.get("group", 1))))
        elif name == "serve.tick":
            self._close_tick()

    def on_operator_end(self, ev):
        if ev.name == "serve.prefill":
            if self._prefill_start is not None:
                self._tick_prefill_s += ev.time - self._prefill_start
                self._prefill_start = None
            self.duplicate_copy_bytes += int(
                ev.attrs.get("copied_bytes", 0))
        elif ev.name == "serve.decode":
            a = ev.attrs
            # non-speculative ticks commit one token per active slot
            self.committed_tokens += int(a.get("committed",
                                               a.get("active", 0)))
            self.spec_k = max(self.spec_k, int(a.get("spec_k", 0)))
            self.drafted_tokens += int(a.get("drafted", 0))
            self.accepted_tokens += int(a.get("accepted", 0))
            self.draft_s += float(a.get("draft_s", 0.0))
            self.params_bytes = int(a.get("params_bytes", self.params_bytes))
            self.kv_read_bytes += int(a.get("kv_read_bytes", 0))

    def _close_tick(self) -> None:
        """Fold the prefill work accumulated since the last decode dispatch
        into the per-tick stall maxima."""
        self.max_prefill_tokens_per_tick = max(
            self.max_prefill_tokens_per_tick, self._tick_prefill_tokens)
        self.max_prefill_stall_s = max(self.max_prefill_stall_s,
                                       self._tick_prefill_s)
        self._tick_prefill_tokens = 0
        self._tick_prefill_s = 0.0

    # -------------------------------------------------------------- finalize
    def finalize(self) -> dict:
        self._close_tick()       # a trailing prefill-only tick still counts
        ttft, tpot, queue, per_request = [], [], [], {}
        finished = 0
        generated = 0
        admits = 0
        hits = 0
        prompt_tokens = 0
        t_last = self._t0 or 0.0
        for rid, e in sorted(self.req.items()):
            row = {"prompt_len": e.get("prompt_len", 0),
                   "cached_tokens": e.get("cached", 0),
                   "n_tokens": e.get("n_tokens", 0),
                   "drafted": e.get("drafted", 0),
                   "accepted": e.get("accepted", 0)}
            if "admit" in e:
                admits += 1
                hits += e.get("cached", 0) > 0
                prompt_tokens += e.get("prompt_len", 0)
                if "submit" in e:
                    row["queue_s"] = e["admit"] - e["submit"]
                    queue.append(row["queue_s"])
            if "first" in e and "submit" in e:
                row["ttft_s"] = e["first"] - e["submit"]
                ttft.append(row["ttft_s"])
            if "finish" in e:
                finished += 1
                generated += e.get("n_tokens", 0)
                t_last = max(t_last, e["finish"])
                if "first" in e and e.get("n_tokens", 0) > 1:
                    row["tpot_s"] = (e["finish"] - e["first"]) \
                        / (e["n_tokens"] - 1)
                    tpot.append(row["tpot_s"])
            per_request[rid] = row
        span = max(t_last - (self._t0 or 0.0), 0.0)
        return {
            "requests": len(self.req),
            "finished": finished,
            "generated_tokens": generated,
            "tok_per_s": generated / span if span > 0 else 0.0,
            "ttft_s": _pctl(ttft),
            "tpot_s": _pctl(tpot),
            "queue_s": _pctl(queue),
            "decode_steps": self.decode_steps,
            "occupancy": {
                "mean": (self.occupancy_sum / self.decode_steps
                         if self.decode_steps else 0.0),
                "max": self.occupancy_max,
                "slots": self.slots,
            },
            "prefill": {"events": self.prefill_events,
                        "tokens": self.prefill_tokens,
                        "chunked_events": self.chunked_events,
                        "max_tokens_per_tick":
                            self.max_prefill_tokens_per_tick,
                        "max_stall_s": self.max_prefill_stall_s},
            "pool": {"n_blocks": self.pool_n_blocks,
                     "utilization_max": self.pool_util_max,
                     "store_blocks_max": self.pool_store_blocks_max,
                     "duplicate_copy_bytes": self.duplicate_copy_bytes},
            "speculative": {
                "spec_k": self.spec_k,
                "drafted_tokens": self.drafted_tokens,
                "accepted_tokens": self.accepted_tokens,
                "acceptance_rate": (self.accepted_tokens
                                    / self.drafted_tokens
                                    if self.drafted_tokens else 0.0),
                "committed_tokens": self.committed_tokens,
                "tokens_per_tick": (self.committed_tokens
                                    / self.decode_steps
                                    if self.decode_steps else 0.0),
                "draft_overhead_s": self.draft_s,
            },
            "bandwidth": {
                "params_bytes": self.params_bytes,
                "kv_read_bytes": self.kv_read_bytes,
                "analytic_bytes_per_token": (
                    (self.decode_steps * self.params_bytes
                     + self.kv_read_bytes) / self.committed_tokens
                    if self.committed_tokens else 0.0),
            },
            "prefix_cache": {
                "admits": admits,
                "hits": int(hits),
                "hit_rate": hits / admits if admits else 0.0,
                "reused_tokens": self.cached_tokens,
                "reused_frac": (self.cached_tokens / prompt_tokens
                                if prompt_tokens else 0.0),
            },
            "by_request": per_request,
            "series": self.timeline,
        }
