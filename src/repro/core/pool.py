"""Virtual caching allocator — the pool-based memory model (paper §V-C1).

DL frameworks allocate large *memory objects* from the driver and sub-allocate
individual *tensors* inside them (PyTorch's caching allocator; XLA's buffer
assignment behaves similarly with arenas).  PASTA's key UVM insight is that
object granularity != tensor granularity: one object holds many tensors with
different lifetimes, so object-level prefetch/offload decisions are suboptimal
under memory pressure.

This module models that address space faithfully: a best-fit free-list
sub-allocator inside 2 MiB-aligned chunks, emitting ALLOC / TENSOR_ALLOC /
TENSOR_FREE events.  It does *not* allocate device memory — JAX/XLA owns the
real buffers — it mirrors their lifetimes so the analysis tools can reason
about addresses, blocks, and reuse exactly the way the paper's tools do.

Deliberate quirk kept from real runtimes: TENSOR_FREE events are emitted with
a *negative* size delta (some runtimes report deallocations that way, per the
paper's normalization discussion); the event processor normalizes the sign.
"""

from __future__ import annotations

import bisect
import dataclasses
import itertools

from .events import EventKind, next_seq

CHUNK_ALIGN = 2 * 1024 * 1024        # 2 MiB — UVM/hotness block granularity
TENSOR_ROUND = 512                   # PyTorch-style 512 B rounding


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass
class TensorHandle:
    tid: int
    name: str
    addr: int
    size: int            # rounded, bytes
    nbytes: int          # requested, bytes
    object_id: int
    alloc_seq: int
    free_seq: int = -1

    @property
    def live(self) -> bool:
        return self.free_seq < 0

    def addr_range(self) -> tuple:
        return (self.addr, self.addr + self.size)


@dataclasses.dataclass
class MemoryObject:
    """One pool chunk (a ``cudaMalloc``-analogue memory object)."""

    oid: int
    base: int
    size: int
    # free blocks as sorted list of (addr, size)
    free_blocks: list = dataclasses.field(default_factory=list)
    used: int = 0

    def __post_init__(self):
        if not self.free_blocks:
            self.free_blocks = [(self.base, self.size)]

    def fit(self, size: int) -> int | None:
        """Best-fit block address or None."""
        best = None
        for addr, bsz in self.free_blocks:
            if bsz >= size and (best is None or bsz < best[1]):
                best = (addr, bsz)
        return best[0] if best else None

    def carve(self, addr: int, size: int) -> None:
        for i, (a, bsz) in enumerate(self.free_blocks):
            if a == addr:
                assert bsz >= size
                self.free_blocks.pop(i)
                if bsz > size:
                    self.free_blocks.append((a + size, bsz - size))
                    self.free_blocks.sort()
                self.used += size
                return
        raise ValueError("carve from non-free address")

    def release(self, addr: int, size: int) -> None:
        bisect.insort(self.free_blocks, (addr, size))
        self.used -= size
        # coalesce neighbours
        merged = []
        for a, s in self.free_blocks:
            if merged and merged[-1][0] + merged[-1][1] == a:
                merged[-1] = (merged[-1][0], merged[-1][1] + s)
            else:
                merged.append((a, s))
        self.free_blocks = [tuple(b) for b in merged]


class MemoryPool:
    """Caching allocator model emitting PASTA memory events."""

    def __init__(self, handler=None, chunk_size: int = 32 * 1024 * 1024,
                 device: tuple = (), align: int = CHUNK_ALIGN):
        self._handler = handler
        self.chunk_size = chunk_size
        self.align = align
        self.device = device
        self.objects: dict[int, MemoryObject] = {}
        self.tensors: dict[int, TensorHandle] = {}
        self._next_addr = CHUNK_ALIGN          # never hand out address 0
        self._oid = itertools.count()
        self._tid = itertools.count()
        self.peak_bytes = 0
        self.live_bytes = 0

    @property
    def handler(self):
        """The pool's event sink.  A pool constructed without an explicit
        handler resolves the innermost active session *at emit time*, so one
        pool composes with nested/scoped sessions."""
        if self._handler is not None:
            return self._handler
        from .session import current_handler
        return current_handler()

    # ----------------------------------------------------------------- chunks
    def _new_object(self, min_size: int) -> MemoryObject:
        size = _round_up(max(min_size, self.chunk_size), self.align)
        base = self._next_addr
        self._next_addr += size + self.align    # guard gap between objects
        obj = MemoryObject(next(self._oid), base, size)
        self.objects[obj.oid] = obj
        self.handler.emit_row(EventKind.ALLOC, name=f"object{obj.oid}",
                              size=size, addr=base, device=self.device,
                              attrs={"object_id": obj.oid})
        return obj

    # ---------------------------------------------------------------- tensors
    def alloc(self, nbytes: int, name: str = "") -> TensorHandle:
        size = _round_up(max(nbytes, 1), TENSOR_ROUND)
        obj = None
        for o in self.objects.values():
            if o.fit(size) is not None:
                obj = o
                break
        if obj is None:
            obj = self._new_object(size)
        addr = obj.fit(size)
        obj.carve(addr, size)
        t = TensorHandle(next(self._tid), name, addr, size, nbytes, obj.oid,
                         alloc_seq=0)
        self.tensors[t.tid] = t
        self.live_bytes += size
        self.peak_bytes = max(self.peak_bytes, self.live_bytes)
        # seq is reserved (and the handle stamped) BEFORE dispatch so
        # subscribers that query the pool during dispatch see a consistent
        # handle state
        t.alloc_seq = next_seq()
        self.handler.emit_row(
            EventKind.TENSOR_ALLOC, name=name or f"tensor{t.tid}",
            size=size, addr=addr, device=self.device, seq=t.alloc_seq,
            attrs={"tensor_id": t.tid, "object_id": obj.oid,
                   "requested": nbytes})
        return t

    def free(self, t: TensorHandle) -> None:
        if not t.live:
            raise ValueError(f"double free of tensor {t.tid}")
        self.objects[t.object_id].release(t.addr, t.size)
        self.live_bytes -= t.size
        # NOTE: raw size is negative on purpose — normalization test surface.
        t.free_seq = next_seq()          # stamp before dispatch (see alloc)
        self.handler.emit_row(
            EventKind.TENSOR_FREE, name=t.name, size=-t.size, addr=t.addr,
            device=self.device, seq=t.free_seq,
            attrs={"tensor_id": t.tid, "object_id": t.object_id})

    # ------------------------------------------------------------------ views
    def live_tensors(self) -> list:
        return [t for t in self.tensors.values() if t.live]

    def object_of(self, addr: int) -> MemoryObject | None:
        for o in self.objects.values():
            if o.base <= addr < o.base + o.size:
                return o
        return None

    def tensor_at(self, addr: int) -> TensorHandle | None:
        for t in self.tensors.values():
            if t.live and t.addr <= addr < t.addr + t.size:
                return t
        return None

    @property
    def footprint(self) -> int:
        """Total bytes of pool objects obtained from the 'driver'."""
        return sum(o.size for o in self.objects.values())
