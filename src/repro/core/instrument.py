"""Eager-execution instrumentation — the DL-framework-callback event source.

The GPU PASTA hooks PyTorch's ``reportMemoryUsage``/``RecordFunction``; the
JAX analogue here tracks *real array lifetimes*: every array first seen at an
operator boundary is registered in the virtual
:class:`~repro.core.pool.MemoryPool` (TENSOR_ALLOC), and a ``weakref``
finalizer frees its pool block when Python drops the array (TENSOR_FREE) —
lifetimes mirror the framework's actual deallocations, which is what makes
the ramp-up/peak/ramp-down timelines (Fig. 14) and working sets (Table V)
faithful.

Fine-grained mode additionally emits access-record TRACE_BUFFERs (addresses
sampled every ``stride`` bytes of each touched tensor) that the event
processor aggregates on device (Fig. 2b) or host (Fig. 2a baseline).

Model code calls :func:`op_hook` at operator boundaries; it is a no-op under
tracing (jit) and when no instrumenter is installed, so the hot path costs
one global check.
"""

from __future__ import annotations

import time
import weakref

import numpy as np

import jax

from .events import Event, EventKind
from .pool import MemoryPool

ACTIVE: "EagerInstrumenter | None" = None


class EagerInstrumenter:
    def __init__(self, handler=None, pool: MemoryPool | None = None,
                 fine: bool = False, stride: int = 512,
                 max_records_per_op: int = 65536,
                 pool_chunk: int = 32 * 1024 * 1024,
                 pool_align: int | None = None,
                 time_source=None, buffered: bool = False):
        from .pool import CHUNK_ALIGN
        if handler is None:
            from .session import current_handler
            handler = current_handler()
        self.handler = handler
        self.pool = pool or MemoryPool(
            handler, chunk_size=pool_chunk,
            align=pool_align if pool_align is not None else CHUNK_ALIGN)
        self.fine = fine
        self.stride = stride
        self.max_records = max_records_per_op
        self._tensors: dict = {}          # id(arr) -> TensorHandle
        self.t0 = time.perf_counter()
        self.time_source = time_source
        #: batch operator/tensor/trace events through the handler's SoA ring
        #: (flushed at step boundaries and capacity); leave off for tools
        #: that need synchronous per-event context (e.g. LocatorTool's
        #: Python-stack capture at emit time).
        self.buffered = buffered
        self._prev_buffered = False

    # ------------------------------------------------------------ lifetime
    def tensor(self, arr, name: str = ""):
        key = id(arr)
        h = self._tensors.get(key)
        if h is not None:
            return h
        h = self.pool.alloc(arr.nbytes, name or f"t{key & 0xffff:x}")
        self._tensors[key] = h
        weakref.finalize(arr, self._on_free, key)
        return h

    def _on_free(self, key) -> None:
        h = self._tensors.pop(key, None)
        if h is not None and h.live:
            self.pool.free(h)

    # ------------------------------------------------------------------ op
    def op(self, name: str, inputs, outputs) -> None:
        handles = [self.tensor(a, f"{name}.in{i}")
                   for i, a in enumerate(inputs)]
        handles += [self.tensor(a, f"{name}.out{i}")
                    for i, a in enumerate(outputs)]
        tensors = [(h.addr, h.size) for h in handles]
        self.handler.operator_start(name, tensors=tensors, traced=self.fine)
        if self.fine:
            self._emit_trace(name, handles)
        self.handler.operator_end(name)

    def _emit_trace(self, name: str, handles) -> None:
        recs = []
        for h in handles:
            n = max(1, min(h.size // self.stride,
                           self.max_records // max(len(handles), 1)))
            recs.append(h.addr + (np.arange(n, dtype=np.int64)
                                  * self.stride) % h.size)
        addrs = np.concatenate(recs)
        # access-verified granularity = live TENSOR ranges (the paper's
        # object-to-access map at allocator granularity), NOT pool chunks —
        # this is exactly the tensor-vs-object distinction of §V-C1.
        objs = sorted(t.addr_range() for t in self.pool.live_tensors())
        self.handler.trace_buffer(
            addrs, name=name, kernel=name, objects=objs,
            object_sizes=[e - s for s, e in objs],
            time=(self.time_source() if self.time_source
                  else time.perf_counter() - self.t0))

    # ------------------------------------------------------------- control
    def __enter__(self):
        global ACTIVE
        self._prev = ACTIVE
        ACTIVE = self
        self._prev_buffered = self.handler.buffered
        if self.buffered:
            self.handler.set_buffered(True)
        return self

    def __exit__(self, *exc):
        global ACTIVE
        ACTIVE = self._prev
        if self.buffered:
            self.handler.flush()
            self.handler.set_buffered(self._prev_buffered)


def op_hook(name: str, inputs, outputs) -> None:
    """Call at operator boundaries in model code. No-op under jit tracing."""
    inst = ACTIVE
    if inst is None:
        return
    arrays = [a for a in (*inputs, *outputs) if hasattr(a, "nbytes")]
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        return
    inst.op(name, [a for a in inputs if hasattr(a, "nbytes")],
            [a for a in outputs if hasattr(a, "nbytes")])
