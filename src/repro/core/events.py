"""PASTA event vocabulary — the Table-II analogue for TPU/JAX.

The paper's event taxonomy has three tiers:

  * coarse-grained host-called API events (kernel launch, memcpy, sync, ...)
  * fine-grained device-side operations (per-thread memory accesses, ...)
  * high-level DL framework events (operator begin/end, tensor alloc, ...)

On TPU there is no per-instruction instrumentation surface, so the fine-grained
tier is carried by *trace buffers* (structured arrays of access records that are
aggregated on device — see ``repro.kernels``) rather than one Python object per
access.  Everything else maps 1:1.

The coarse-grained tier itself is columnar: the canonical in-flight
representation is :class:`EventBatch`, a structure-of-arrays batch (parallel
numpy columns for kind/step/time/size/addr/seq, dictionary-encoded names, and
a side table for attrs/device/region).  :class:`Event` remains the scalar
view — one row — kept for authoring convenience and API compatibility; the
handler wraps scalar emits into one-row batches.
"""

from __future__ import annotations

import dataclasses
import enum
import time as _time
from typing import Any, Iterable, Iterator

import numpy as np


class EventKind(enum.Enum):
    # --- low-level, coarse-grained (host-called API analogues) -------------
    KERNEL_LAUNCH = "kernel_launch"        # one top-level HLO instruction
    MEMCPY = "memcpy"
    MEMSET = "memset"
    SYNC = "sync"
    ALLOC = "alloc"                        # device memory object (pool chunk)
    FREE = "free"
    COLLECTIVE = "collective"              # all-reduce / all-gather / ...
    COMPILE = "compile"                    # XLA compilation finished
    # --- low-level, fine-grained (device-side) -----------------------------
    TRACE_BUFFER = "trace_buffer"          # handle to a device access-record
                                           # buffer; aggregated by processor
    # --- high-level DL framework events -------------------------------------
    OPERATOR_START = "operator_start"
    OPERATOR_END = "operator_end"
    TENSOR_ALLOC = "tensor_alloc"
    TENSOR_FREE = "tensor_free"
    REGION_START = "region_start"          # pasta.start()/pasta.end()
    REGION_END = "region_end"
    STEP_START = "step_start"
    STEP_END = "step_end"
    FINDING = "finding"                    # static-analysis lint finding
                                           # (repro.analysis pass output)


#: stable integer codes for the columnar ``kind`` column
KIND_LIST = list(EventKind)
KIND_CODE = {k: np.int16(i) for i, k in enumerate(KIND_LIST)}

#: kinds whose ``size`` field is known to arrive with inconsistent sign
#: conventions across backends (the paper's normalization example: some
#: runtimes report deallocation sizes as negative deltas).
_SIGNED_SIZE_KINDS = (EventKind.FREE, EventKind.TENSOR_FREE)
_SIGNED_CODES = np.asarray([int(KIND_CODE[k]) for k in _SIGNED_SIZE_KINDS],
                           dtype=np.int16)


class _SeqCounter:
    """Monotone event sequence counter with O(1) bulk reservation for
    columnar producers (``take(n)`` hands out a contiguous id range)."""

    __slots__ = ("n",)

    def __init__(self, start: int = 0):
        self.n = start

    def __next__(self) -> int:
        v = self.n
        self.n += 1
        return v

    def take(self, count: int) -> np.ndarray:
        v = self.n
        self.n += count
        return np.arange(v, v + count, dtype=np.int64)


_seq = _SeqCounter()


def reset_seq() -> None:
    """Reset the global sequence counter (test isolation)."""
    global _seq
    _seq = _SeqCounter()


def take_seqs(count: int) -> np.ndarray:
    """Reserve ``count`` contiguous sequence numbers (columnar emit path)."""
    return _seq.take(count)


def next_seq() -> int:
    """Reserve one sequence number (for producers that need the seq before
    emitting, e.g. to stamp their own bookkeeping first)."""
    return next(_seq)


def _intern(name: str, table: list, index: dict) -> int:
    """Dictionary-encode ``name`` against table/index (shared by every
    batch/ring producer so the encoded column stays consistent)."""
    nid = index.get(name)
    if nid is None:
        nid = index[name] = len(table)
        table.append(name)
    return nid


@dataclasses.dataclass
class Event:
    """A single normalized-or-raw PASTA event (scalar row view)."""

    kind: EventKind
    name: str = ""
    step: int = -1
    time: float = dataclasses.field(default_factory=_time.perf_counter)
    device: tuple = ()            # mesh coordinates, e.g. (pod, data, model)
    size: int = 0                 # bytes (sign-normalized by the processor)
    addr: int = 0                 # virtual address (pool-modelled)
    region: tuple = ()            # annotation stack snapshot
    attrs: dict = dataclasses.field(default_factory=dict)
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    normalized: bool = False

    def with_attrs(self, **kw: Any) -> "Event":
        self.attrs.update(kw)
        return self


def codes_for(kinds: Iterable) -> np.ndarray | None:
    """Map a tool-style EVENTS tuple (EventKinds, value strings, or "*") to
    an int16 code array; ``None`` means "all kinds"."""
    out = []
    for k in kinds:
        if k == "*":
            return None
        out.append(int(KIND_CODE[k if isinstance(k, EventKind)
                                 else EventKind(k)]))
    return np.asarray(out, dtype=np.int16)


class EventBatch:
    """Structure-of-arrays batch of events — the columnar event backbone.

    Numeric per-row state lives in parallel numpy columns; names are
    dictionary-encoded against ``name_table``; rarely-populated state (attrs
    dicts) lives in an optional side table (``attrs is None`` ⇒ no row in the
    batch carries attrs — the fast path).  ``devices``/``regions`` are either
    a single tuple shared by every row (the common case) or per-row lists.
    """

    __slots__ = ("kinds", "steps", "times", "sizes", "addrs", "seqs",
                 "name_ids", "name_table", "attrs", "devices", "regions",
                 "counts", "normalized", "_events")

    def __init__(self, kinds, steps, times, sizes, addrs, seqs, name_ids,
                 name_table, attrs=None, devices=(), regions=(), counts=None,
                 normalized=False, events=None):
        self.kinds = kinds
        self.steps = steps
        self.times = times
        self.sizes = sizes
        self.addrs = addrs
        self.seqs = seqs
        self.name_ids = name_ids
        self.name_table = name_table
        self.attrs = attrs
        self.devices = devices
        self.regions = regions
        self.counts = counts          # filled by EventProcessor.normalize_batch
        self.normalized = normalized
        self._events = events         # scalar-origin Event rows (identity)

    # ------------------------------------------------------------- builders
    @classmethod
    def of(cls, kind, n: int | None = None, names=None, name_ids=None,
           name_table=None, steps=None, times=None, sizes=None, addrs=None,
           seqs=None, attrs=None, device=(), region=()) -> "EventBatch":
        """Vectorized batch construction for columnar producers.

        ``kind`` is one EventKind (broadcast) or a per-row code array.
        Names are passed either as a per-row string list (``names``) or
        pre-encoded as ``name_ids`` + ``name_table``.  Omitted columns get
        cheap defaults; ``seqs`` defaults to a fresh contiguous reservation
        from the global counter.
        """
        cols = (("kind", None if isinstance(kind, EventKind) else kind),
                ("names", names), ("name_ids", name_ids), ("steps", steps),
                ("times", times), ("sizes", sizes), ("addrs", addrs),
                ("seqs", seqs), ("attrs", attrs))
        for _label, col in cols:
            if col is not None:
                n = len(col)
                break
        else:
            if n is None:
                raise ValueError("cannot infer batch length; pass n=")
        for label, col in cols:
            if col is not None and len(col) != n:
                raise ValueError(
                    f"column {label!r} has length {len(col)}, expected {n}")
        if isinstance(kind, EventKind):
            kinds = np.full(n, KIND_CODE[kind], dtype=np.int16)
        else:
            kinds = np.asarray(kind, dtype=np.int16)
        if name_ids is None:
            if names is None:
                name_ids = np.zeros(n, dtype=np.int32)
                name_table = [""]
            elif n == 0:
                name_ids = np.zeros(0, dtype=np.int32)
                name_table = []
            else:
                # vectorized dictionary encoding: one np.unique pass over a
                # fixed-width string array instead of a per-row _intern loop
                # (the table comes out sorted rather than
                # first-appearance-ordered — ids are opaque)
                uniq, inverse = np.unique(np.asarray(names),
                                          return_inverse=True)
                name_table = uniq.tolist()
                name_ids = inverse.astype(np.int32)
        else:
            name_ids = np.asarray(name_ids, dtype=np.int32)
            name_table = list(name_table if name_table is not None else [])
        mk = lambda col, dtype, fill: (  # noqa: E731
            np.full(n, fill, dtype=dtype) if col is None
            else np.asarray(col, dtype=dtype))
        return cls(
            kinds=kinds,
            steps=mk(steps, np.int64, -1),
            times=(np.full(n, _time.perf_counter(), dtype=np.float64)
                   if times is None else np.asarray(times, np.float64)),
            sizes=mk(sizes, np.int64, 0),
            addrs=mk(addrs, np.int64, 0),
            seqs=(take_seqs(n) if seqs is None
                  else np.asarray(seqs, np.int64)),
            name_ids=name_ids, name_table=name_table, attrs=attrs,
            devices=device, regions=region)

    @classmethod
    def from_events(cls, events) -> "EventBatch":
        """Wrap scalar :class:`Event` rows (compatibility path).  Keeps the
        original objects so scalar subscribers observe identical instances
        (attrs dicts are shared, normalization writes back)."""
        events = list(events)
        n = len(events)
        kinds = np.empty(n, dtype=np.int16)
        steps = np.empty(n, dtype=np.int64)
        times = np.empty(n, dtype=np.float64)
        sizes = np.empty(n, dtype=np.int64)
        addrs = np.empty(n, dtype=np.int64)
        seqs = np.empty(n, dtype=np.int64)
        name_ids = np.empty(n, dtype=np.int32)
        name_table: list = []
        index: dict = {}
        attrs = [None] * n
        devices = [()] * n
        regions = [()] * n
        for i, ev in enumerate(events):
            kinds[i] = KIND_CODE[ev.kind]
            steps[i] = ev.step
            times[i] = ev.time
            sizes[i] = ev.size
            addrs[i] = ev.addr
            seqs[i] = ev.seq
            name_ids[i] = _intern(ev.name, name_table, index)
            attrs[i] = ev.attrs
            devices[i] = ev.device
            regions[i] = ev.region
        return cls(kinds, steps, times, sizes, addrs, seqs, name_ids,
                   name_table, attrs=attrs, devices=devices, regions=regions,
                   events=events)

    # ------------------------------------------------------------ accessors
    def __len__(self) -> int:
        return len(self.kinds)

    def name_of(self, i: int) -> str:
        return self.name_table[self.name_ids[i]]

    def device_at(self, i: int) -> tuple:
        d = self.devices
        return d if isinstance(d, tuple) else d[i]

    def region_at(self, i: int) -> tuple:
        r = self.regions
        return r if isinstance(r, tuple) else r[i]

    def attrs_at(self, i: int):
        return None if self.attrs is None else self.attrs[i]

    def attr_column(self, key: str, default=0, rows=None,
                    dtype=None) -> np.ndarray:
        """Gather one attrs key across the side table as a dense column.

        Returns an ndarray aligned with ``rows`` (all rows when ``None``),
        filling ``default`` for rows without attrs or without ``key``.  The
        ``attrs is None`` fast path is a single ``np.full`` — tools never
        need to special-case batches that carry no side table, and per-row
        ``attrs_at`` loops collapse to one vectorized gather + array op.
        """
        n = len(self) if rows is None else len(rows)
        if self.attrs is None:
            return np.full(n, default, dtype=dtype)
        if rows is None:
            src = self.attrs
        else:
            src = (self.attrs[int(i)] for i in rows)
        return np.asarray([default if a is None else a.get(key, default)
                           for a in src], dtype=dtype)

    def mask(self, *kinds) -> np.ndarray:
        codes = codes_for(kinds)
        if codes is None:
            return np.ones(len(self), dtype=bool)
        if len(codes) == 1:
            return self.kinds == codes[0]
        return np.isin(self.kinds, codes)

    def rows(self, *kinds) -> np.ndarray:
        """Row indices whose kind is one of ``kinds`` (vectorized filter)."""
        return np.nonzero(self.mask(*kinds))[0]

    def present_kinds(self) -> list:
        return [KIND_LIST[c] for c in np.unique(self.kinds)]

    # -------------------------------------------------------- materialization
    def event(self, i: int) -> Event:
        """Materialize row ``i`` as a scalar :class:`Event` (compat view).
        Scalar-origin rows return the *original* object with normalized
        columns written back; columnar rows build a fresh Event sharing the
        side-table attrs dict (so preprocessing results stay visible)."""
        kind = KIND_LIST[self.kinds[i]]
        ev = self._events[i] if self._events is not None else None
        if ev is not None:
            ev.step = int(self.steps[i])
            ev.size = int(self.sizes[i])
            ev.normalized = self.normalized
        else:
            a = self.attrs[i] if self.attrs is not None else None
            ev = Event(kind, name=self.name_table[self.name_ids[i]],
                       step=int(self.steps[i]), time=float(self.times[i]),
                       device=self.device_at(i), size=int(self.sizes[i]),
                       addr=int(self.addrs[i]), region=self.region_at(i),
                       attrs=a if a is not None else {},
                       seq=int(self.seqs[i]), normalized=self.normalized)
        if self.normalized:
            if kind is EventKind.KERNEL_LAUNCH:
                ev.attrs.setdefault(
                    "count", int(self.counts[i]) if self.counts is not None
                    else 1)
            elif kind is EventKind.MEMCPY:
                ev.attrs.setdefault("direction", "d2d")
        return ev

    def iter_events(self, kinds=("*",)) -> Iterator[Event]:
        """Loop-over-rows fallback: yield scalar Events for matching rows."""
        codes = codes_for(kinds)
        if codes is None:
            idx = range(len(self))
        else:
            idx = np.nonzero(np.isin(self.kinds, codes))[0]
        for i in idx:
            yield self.event(int(i))


class EventRing:
    """Preallocated SoA ring buffer that accumulates emitted rows until a
    flush boundary (capacity, step edge, or explicit ``flush()``), then
    surfaces them as one :class:`EventBatch`."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self.n = 0
        self._kinds = np.empty(capacity, dtype=np.int16)
        self._steps = np.empty(capacity, dtype=np.int64)
        self._times = np.empty(capacity, dtype=np.float64)
        self._sizes = np.empty(capacity, dtype=np.int64)
        self._addrs = np.empty(capacity, dtype=np.int64)
        self._seqs = np.empty(capacity, dtype=np.int64)
        self._name_ids = np.empty(capacity, dtype=np.int32)
        self._name_table: list = []
        self._name_index: dict = {}
        self._attrs: list = []
        self._devices: list = []
        self._regions: list = []
        self._events: list = []
        self._any_event = False
        self._any_attrs = False

    def __len__(self) -> int:
        return self.n

    @property
    def full(self) -> bool:
        return self.n >= self.capacity

    def append(self, code, name, step, time, size, addr, seq, attrs,
               device, region, event: Event | None = None) -> bool:
        """Append one row; returns True when the ring reached capacity."""
        i = self.n
        self._kinds[i] = code
        self._steps[i] = step
        self._times[i] = time
        self._sizes[i] = size
        self._addrs[i] = addr
        self._seqs[i] = seq
        self._name_ids[i] = _intern(name, self._name_table,
                                    self._name_index)
        self._attrs.append(attrs)
        self._devices.append(device)
        self._regions.append(region)
        self._events.append(event)
        if event is not None:
            self._any_event = True
        if attrs:
            self._any_attrs = True
        self.n = i + 1
        return self.n >= self.capacity

    def flush(self) -> EventBatch | None:
        """Drain the ring into an EventBatch (or None when empty)."""
        n = self.n
        if n == 0:
            return None
        batch = EventBatch(
            self._kinds[:n].copy(), self._steps[:n].copy(),
            self._times[:n].copy(), self._sizes[:n].copy(),
            self._addrs[:n].copy(), self._seqs[:n].copy(),
            self._name_ids[:n].copy(), list(self._name_table),
            # attrs=None is the vectorized fast path — only surface the side
            # table when some appended row actually carried attrs
            attrs=self._attrs if self._any_attrs else None,
            devices=self._devices, regions=self._regions,
            events=self._events if self._any_event else None)
        self.n = 0
        self._name_table = []
        self._name_index = {}
        self._attrs = []
        self._devices = []
        self._regions = []
        self._events = []
        self._any_event = False
        self._any_attrs = False
        return batch


# Collective opcodes recognized in HLO text (async *-start forms are folded
# into their base opcode by the parser; *-done carries no payload).
COLLECTIVE_OPCODES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
    "collective-broadcast",
)
