"""PASTA event vocabulary — the Table-II analogue for TPU/JAX.

The paper's event taxonomy has three tiers:

  * coarse-grained host-called API events (kernel launch, memcpy, sync, ...)
  * fine-grained device-side operations (per-thread memory accesses, ...)
  * high-level DL framework events (operator begin/end, tensor alloc, ...)

On TPU there is no per-instruction instrumentation surface, so the fine-grained
tier is carried by *trace buffers* (structured arrays of access records that are
aggregated on device — see ``repro.kernels``) rather than one Python object per
access.  Everything else maps 1:1.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import time as _time
from typing import Any


class EventKind(enum.Enum):
    # --- low-level, coarse-grained (host-called API analogues) -------------
    KERNEL_LAUNCH = "kernel_launch"        # one top-level HLO instruction
    MEMCPY = "memcpy"
    MEMSET = "memset"
    SYNC = "sync"
    ALLOC = "alloc"                        # device memory object (pool chunk)
    FREE = "free"
    COLLECTIVE = "collective"              # all-reduce / all-gather / ...
    COMPILE = "compile"                    # XLA compilation finished
    # --- low-level, fine-grained (device-side) -----------------------------
    TRACE_BUFFER = "trace_buffer"          # handle to a device access-record
                                           # buffer; aggregated by processor
    # --- high-level DL framework events -------------------------------------
    OPERATOR_START = "operator_start"
    OPERATOR_END = "operator_end"
    TENSOR_ALLOC = "tensor_alloc"
    TENSOR_FREE = "tensor_free"
    REGION_START = "region_start"          # pasta.start()/pasta.end()
    REGION_END = "region_end"
    STEP_START = "step_start"
    STEP_END = "step_end"


#: kinds whose ``size`` field is known to arrive with inconsistent sign
#: conventions across backends (the paper's normalization example: some
#: runtimes report deallocation sizes as negative deltas).
_SIGNED_SIZE_KINDS = (EventKind.FREE, EventKind.TENSOR_FREE)

_seq = itertools.count()


@dataclasses.dataclass
class Event:
    """A single normalized-or-raw PASTA event."""

    kind: EventKind
    name: str = ""
    step: int = -1
    time: float = dataclasses.field(default_factory=_time.perf_counter)
    device: tuple = ()            # mesh coordinates, e.g. (pod, data, model)
    size: int = 0                 # bytes (sign-normalized by the processor)
    addr: int = 0                 # virtual address (pool-modelled)
    region: tuple = ()            # annotation stack snapshot
    attrs: dict = dataclasses.field(default_factory=dict)
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    normalized: bool = False

    def with_attrs(self, **kw: Any) -> "Event":
        self.attrs.update(kw)
        return self


# Collective opcodes recognized in HLO text (async *-start forms are folded
# into their base opcode by the parser; *-done carries no payload).
COLLECTIVE_OPCODES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
    "collective-broadcast",
)
