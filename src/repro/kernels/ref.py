"""Pure-jnp oracles for the PASTA analysis kernels.

These are the correctness references the Pallas kernels are swept against,
and also the XLA fallback used off-TPU (the device-resident analysis model
still holds: XLA compiles these to vectorized device code).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def object_histogram_ref(addrs: jax.Array, starts: jax.Array,
                         ends: jax.Array) -> jax.Array:
    """Per-object access counts.

    addrs: int32[N] — accessed addresses (any unit, consistent with ranges).
    starts/ends: int32[K] — sorted, disjoint half-open object ranges.
    returns int32[K].
    """
    idx = jnp.searchsorted(starts, addrs, side="right") - 1
    idx_c = jnp.clip(idx, 0, starts.shape[0] - 1)
    valid = (idx >= 0) & (addrs < ends[idx_c]) & (addrs >= starts[idx_c])
    return jax.ops.segment_sum(valid.astype(jnp.int32), idx_c,
                               num_segments=starts.shape[0])


def trace_aggregate_ref(addrs: jax.Array, tbins: jax.Array,
                        starts: jax.Array, ends: jax.Array, base: int,
                        n_blocks: int, n_tbins: int, block_shift: int):
    """Fused oracle: per-object counts AND the [time-bin × block] hotness
    map from one (jit-compiled) pass over the shared trace columns — the
    XLA fallback for the fused ``trace_aggregate`` Pallas kernel."""
    return (object_histogram_ref(addrs, starts, ends),
            hotness_histogram_ref(addrs, tbins, base, n_blocks, n_tbins,
                                  block_shift))


def hotness_histogram_ref(addrs: jax.Array, tbins: jax.Array, base: int,
                          n_blocks: int, n_tbins: int,
                          block_shift: int) -> jax.Array:
    """[time-bin × block] access hotness.

    addrs: int32[N] (512 B units); tbins: int32[N] pre-binned time indices.
    base: int32 base address (512 B units); block granularity 2^block_shift
    units (2 MiB blocks = 4096 units → shift 12).
    returns int32[n_tbins, n_blocks].
    """
    b = (addrs - base) >> block_shift
    valid = (b >= 0) & (b < n_blocks) & (tbins >= 0) & (tbins < n_tbins)
    b_c = jnp.clip(b, 0, n_blocks - 1)
    t_c = jnp.clip(tbins, 0, n_tbins - 1)
    flat = t_c * n_blocks + b_c
    hist = jax.ops.segment_sum(valid.astype(jnp.int32), flat,
                               num_segments=n_tbins * n_blocks)
    return hist.reshape(n_tbins, n_blocks)
