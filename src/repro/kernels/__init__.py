"""PASTA device-resident analysis kernels (paper Fig. 2b) + model hot-spots.

Layout per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jitted dispatch), ``ref.py`` (pure-jnp oracle).
"""

from . import ops, ref  # noqa: F401
