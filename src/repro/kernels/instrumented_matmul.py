"""Instrumented compute kernel — in-kernel device-side event recording.

Table II's fine-grained tier (thread-block entry/exit, per-access events) has
no interception surface on TPU; the PASTA way to get it is *opt-in kernel
instrumentation*: the kernel itself appends records to a device-resident
trace buffer as it runs (paper Fig. 2b: produce events where the data is).

This blocked matmul writes, per (i, j) grid step, one record
``[block_i, block_j, bytes_read, bytes_written]`` into a trace output that
lives entirely on device; the PASTA processor aggregates it without ever
copying raw per-access data to the host.  The compute tile is the standard
MXU-aligned (BM×K)·(K×BN) block with f32 accumulation; the instrumentation
adds one 4-int VMEM row per grid step (<0.01 % overhead), matching the
paper's low-overhead-hooks principle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BN = 128


def _kernel(x_ref, w_ref, o_ref, trace_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = jax.lax.dot(x, w, preferred_element_type=jnp.float32) \
        .astype(o_ref.dtype)
    # ---- device-side event record (fine-grained tier) ----------------------
    bytes_read = x.size * x.dtype.itemsize + w.size * w.dtype.itemsize
    bytes_written = o_ref.size * o_ref.dtype.itemsize
    trace_ref[0, 0] = i
    trace_ref[0, 1] = j
    trace_ref[0, 2] = bytes_read
    trace_ref[0, 3] = bytes_written


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_traced(x: jax.Array, w: jax.Array, interpret: bool = False):
    """(M,K)@(K,N) with an on-device access-record trace.

    Returns (out f32[M,N], trace int32[n_grid_steps, 4])."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % BM == 0 and n % BN == 0, (x.shape, w.shape)
    grid = (m // BM, n // BN)
    out, trace = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i * (n // BN) + j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((grid[0] * grid[1], 4), jnp.int32),
        ],
        interpret=interpret,
    )(x, w)
    return out, trace


def matmul_traced_ref(x: jax.Array, w: jax.Array):
    """Oracle: plain matmul + analytically derived trace."""
    m, k = x.shape
    _, n = w.shape
    gi, gj = m // BM, n // BN
    ij = jnp.stack(jnp.meshgrid(jnp.arange(gi), jnp.arange(gj),
                                indexing="ij"), -1).reshape(-1, 2)
    br = BM * k * x.dtype.itemsize + k * BN * w.dtype.itemsize
    bw = BM * BN * 4
    trace = jnp.concatenate(
        [ij.astype(jnp.int32),
         jnp.full((gi * gj, 1), br, jnp.int32),
         jnp.full((gi * gj, 1), bw, jnp.int32)], axis=1)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)), trace
