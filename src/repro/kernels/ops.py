"""Jitted dispatch wrappers for the PASTA analysis kernels.

Dispatch policy:

  * on TPU: the Pallas kernels (compiled);
  * ``REPRO_PALLAS_INTERPRET=1``: Pallas kernels in interpret mode (CPU
    correctness path used by the test sweeps);
  * otherwise: the pure-jnp oracles in :mod:`repro.kernels.ref` compiled by
    XLA — still the device-resident (Fig. 2b) analysis model, just without
    hand tiling.

Addresses are byte int64 at the API; kernels work in 512-byte units (int32),
which is lossless because the pool rounds tensors to 512 B.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .trace_aggregate import BLOCK_T as AGG_BLOCK_T, BLOCK_K as AGG_BLOCK_K
from .trace_aggregate import (FUSE_BLOCK_T, FUSE_BLOCK_K, FUSE_VMEM_BUDGET,
                              fuse_vmem_bytes, object_histogram_pallas,
                              trace_aggregate_pallas)
from .hotness import BLOCK_T as HOT_BLOCK_T, BLOCK_B as HOT_BLOCK_B
from .hotness import hotness_histogram_pallas

UNIT_SHIFT = 9                 # 512-byte address units
BLOCK_SHIFT = 12               # 2 MiB blocks = 4096 units = 2**12
_I32_MAX = np.int32(2**31 - 1)


def _backend() -> str:
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return "interpret"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "ref"


_ref_object_histogram = jax.jit(ref.object_histogram_ref)
_ref_hotness = jax.jit(ref.hotness_histogram_ref,
                       static_argnames=("n_blocks", "n_tbins", "block_shift"))
_ref_trace_aggregate = jax.jit(
    ref.trace_aggregate_ref,
    static_argnames=("n_blocks", "n_tbins", "block_shift"))


def _pad_to(x: np.ndarray, mult: int, value) -> np.ndarray:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, value, dtype=x.dtype)])


def _to_units(addrs_bytes) -> np.ndarray:
    a = np.asarray(addrs_bytes, dtype=np.int64) >> UNIT_SHIFT
    assert a.max(initial=0) < 2**31, "address space exceeds int32 units"
    return a.astype(np.int32)


def object_histogram(addrs_bytes, starts_bytes, ends_bytes):
    """Per-object access counts. Returns int64[K]."""
    k = len(starts_bytes)
    a = _to_units(addrs_bytes)
    s = _to_units(starts_bytes)
    e = _to_units(ends_bytes)
    assert a.shape[0] < 2**24, "split traces >16M records for exact f32 accum"
    backend = _backend()
    if backend == "ref":
        return np.asarray(_ref_object_histogram(
            jnp.asarray(a), jnp.asarray(s), jnp.asarray(e))).astype(np.int64)
    a = _pad_to(a, AGG_BLOCK_T, -1)
    s = _pad_to(s, AGG_BLOCK_K, _I32_MAX)
    e = _pad_to(e, AGG_BLOCK_K, _I32_MAX)
    counts = object_histogram_pallas(jnp.asarray(a), jnp.asarray(s),
                                     jnp.asarray(e),
                                     interpret=backend == "interpret")
    return np.asarray(counts[:k]).astype(np.int64)


def hotness_histogram(addrs_bytes, times, base_addr: int, n_blocks: int,
                      n_tbins: int, t_max: float,
                      block_shift: int = BLOCK_SHIFT):
    """[time-bin × block] hotness (block = 2^block_shift 512-B units; default
    2 MiB, the UVM page-group size). Returns int64[n_tbins, n_blocks]."""
    a = _to_units(addrs_bytes)
    t = np.asarray(times, dtype=np.float64)
    tb = np.minimum((t / max(t_max, 1e-12) * n_tbins).astype(np.int32),
                    n_tbins - 1)
    base = np.int32(int(base_addr) >> UNIT_SHIFT)
    backend = _backend()
    if backend == "ref":
        out = _ref_hotness(jnp.asarray(a), jnp.asarray(tb), base,
                           n_blocks=n_blocks, n_tbins=n_tbins,
                           block_shift=block_shift)
        return np.asarray(out).astype(np.int64)
    a_p = _pad_to(a, HOT_BLOCK_T, -1)
    tb_p = _pad_to(tb, HOT_BLOCK_T, -1)
    nb_p = n_blocks + ((-n_blocks) % HOT_BLOCK_B)
    out = hotness_histogram_pallas(jnp.asarray(a_p), jnp.asarray(tb_p), base,
                                   nb_p, n_tbins, block_shift,
                                   interpret=backend == "interpret")
    return np.asarray(out[:, :n_blocks]).astype(np.int64)


def can_fuse(n_objects: int, n_blocks: int, n_tbins: int) -> bool:
    """Whether the fused counts+hotness kernel can host this problem.  The
    fused kernel keeps the whole object table and hotness matrix resident in
    VMEM and materializes (tile × table) one-hot operands, so its working
    set must fit the VMEM budget — limits the tiled two-pass kernels do not
    have; callers fall back to the separate kernels when this returns False.
    The jnp oracle backend has no such limits."""
    if _backend() == "ref":
        return True
    k_p = n_objects + ((-n_objects) % FUSE_BLOCK_K)
    nb_p = n_blocks + ((-n_blocks) % HOT_BLOCK_B)
    return fuse_vmem_bytes(k_p, nb_p, n_tbins) <= FUSE_VMEM_BUDGET


def trace_aggregate(addrs_bytes, times, starts_bytes, ends_bytes,
                    base_addr: int, n_blocks: int, n_tbins: int,
                    t_max: float, block_shift: int = BLOCK_SHIFT):
    """Fused per-object counts AND [time-bin × block] hotness from ONE pass
    over the trace (one device round-trip instead of two).  Returns
    ``(int64[K] counts, int64[n_tbins, n_blocks] hotness)`` identical to
    running :func:`object_histogram` and :func:`hotness_histogram`
    separately."""
    k = len(starts_bytes)
    a = _to_units(addrs_bytes)
    s = _to_units(starts_bytes)
    e = _to_units(ends_bytes)
    t = np.asarray(times, dtype=np.float64)
    tb = np.minimum((t / max(t_max, 1e-12) * n_tbins).astype(np.int32),
                    n_tbins - 1)
    base = np.int32(int(base_addr) >> UNIT_SHIFT)
    assert a.shape[0] < 2**24, "split traces >16M records for exact f32 accum"
    backend = _backend()
    if backend == "ref":
        counts, hist = _ref_trace_aggregate(
            jnp.asarray(a), jnp.asarray(tb), jnp.asarray(s), jnp.asarray(e),
            base, n_blocks=n_blocks, n_tbins=n_tbins, block_shift=block_shift)
        return (np.asarray(counts).astype(np.int64),
                np.asarray(hist).astype(np.int64))
    a_p = _pad_to(a, FUSE_BLOCK_T, -1)
    tb_p = _pad_to(tb, FUSE_BLOCK_T, -1)
    s_p = _pad_to(s, FUSE_BLOCK_K, _I32_MAX)
    e_p = _pad_to(e, FUSE_BLOCK_K, _I32_MAX)
    nb_p = n_blocks + ((-n_blocks) % HOT_BLOCK_B)
    counts, hist = trace_aggregate_pallas(
        jnp.asarray(a_p), jnp.asarray(tb_p), jnp.asarray(s_p),
        jnp.asarray(e_p), base, block_shift, n_blocks=nb_p, n_tbins=n_tbins,
        interpret=backend == "interpret")
    return (np.asarray(counts[:k]).astype(np.int64),
            np.asarray(hist[:, :n_blocks]).astype(np.int64))
