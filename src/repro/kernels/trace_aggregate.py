"""Device-resident trace aggregation (paper Fig. 2b) as a Pallas TPU kernel.

The paper's GPU version has warps increment per-object access counters with
atomics.  Scatter atomics are the wrong shape for a TPU; the TPU-native
formulation is *histogramming as a matmul*:

    in_range[t, k] = (starts[k] <= addr[t] < ends[k])     # VPU compares
    counts[k]     += ones[1, T] @ in_range[T, K]           # MXU reduction

Object ranges are disjoint, so ``in_range`` rows are one-hot and the f32
accumulation is exact for N < 2**24 records (asserted by the wrapper).

Tiling: the trace is streamed through VMEM in (1, BLOCK_T) tiles; object
tables live in (1, BLOCK_K) tiles; the grid is (K/BLOCK_K, N/BLOCK_T) with
the trace axis innermost so each counts tile stays resident in VMEM across
the whole stream (revisit-free output).  VMEM footprint per step:
BLOCK_T·4 B (addrs) + 2·BLOCK_K·4 B (ranges) + BLOCK_T·BLOCK_K·4 B (one-hot)
+ BLOCK_K·4 B (counts) ≈ 4.2 MiB at the default 2048×512 — comfortably
inside 16 MiB VMEM with double buffering; both block dims are multiples of
the 128-lane MXU/VPU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 2048     # trace records per tile
BLOCK_K = 512      # objects per tile


def _kernel(addrs_ref, starts_ref, ends_ref, counts_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    a = addrs_ref[0, :]                        # (T,)
    s = starts_ref[0, :]                       # (K,)
    e = ends_ref[0, :]
    in_range = ((a[:, None] >= s[None, :]) &
                (a[:, None] < e[None, :])).astype(jnp.float32)   # (T, K)
    ones = jnp.ones((1, a.shape[0]), dtype=jnp.float32)
    counts_ref[...] += jax.lax.dot(ones, in_range,
                                   preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def object_histogram_pallas(addrs: jax.Array, starts: jax.Array,
                            ends: jax.Array, interpret: bool = False):
    """addrs int32[N], starts/ends int32[K] (disjoint sorted ranges) →
    f32[K] counts.  N, K are padded to tile multiples by the caller
    (pad addrs with -1; pad ranges with empty [0, 0))."""
    n = addrs.shape[0]
    k = starts.shape[0]
    assert n % BLOCK_T == 0 and k % BLOCK_K == 0, (n, k)
    grid = (k // BLOCK_K, n // BLOCK_T)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_T), lambda kk, nn: (0, nn)),
            pl.BlockSpec((1, BLOCK_K), lambda kk, nn: (0, kk)),
            pl.BlockSpec((1, BLOCK_K), lambda kk, nn: (0, kk)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_K), lambda kk, nn: (0, kk)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        interpret=interpret,
    )(addrs.reshape(1, n), starts.reshape(1, k), ends.reshape(1, k))
    return out[0]
