"""Device-resident trace aggregation (paper Fig. 2b) as a Pallas TPU kernel.

The paper's GPU version has warps increment per-object access counters with
atomics.  Scatter atomics are the wrong shape for a TPU; the TPU-native
formulation is *histogramming as a matmul*:

    in_range[t, k] = (starts[k] <= addr[t] < ends[k])     # VPU compares
    counts[k]     += ones[1, T] @ in_range[T, K]           # MXU reduction

Object ranges are disjoint, so ``in_range`` rows are one-hot and the f32
accumulation is exact for N < 2**24 records (asserted by the wrapper).

Tiling: the trace is streamed through VMEM in (1, BLOCK_T) tiles; object
tables live in (1, BLOCK_K) tiles; the grid is (K/BLOCK_K, N/BLOCK_T) with
the trace axis innermost so each counts tile stays resident in VMEM across
the whole stream (revisit-free output).  VMEM footprint per step:
BLOCK_T·4 B (addrs) + 2·BLOCK_K·4 B (ranges) + BLOCK_T·BLOCK_K·4 B (one-hot)
+ BLOCK_K·4 B (counts) ≈ 4.2 MiB at the default 2048×512 — comfortably
inside 16 MiB VMEM with double buffering; both block dims are multiples of
the 128-lane MXU/VPU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 2048     # trace records per tile
BLOCK_K = 512      # objects per tile


def _kernel(addrs_ref, starts_ref, ends_ref, counts_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    a = addrs_ref[0, :]                        # (T,)
    s = starts_ref[0, :]                       # (K,)
    e = ends_ref[0, :]
    in_range = ((a[:, None] >= s[None, :]) &
                (a[:, None] < e[None, :])).astype(jnp.float32)   # (T, K)
    ones = jnp.ones((1, a.shape[0]), dtype=jnp.float32)
    counts_ref[...] += jax.lax.dot(ones, in_range,
                                   preferred_element_type=jnp.float32)


#: trace records per tile for the fused counts+hotness kernel; smaller than
#: BLOCK_T because the tile feeds THREE one-hot matmuls' operands at once
FUSE_BLOCK_T = 1024
#: object-table padding granularity for the fused kernel (full table
#: resident in VMEM, so pad to the 128-lane tile only)
FUSE_BLOCK_K = 128
#: conservative slice of the ~16 MiB VMEM left for the fused kernel's
#: working set (accumulators + one-hot operands + compiler temporaries)
FUSE_VMEM_BUDGET = 12 * 1024 * 1024


def fuse_vmem_bytes(k: int, n_blocks: int, n_tbins: int) -> int:
    """Worst-case f32 VMEM footprint of one fused-kernel grid step: the
    resident accumulators (counts[K], hist[tbins, blocks]) plus the
    per-tile transients — in_range (T×K), onehot_t (T×tbins), onehot_b
    (T×blocks) — doubled for their iota/compare intermediates.  Used by
    :func:`repro.kernels.ops.can_fuse` to route oversize problems to the
    tiled two-pass kernels instead."""
    resident = 4 * (k + n_tbins * n_blocks)
    transient = 4 * FUSE_BLOCK_T * (k + n_blocks + n_tbins)
    return resident + 2 * transient


def _fused_kernel(addrs_ref, tbins_ref, starts_ref, ends_ref, meta_ref,
                  counts_ref, hist_ref):
    """One stream over the trace, two accumulators: per-object counts and
    the [time-bin × block] hotness map share each (1, FUSE_BLOCK_T) addr
    tile, so the trace is read from HBM exactly once (vs twice for the
    separate object_histogram + hotness_histogram kernels)."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        hist_ref[...] = jnp.zeros_like(hist_ref)

    a = addrs_ref[0, :]                        # (T,) shared addr tile
    # --- accumulator 1: per-object counts (histogram-as-matmul) ----------
    s = starts_ref[0, :]                       # (K,)
    e = ends_ref[0, :]
    in_range = ((a[:, None] >= s[None, :]) &
                (a[:, None] < e[None, :])).astype(jnp.float32)   # (T, K)
    ones = jnp.ones((1, a.shape[0]), dtype=jnp.float32)
    counts_ref[...] += jax.lax.dot(ones, in_range,
                                   preferred_element_type=jnp.float32)
    # --- accumulator 2: time×block hotness (rank-expanding one-hots) ------
    base = meta_ref[0, 0]
    shift = meta_ref[0, 1]
    n_tbins, n_blocks = hist_ref.shape
    tb = tbins_ref[0, :]
    blk = jax.lax.shift_right_arithmetic(a - base, shift)
    valid = (blk >= 0) & (blk < n_blocks) & \
            (tb >= 0) & (tb < n_tbins) & (a >= 0)
    t_iota = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], n_tbins), 1)
    b_iota = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], n_blocks), 1)
    onehot_t = ((tb[:, None] == t_iota) & valid[:, None]).astype(jnp.float32)
    onehot_b = (blk[:, None] == b_iota).astype(jnp.float32)
    hist_ref[...] += jax.lax.dot(onehot_t.T, onehot_b,
                                 preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_blocks", "n_tbins",
                                              "interpret"))
def trace_aggregate_pallas(addrs: jax.Array, tbins: jax.Array,
                           starts: jax.Array, ends: jax.Array, base,
                           block_shift, n_blocks: int, n_tbins: int,
                           interpret: bool = False):
    """Fused device pass: addrs int32[N] (512 B units, -1 = padding),
    tbins int32[N] (-1 = padding), starts/ends int32[K] (disjoint sorted
    ranges, padded with empty [MAX, MAX)) → (f32[K] counts,
    f32[n_tbins, n_blocks] hotness).  Both the object table and the hotness
    matrix stay resident in VMEM across the whole stream (grid is the trace
    axis only), bounded by FUSE_VMEM_BUDGET — callers must pre-check with
    ``ops.can_fuse`` and fall back to the tiled two-pass kernels."""
    n = addrs.shape[0]
    k = starts.shape[0]
    assert n % FUSE_BLOCK_T == 0 and k % FUSE_BLOCK_K == 0, (n, k)
    assert fuse_vmem_bytes(k, n_blocks, n_tbins) <= FUSE_VMEM_BUDGET, \
        f"fused working set exceeds VMEM budget: {(k, n_blocks, n_tbins)}"
    grid = (n // FUSE_BLOCK_T,)
    meta = jnp.array([[base, block_shift]], dtype=jnp.int32)
    counts, hist = pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, FUSE_BLOCK_T), lambda nn: (0, nn)),
            pl.BlockSpec((1, FUSE_BLOCK_T), lambda nn: (0, nn)),
            pl.BlockSpec((1, k), lambda nn: (0, 0)),
            pl.BlockSpec((1, k), lambda nn: (0, 0)),
            pl.BlockSpec((1, 2), lambda nn: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda nn: (0, 0)),
            pl.BlockSpec((n_tbins, n_blocks), lambda nn: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((n_tbins, n_blocks), jnp.float32),
        ],
        interpret=interpret,
    )(addrs.reshape(1, n), tbins.reshape(1, n), starts.reshape(1, k),
      ends.reshape(1, k), meta)
    return counts[0], hist


@functools.partial(jax.jit, static_argnames=("interpret",))
def object_histogram_pallas(addrs: jax.Array, starts: jax.Array,
                            ends: jax.Array, interpret: bool = False):
    """addrs int32[N], starts/ends int32[K] (disjoint sorted ranges) →
    f32[K] counts.  N, K are padded to tile multiples by the caller
    (pad addrs with -1; pad ranges with empty [0, 0))."""
    n = addrs.shape[0]
    k = starts.shape[0]
    assert n % BLOCK_T == 0 and k % BLOCK_K == 0, (n, k)
    grid = (k // BLOCK_K, n // BLOCK_T)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_T), lambda kk, nn: (0, nn)),
            pl.BlockSpec((1, BLOCK_K), lambda kk, nn: (0, kk)),
            pl.BlockSpec((1, BLOCK_K), lambda kk, nn: (0, kk)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_K), lambda kk, nn: (0, kk)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        interpret=interpret,
    )(addrs.reshape(1, n), starts.reshape(1, k), ends.reshape(1, k))
    return out[0]
