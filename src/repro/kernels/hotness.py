"""Time-series hotness aggregation (paper §V-C2) as a Pallas TPU kernel.

Builds the [time-bin × 2 MiB-block] access-hotness matrix on device.  The 2-D
histogram is expressed as a rank-expanding one-hot **matmul** so the MXU does
the scatter:

    onehot_t[t, i] = (tbin[t] == i)          # (T, TBINS)
    onehot_b[t, j] = (block[t] == j)         # (T, BLOCK_B)
    hist[i, j]    += onehot_t.T @ onehot_b   # MXU, exact in f32 < 2**24

Grid: (n_block_tiles, n_trace_tiles), trace axis innermost so each hist tile
accumulates in VMEM across the full stream.  VMEM per step at defaults
(T=1024, TBINS=64, BLOCK_B=512): two one-hots (1024×64 + 1024×512)·4 B ≈
2.4 MiB + hist tile 128 KiB — MXU-aligned (all dims multiples of 128 except
TBINS=64, which pads one sublane tile; fine on v5e's 128×128 MXU via lane
packing)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 1024     # trace records per tile
BLOCK_B = 512      # memory blocks per tile


def _kernel(addrs_ref, tbins_ref, meta_ref, hist_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    base = meta_ref[0, 0]
    shift = meta_ref[0, 1]
    n_tbins = hist_ref.shape[0]
    a = addrs_ref[0, :]
    tb = tbins_ref[0, :]
    blk = jax.lax.shift_right_arithmetic(a - base, shift)
    blk_local = blk - pl.program_id(0) * BLOCK_B
    valid = (blk_local >= 0) & (blk_local < BLOCK_B) & \
            (tb >= 0) & (tb < n_tbins) & (a >= 0)
    t_iota = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], n_tbins), 1)
    b_iota = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], BLOCK_B), 1)
    onehot_t = ((tb[:, None] == t_iota) & valid[:, None]).astype(jnp.float32)
    onehot_b = (blk_local[:, None] == b_iota).astype(jnp.float32)
    hist_ref[...] += jax.lax.dot(onehot_t.T, onehot_b,
                                 preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_blocks", "n_tbins",
                                              "block_shift", "interpret"))
def hotness_histogram_pallas(addrs: jax.Array, tbins: jax.Array, base,
                             n_blocks: int, n_tbins: int, block_shift: int,
                             interpret: bool = False):
    """addrs int32[N] (512 B units, -1 = padding), tbins int32[N], base
    scalar int32 → f32[n_tbins, n_blocks]."""
    n = addrs.shape[0]
    assert n % BLOCK_T == 0 and n_blocks % BLOCK_B == 0, (n, n_blocks)
    grid = (n_blocks // BLOCK_B, n // BLOCK_T)
    meta = jnp.array([[base, block_shift]], dtype=jnp.int32)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_T), lambda bb, nn: (0, nn)),
            pl.BlockSpec((1, BLOCK_T), lambda bb, nn: (0, nn)),
            pl.BlockSpec((1, 2), lambda bb, nn: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_tbins, BLOCK_B), lambda bb, nn: (0, bb)),
        out_shape=jax.ShapeDtypeStruct((n_tbins, n_blocks), jnp.float32),
        interpret=interpret,
    )(addrs.reshape(1, n), tbins.reshape(1, n), meta)
    return out
