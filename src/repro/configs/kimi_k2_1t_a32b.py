"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 + 1 shared
[arXiv:2501.kimi2].  Memory policy: bf16 params + int8 Adam moments (f32
states would need ~14 TB — see DESIGN.md §4)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840, mlp="swiglu", rope_theta=5e4,
    n_experts=384, n_experts_active=8, d_ff_expert=2048, n_shared_experts=1,
    param_dtype="bfloat16", opt_moment_dtype="int8",
)
