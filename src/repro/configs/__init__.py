"""Architecture config registry: ``--arch <id>`` resolution + reduced
(smoke-test) variants."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig
from .shapes import SHAPES, Shape, get_shape, cells_for

_ARCHS = {
    "mamba2-2.7b": "mamba2_2_7b",
    "stablelm-1.6b": "stablelm_1_6b",
    "glm4-9b": "glm4_9b",
    "gemma-7b": "gemma_7b",
    "qwen3-32b": "qwen3_32b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "musicgen-large": "musicgen_large",
    "paper-gpt2": "paper_gpt2",
    "paper-bert": "paper_bert",
}

ASSIGNED = list(_ARCHS)[:10]          # the 10 assigned architectures


def get(name: str) -> ModelConfig:
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
    return mod.CONFIG


def list_archs() -> list:
    return list(_ARCHS)


def reduced(cfg: ModelConfig, seq_len: int = 64) -> ModelConfig:
    """Family-preserving tiny variant for CPU smoke tests."""
    hd = 16
    n_heads = 4 if cfg.n_heads else 0
    if cfg.n_heads:
        ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
        n_kv = max(1, n_heads // min(ratio, n_heads))
    else:
        n_kv = 0
    updates = dict(
        n_layers=5 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=hd if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 256) if cfg.vocab_size else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=8 if cfg.ssm_state else 64,
        ssm_chunk=16,
        shared_attn_every=2 if cfg.family == "hybrid" else 0,
        n_experts=4 if cfg.n_experts else 0,
        n_experts_active=2 if cfg.n_experts else 0,
        d_ff_expert=32 if cfg.n_experts else 0,
        capacity_factor=2.0,        # = e/k: dropless at smoke scale, so
                                    # teacher-forced == decode exactly
        n_shared_experts=min(cfg.n_shared_experts, 1),
        param_dtype="float32", opt_moment_dtype=cfg.opt_moment_dtype,
        dtype="float32",
    )
    return dataclasses.replace(cfg, **updates)
