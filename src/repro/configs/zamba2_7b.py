"""zamba2-7b — Mamba2 backbone + ONE weight-shared attention block applied
every 6 SSM layers [arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000, mlp="swiglu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256, ssm_groups=1,
    shared_attn_every=6,
    supports_long_context=True,
)
