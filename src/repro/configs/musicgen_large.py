"""musicgen-large — decoder-only over EnCodec tokens; the EnCodec
frame-embedding frontend is a stub (input_specs supplies precomputed frame
embeddings) [arXiv:2306.05284]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, mlp="swiglu", frontend="embed",
)
