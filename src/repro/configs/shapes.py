"""Assigned input shapes (per-arch cells = arch × shape).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV/SSM
cache of ``seq_len``); ``train_*`` lower ``train_step``; ``prefill_*`` lower
``prefill_step``.  ``long_500k`` requires sub-quadratic attention: it runs for
ssm/hybrid archs and is skipped (recorded, not hidden) for pure full-attention
archs per the assignment.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1  # grad-accum microbatches (train only)


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256, microbatches=8),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def get_shape(name: str) -> Shape:
    return SHAPES[name]


def cells_for(cfg) -> list:
    """All (shape) names applicable to an arch config."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        out.append("long_500k")
    return out
