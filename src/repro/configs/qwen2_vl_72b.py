"""qwen2-vl-72b — VLM backbone with M-RoPE; patch-embedding frontend is a
stub (input_specs supplies precomputed patch embeddings) [arXiv:2409.12191]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064, mlp="swiglu", m_rope=True,
    rope_theta=1e6, frontend="embed",
)
