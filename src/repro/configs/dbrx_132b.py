"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352, mlp="swiglu", rope_theta=5e5,
    n_experts=16, n_experts_active=4, d_ff_expert=10752,
)
