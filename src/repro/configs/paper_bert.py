"""BERT-base — paper evaluation model (Table IV); encoder (non-causal)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-bert", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=30522, mlp="geglu", causal=False,
)
