"""mamba2-2.7b — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    ssm_groups=1, tie_embeddings=True,
    supports_long_context=True,
)
