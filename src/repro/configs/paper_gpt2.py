"""GPT-2 (124M) — one of the paper's own evaluation models (Table IV)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-gpt2", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=50257, mlp="swiglu", tie_embeddings=True,
)
