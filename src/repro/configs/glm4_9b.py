"""glm4-9b — RoPE, extreme GQA (kv=2) [hf:THUDM/glm-4-9b]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=151552, mlp="swiglu", rope_theta=1e4,
)
