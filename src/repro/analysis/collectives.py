"""Collective-traffic lint passes: exposed comm + unintended reshards.

Both passes consume the overlap-aware rollup ``core.hlo.analyze`` already
computed (``stats.collective_instances`` carries per-instance wire bytes,
alpha-beta comm seconds, hidden/exposed splits, and the ICI/DCI link
classification), so they add no second walk over the artifact.
"""

from __future__ import annotations

from .base import AnalysisPass, register_pass

import re as _re

#: ops a value may pass through while still being "the same value" for
#: reshard-provenance purposes: layout/dtype-only ops plus the adds that
#: accumulate loop-carried gradient buckets
_PROVENANCE_CHAIN = {"convert", "bitcast", "reshape", "copy", "transpose",
                     "slice", "dynamic-slice", "optimization-barrier",
                     "opt-barrier", "add", "multiply", "divide", "tuple"}

_GTE_INDEX_RE = _re.compile(r"index=(\d+)")

#: jax primitive names that appear as the *final* op_name segment when the
#: user explicitly asked for the collective (shard_map / lax collectives);
#: partitioner-inserted reshards instead inherit the name of the op they
#: serve (gather, dot_general, transpose, while, ...)
_EXPLICIT_COLLECTIVE_PRIMS = {"all_gather", "all_to_all", "ppermute",
                              "psum", "psum_scatter", "reduce_scatter",
                              "all_reduce", "pbroadcast", "psum_start",
                              "psum_wait"}


def _explicitly_requested(op_name: str) -> bool:
    tail = op_name.rsplit("/", 1)[-1]
    # strip a trailing jax suffix like "all_gather[axis_name=...]"
    tail = tail.split("[", 1)[0]
    return tail in _EXPLICIT_COLLECTIVE_PRIMS


def _upstream_evidence(module, comp, ins, limit: int = 256) -> tuple:
    """Bounded upstream-dataflow walk from a reshard collective.

    Returns ``(reaches_entry_param, reduce_scatters)`` where
    ``reduce_scatters`` is the list of reduce-scatter Instructions found
    on the provenance chain.  The walk follows layout-only ops and
    accumulation adds, and *threads through while loops*: a
    ``get-tuple-element(while, index=i)`` continues at element ``i`` of
    both the loop body's root tuple and the loop's init tuple — that is
    how the tail all-gather of an all-reduce that XLA decomposed around a
    loop (reduce-scatter inside, all-gather after) finds its partner.
    """
    entry = module.computations.get(module.entry)
    queue = [(comp, ins)]
    seen = set()
    reaches_param = False
    reduce_scatters = []
    while queue and len(seen) < limit:
        c, cur = queue.pop()
        key = (c.name, cur.name)
        if key in seen:
            continue
        seen.add(key)
        for o in cur.operands:
            nxt = c.instructions.get(o.lstrip("%"))
            if nxt is None:
                continue
            op = nxt.opcode
            if op == "reduce-scatter":
                reduce_scatters.append(nxt)
            elif op == "parameter":
                if c is entry:
                    reaches_param = True
            elif op == "get-tuple-element":
                m = _GTE_INDEX_RE.search(nxt.attrs)
                idx = int(m.group(1)) if m else None
                src = c.instructions.get(
                    nxt.operands[0].lstrip("%")) if nxt.operands else None
                if src is None or idx is None:
                    continue
                if src.opcode == "while":
                    for cname in src.called_computations():
                        sub = module.computations.get(cname)
                        if sub is None:
                            continue
                        root = next((sub.instructions[n] for n in sub.order
                                     if sub.instructions[n].is_root), None)
                        if root is not None and root.opcode == "tuple" \
                                and idx < len(root.operands):
                            queue.append((sub, _Hop(root.operands[idx])))
                    init = c.instructions.get(
                        src.operands[0].lstrip("%")) if src.operands else None
                    if init is not None and init.opcode == "tuple" \
                            and idx < len(init.operands):
                        queue.append((c, _Hop(init.operands[idx])))
                elif src.opcode == "tuple" and idx < len(src.operands):
                    queue.append((c, _Hop(src.operands[idx])))
                else:
                    queue.append((c, nxt))
            elif op in _PROVENANCE_CHAIN:
                queue.append((c, nxt))
            # anything else (dot, fusion, …) is real compute: provenance
            # ends — an all-gather of *that* is an activation reshard
    return reaches_param, reduce_scatters


class _Hop:
    """Synthetic single-operand node so the walk can enqueue 'continue at
    this operand name' without duplicating expansion logic."""

    __slots__ = ("name", "opcode", "operands", "attrs")

    def __init__(self, operand: str):
        self.name = f"hop:{operand}"
        self.opcode = "copy"
        self.operands = [operand]
        self.attrs = ""


@register_pass("exposed-collectives")
class ExposedCollectivesPass(AnalysisPass):
    """Flag collectives whose transfer the schedule does not hide.

    A collective is *exposed* when the async-schedule model (committed
    ``*-start``/``*-done`` spans, or the async-runtime simulation for
    synchronous schedules) finds too little concurrent compute/other-link
    work to hide its alpha-beta transfer time.  A blocking gradient sync
    fires this pass; the bucketed ``psum_start``/``psum_wait`` overlap
    pipeline must make it go quiet.

    Per-instance knobs: ``threshold_frac`` — exposed fraction of wire
    bytes above which an instance is flagged; ``min_bytes`` — ignore
    instances with less wire traffic (control-flow tokens, tiny scale
    factors); ``min_comm_s`` — ignore instances cheaper than this even
    when fully exposed; ``severity`` — finding severity.

    Aggregate knobs: ``link`` — restrict the pass to one link class
    (``"ici"``/``"dci"``; empty = all); ``total_budget_s`` — when > 0,
    additionally emit one summary finding if the *total* exposed seconds
    over the considered instances exceed the budget.  At smoke scale
    individual instances look alike between a blocking and an overlapped
    schedule; the aggregate DCI exposure is what separates them (set
    ``threshold_frac`` above 1 to gate on the aggregate alone).
    """

    KNOBS = {"threshold_frac": 0.2, "min_bytes": 1 << 14,
             "min_comm_s": 0.0, "link": "", "total_budget_s": 0.0,
             "severity": "warn"}

    def run(self, ctx):
        out = []
        if ctx.stats is None:
            return out
        thr = float(self.knobs["threshold_frac"])
        min_bytes = float(self.knobs["min_bytes"])
        min_comm = float(self.knobs["min_comm_s"])
        only_link = str(self.knobs["link"]).strip().lower()
        budget = float(self.knobs["total_budget_s"])
        total_exposed_s = 0.0
        total_wire = 0.0
        n_considered = 0
        for inst in ctx.stats.collective_instances:
            wire = float(inst.get("wire_bytes", 0.0))
            comm = float(inst.get("comm_s", 0.0))
            link = inst.get("link", "ici")
            if only_link and link != only_link:
                continue
            if wire < min_bytes or comm <= 0.0 or comm < min_comm:
                continue
            exposed_b = float(inst.get("exposed_bytes", wire))
            frac = exposed_b / wire if wire > 0 else 0.0
            hidden_s = float(inst.get("hidden_s", 0.0))
            exposed_s = max(comm - hidden_s, 0.0)
            mult = float(inst.get("mult", 1.0))
            total_exposed_s += exposed_s * mult
            total_wire += wire * mult
            n_considered += 1
            if frac <= thr:
                continue
            out.append(self.finding(
                str(self.knobs["severity"]),
                f"{inst['opcode']} {inst['name']!r} exposes "
                f"{frac:.0%} of its {wire / 1e6:.2f} MB wire traffic "
                f"({exposed_s * 1e6:.0f} us/instance x{mult:.0f} on "
                f"{link.upper()})",
                opcode=inst["opcode"], instruction=inst["name"],
                computation=inst.get("computation", ""),
                op_name=inst.get("op_name", ""),
                bytes_impact=exposed_b * mult,
                seconds_impact=exposed_s * mult,
                fix_hint="overlap it: issue the collective earlier "
                         "(psum_start/psum_wait bucketing, overlap_sync="
                         "True) or aggregate small messages so the "
                         "alpha cost amortizes",
                data={"exposed_frac": frac, "wire_bytes": wire,
                      "comm_s": comm, "hidden_s": hidden_s,
                      "link": link, "mult": mult}))
        link_tag = only_link.upper() if only_link else "all links"
        ctx.meta[f"exposed_s:{only_link or 'all'}"] = total_exposed_s
        if budget > 0.0 and total_exposed_s > budget:
            out.append(self.finding(
                str(self.knobs["severity"]),
                f"aggregate exposed collective time on {link_tag} is "
                f"{total_exposed_s * 1e6:.1f} us across {n_considered} "
                f"instance(s) — over the {budget * 1e6:.1f} us budget",
                opcode="", instruction=f"total[{only_link or 'all'}]",
                bytes_impact=total_wire,
                seconds_impact=total_exposed_s,
                fix_hint="the schedule is not hiding its gradient sync: "
                         "enable the bucketed overlap pipeline "
                         "(overlap_sync=True) or raise total_budget_s if "
                         "this config's exposure is accepted",
                data={"total_exposed_s": total_exposed_s,
                      "budget_s": budget, "link": only_link or "all",
                      "n_instances": n_considered}))
        ctx.meta["exposed_collective_s"] = ctx.stats.exposed_collective_s
        return out


@register_pass("implicit-reshard")
class ImplicitReshardPass(AnalysisPass):
    """Flag reshard traffic the sharding rule table never asked for.

    The partitioner inserts all-gathers / all-to-alls / permutes whenever
    an operand's layout does not match what an op needs.  Most are
    *intended* (ZeRO parameter gathers, expert dispatch, pipeline shifts
    — see ``repro.dist.sharding.intended_collectives``); one wrong
    annotation makes GSPMD silently bounce whole activations between
    layouts every layer.  This pass decodes each reshard collective's
    replica groups onto mesh axes and reports any span the intent table
    does not cover.

    All-gathers get two provenance-based allowances (via a bounded
    upstream-dataflow walk that threads through while-loop carries):

    * a gather whose provenance roots at an entry ``parameter`` is the
      partitioner's chosen implementation of a sharded weight (e.g.
      all-gathering a TP-sharded embedding table before its lookup) —
      allowed over any axis some ``p_*`` rule shards over;
    * a gather whose provenance contains a ``reduce-scatter`` over the
      *same* axes is the tail of an all-reduce XLA decomposed around the
      microbatch loop (reduce-scatter inside, all-gather on the
      loop-carried accumulator) — intended reduction traffic.

    Activation reshards get neither pass: their provenance ends at real
    compute (dot/fusion), and they are exactly the mis-sharding signal
    this lint exists for.

    Knobs: ``min_bytes`` — ignore tiny reshards; ``allow_axes`` — extra
    allowed axis sets, ``"+"``-separated (e.g. ``"model+data,model"``
    allows {model,data} and {model}); ``severity``.
    """

    KNOBS = {"min_bytes": 1 << 12, "allow_axes": "", "severity": "warn"}

    def run(self, ctx):
        out = []
        if ctx.stats is None or ctx.module is None or not ctx.mesh_axes:
            return out          # no topology to judge against
        from ..dist.sharding import (RESHARD_OPCODES, axes_of_replica_groups,
                                     intended_collectives)
        intended = intended_collectives(rules=ctx.rules or None,
                                        mesh_axes=ctx.mesh_axes,
                                        kind=ctx.kind)
        extra = set()
        for seg in str(self.knobs["allow_axes"]).split(","):
            seg = seg.strip()
            if seg:
                extra.add(frozenset(a.strip() for a in seg.split("+")))
        min_bytes = float(self.knobs["min_bytes"])
        present = {a for a, s in ctx.mesh_axes.items() if int(s) > 1}
        param_axes: set = set()
        for key, val in (ctx.rules or {}).items():
            if key.startswith("p_") and val is not None:
                cand = (val,) if isinstance(val, str) else tuple(val)
                param_axes |= {a for a in cand if a in present}
        for inst in ctx.stats.collective_instances:
            op = inst["opcode"]
            if op not in RESHARD_OPCODES:
                continue
            if float(inst.get("wire_bytes", 0.0)) < min_bytes:
                continue
            if _explicitly_requested(inst.get("op_name", "")):
                continue        # user wrote this collective (shard_map /
                # lax.all_gather etc.) — intended by construction; this
                # pass only judges partitioner-inserted traffic
            comp = ctx.module.computations.get(inst.get("computation", ""))
            ins = comp.instructions.get(inst["name"]) if comp else None
            if ins is None:
                continue
            groups = ins.replica_groups()
            axes = axes_of_replica_groups(groups, ctx.mesh_axes)
            if axes is None:
                # hand-written topology (shard_map ring etc.): can't be an
                # accident of the rule table — skip with a counted note
                ctx.meta["reshard_unclassified"] = \
                    ctx.meta.get("reshard_unclassified", 0) + 1
                continue
            allowed = set(intended.get(op, set())) | extra
            if any(axes <= a for a in allowed):
                continue
            if op == "all-gather":
                reaches_param, rss = _upstream_evidence(ctx.module, comp, ins)
                if axes <= param_axes and reaches_param:
                    continue    # sharded-weight gather, compiler's choice
                if any(axes_of_replica_groups(rs.replica_groups(),
                                              ctx.mesh_axes) == axes
                       for rs in rss):
                    # tail of an all-reduce XLA decomposed into
                    # reduce-scatter (inside the microbatch loop) +
                    # all-gather (on the loop-carried accumulator):
                    # intended reduction traffic, not a reshard
                    continue
            mult = float(inst.get("mult", 1.0))
            wire = float(inst.get("wire_bytes", 0.0))
            out.append(self.finding(
                str(self.knobs["severity"]),
                f"partitioner inserted {op} {inst['name']!r} over mesh "
                f"axes {{{', '.join(sorted(axes))}}} — "
                f"{wire / 1e6:.2f} MB x{mult:.0f} the rule table never "
                f"intended",
                opcode=op, instruction=inst["name"],
                computation=inst.get("computation", ""),
                op_name=inst.get("op_name", ""),
                bytes_impact=wire * mult,
                seconds_impact=float(inst.get("comm_s", 0.0)) * mult,
                fix_hint="a layer is mis-sharded: fix the logical-axis "
                         "annotation or extend the rule table in "
                         "repro.dist.sharding (then accept via the "
                         "baseline file if the reshard is deliberate)",
                data={"axes": sorted(axes), "wire_bytes": wire,
                      "mult": mult,
                      "intended": [sorted(a) for a in sorted(
                          allowed, key=sorted)]}))
        return out
