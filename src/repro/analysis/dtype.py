"""dtype-promotion lint: f32 leaks inside bf16 / int8-quantized paths.

Two complementary views:

  * **jaxpr** (pre-lowering, when the driver supplies ``ctx.jaxprs``) —
    where intent is still visible.  Flags (a) elementwise ops whose output
    silently promotes to f32 because one operand is a strong-typed f32
    tensor in an otherwise-narrow path (the classic leak: an ``np.float32``
    constant in a bf16 layer), and (b) large explicit upcasts
    (``convert_element_type`` narrow→f32) above ``min_numel``.
  * **HLO** (post-lowering) — large narrow→f32 ``convert`` instructions
    anywhere in the module (fusion bodies included).  ``min_numel``
    filters the per-group f32 scale factors the quantized collectives
    produce on purpose, and the wholesale convert pairs XLA:CPU's bf16
    legalization inserts at smoke scale.

Reduction accumulators are *supposed* to be f32; converts feeding only
reduces are exempt via ``allow_reduce``.
"""

from __future__ import annotations

import re

from ..core.hlo import _SHAPE_RE, shape_numel
from .base import AnalysisPass, register_pass

#: dtypes a quantized/mixed-precision path is allowed to stay in
NARROW = {"bf16", "f16", "s8", "u8", "s4", "u4", "s2", "u2",
          "f8e4m3fn", "f8e5m2", "f8e4m3b11fnuz", "f8e4m3fnuz",
          "f8e5m2fnuz", "f8e3m4", "f8e4m3", "f8e8m0fnu"}
WIDE = {"f32", "f64"}

#: jaxpr dtype-name → HLO dtype-name (the subset we care about)
_JAX_NARROW = {"bfloat16", "float16", "int8", "uint8", "int4", "uint4",
               "float8_e4m3fn", "float8_e5m2"}
_JAX_WIDE = {"float32", "float64"}

_ELEMENTWISE_PRIMS = {"add", "sub", "mul", "div", "max", "min", "pow",
                      "atan2", "nextafter", "rem"}


def _dtype_of(shape_str: str) -> str:
    m = _SHAPE_RE.search(shape_str)
    return m.group(1) if m else ""


@register_pass("dtype-promotion")
class DtypePromotionPass(AnalysisPass):
    KNOBS = {"min_numel": 1 << 20, "min_numel_jaxpr": 1 << 10,
             "allow_reduce": True, "severity": "warn"}

    # ------------------------------------------------------------ HLO side
    def _run_hlo(self, ctx) -> list:
        out = []
        if ctx.module is None:
            return out
        min_numel = int(self.knobs["min_numel"])
        for cname, comp in ctx.module.computations.items():
            for iname in comp.order:
                ins = comp.instructions[iname]
                if ins.opcode != "convert":
                    continue
                src = _dtype_of(comp.shape_of(ins.operands[0])
                                if ins.operands else "")
                dst = _dtype_of(ins.shape)
                if src not in NARROW or dst not in WIDE:
                    continue
                numel = shape_numel(ins.shape)
                if numel < min_numel:
                    continue
                if self.knobs["allow_reduce"] and self._feeds_reduce(
                        comp, iname):
                    continue
                byts = numel * (4 if dst == "f32" else 8)
                out.append(self.finding(
                    str(self.knobs["severity"]),
                    f"{src}→{dst} promotion of {numel:,} elements "
                    f"({byts / 1e6:.2f} MB materialized) in {cname!r}",
                    opcode="convert", instruction=iname, computation=cname,
                    op_name=self._op_name(ins),
                    bytes_impact=float(byts),
                    fix_hint="keep the quantized path narrow: compute in "
                             f"{src} (or fuse the upcast into the "
                             "consuming reduction) instead of "
                             "materializing a wide copy",
                    data={"src": src, "dst": dst, "numel": numel}))
        return out

    @staticmethod
    def _op_name(ins) -> str:
        m = re.search(r'op_name="([^"]*)"', ins.attrs)
        return m.group(1) if m else ""

    @staticmethod
    def _feeds_reduce(comp, name: str) -> bool:
        users = [si for iname in comp.order
                 for si in (comp.instructions[iname],)
                 if name in si.operands]
        return bool(users) and all(
            si.opcode in ("reduce", "reduce-window", "all-reduce",
                          "reduce-scatter") for si in users)

    # ---------------------------------------------------------- jaxpr side
    def _run_jaxprs(self, ctx) -> list:
        out = []
        for label, jx in ctx.jaxprs:
            try:
                self._walk_jaxpr(label, jx, out, set())
            except Exception:                               # noqa: BLE001
                ctx.meta["jaxpr_walk_errors"] = \
                    ctx.meta.get("jaxpr_walk_errors", 0) + 1
        return out

    def _walk_jaxpr(self, label, jx, out, seen) -> None:
        jx = getattr(jx, "jaxpr", jx)       # ClosedJaxpr → Jaxpr
        if id(jx) in seen or not hasattr(jx, "eqns"):
            return
        seen.add(id(jx))
        min_numel = int(self.knobs["min_numel_jaxpr"])
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                        self._walk_jaxpr(label, sub, out, seen)
            ovals = [getattr(v, "aval", None) for v in eqn.outvars]
            oval = ovals[0] if ovals else None
            odt = str(getattr(oval, "dtype", ""))
            if odt not in _JAX_WIDE:
                continue
            numel = 1
            for d in getattr(oval, "shape", ()):
                numel *= int(d)
            ivals = [getattr(v, "aval", None) for v in eqn.invars]
            narrow_in = [a for a in ivals
                         if str(getattr(a, "dtype", "")) in _JAX_NARROW]
            if not narrow_in:
                continue
            if prim == "convert_element_type":
                if numel < min_numel:
                    continue
                msg = (f"explicit {narrow_in[0].dtype}→{odt} upcast of "
                       f"{numel:,} elements in jaxpr {label!r}")
                hint = ("dequantize lazily inside the consumer instead of "
                        "materializing the wide tensor")
            elif prim in _ELEMENTWISE_PRIMS:
                # a strong f32 operand dragged a narrow path wide
                wide_in = [a for a in ivals
                           if str(getattr(a, "dtype", "")) in _JAX_WIDE
                           and not getattr(a, "weak_type", False)]
                if not wide_in:
                    continue
                msg = (f"implicit promotion: {prim} mixes "
                       f"{narrow_in[0].dtype} with strong f32 → {odt} "
                       f"({numel:,} elements) in jaxpr {label!r}")
                hint = ("cast the f32 operand down (or make it a weak "
                        "python scalar); the whole downstream path now "
                        "runs wide")
            else:
                continue
            out.append(self.finding(
                str(self.knobs["severity"]), msg,
                opcode=prim, instruction=prim, computation=label,
                bytes_impact=float(numel * 4),
                fix_hint=hint,
                data={"numel": numel, "dtype": odt}))

    def run(self, ctx):
        return self._run_hlo(ctx) + self._run_jaxprs(ctx)
