"""Buffer-level lint passes: static peak-HBM estimate + host-sync audit.

``peak-memory`` runs a liveness analysis over the scheduled entry
computation — each instruction's output buffer is live from its definition
to its last consumer, parameters live for the whole program, while-loop
bodies contribute their internal transient peak on top of the live set at
the loop — and checks the resulting peak against the per-device HBM
budget the roofline model uses.  This is the static half of the OOM
gate: it prices a config *before* it burns hardware time.

``host-sync`` flags forced device↔host round-trips (infeed/outfeed,
host transfers, host custom-calls) and missed donations: a large entry
parameter whose shape reappears in the root outputs but is not in the
module's ``input_output_alias`` map is a state buffer XLA must
double-buffer — 2× residency and a copy on every step.
"""

from __future__ import annotations

import re

from ..core.hlo import (_FREE_OPCODES, _SHAPE_RE, _TRANSPARENT, Computation,
                        HloModule, shape_bytes)
from .base import AnalysisPass, register_pass


def _norm_shape(shape_str: str) -> tuple:
    """Layout-insensitive (dtype, dims) tuples of a shape string."""
    return tuple(_SHAPE_RE.findall(shape_str))


# ---------------------------------------------------------------------------
# liveness
# ---------------------------------------------------------------------------

def _buffer_bytes(ins) -> float:
    """Bytes a top-level instruction's *output* occupies.  Aliasing /
    layout-only ops and tuples own no storage of their own."""
    if ins.opcode in _TRANSPARENT or ins.opcode in (
            "tuple", "get-tuple-element", "parameter"):
        return 0.0
    if ins.opcode in _FREE_OPCODES:
        return 0.0
    return float(shape_bytes(ins.shape))


def estimate_peak_bytes(module: HloModule, comp: Computation | None = None,
                        default_trip: int = 1, _depth: int = 0) -> dict:
    """Static peak-HBM estimate of one execution of ``comp`` (default: the
    entry computation).

    Returns ``{"peak_bytes", "persistent_bytes", "transient_peak_bytes",
    "at_instruction"}``.  ``persistent_bytes`` is parameters + constants
    (live throughout); the transient peak tracks intermediate buffers via
    def/last-use liveness, descending into while/call/conditional bodies
    (× nothing — a loop's transient peak is per-iteration) and charging
    fusions only their materialized outputs.
    """
    if comp is None:
        comp = module.entry_computation()
    persistent = 0.0
    for iname in comp.order:
        ins = comp.instructions[iname]
        if ins.opcode in ("parameter", "constant"):
            persistent += float(shape_bytes(ins.shape))

    last_use = {}
    pos = {n: i for i, n in enumerate(comp.order)}
    end = len(comp.order)
    for iname in comp.order:
        ins = comp.instructions[iname]
        for o in ins.operands:
            o = o.lstrip("%")
            if o in pos:
                last_use[o] = pos[iname]
        if ins.is_root:
            last_use[iname] = end

    live = 0.0
    peak = 0.0
    at = ""
    frees: dict = {}
    for i, iname in enumerate(comp.order):
        for nm in frees.pop(i, ()):       # buffers whose last use was < i
            live -= nm
        ins = comp.instructions[iname]
        b = _buffer_bytes(ins)
        live += b
        here = live
        if _depth < 8 and ins.opcode in ("while", "call", "conditional"):
            sub_peaks = []
            for c in ins.called_computations():
                sub = module.computations.get(c)
                if sub is not None and sub is not comp:
                    sp = estimate_peak_bytes(module, sub, default_trip,
                                             _depth + 1)
                    sub_peaks.append(sp["transient_peak_bytes"])
            if sub_peaks:
                here += max(sub_peaks)
        if here > peak:
            peak = here
            at = iname
        lu = last_use.get(iname, i)       # unused value dies immediately
        if b > 0.0:
            frees.setdefault(max(lu, i) + 1, []).append(b)
    transient = peak
    return {"peak_bytes": persistent + transient,
            "persistent_bytes": persistent,
            "transient_peak_bytes": transient,
            "at_instruction": at}


@register_pass("peak-memory")
class PeakMemoryPass(AnalysisPass):
    """Static peak-HBM estimate vs. the device budget.

    Always publishes ``peak_bytes_est`` into the report meta (the CI
    lint-grid compares it against the dry-run measured peak); emits a
    finding only when the estimate exceeds ``budget_frac`` of the budget
    (``ctx.device_budget`` or ``hw["hbm_bytes"]``).
    """

    KNOBS = {"budget_frac": 0.92, "severity": "error"}

    def run(self, ctx):
        if ctx.module is None or not ctx.module.computations:
            return []
        est = estimate_peak_bytes(ctx.module,
                                  default_trip=ctx.default_trip)
        ctx.meta["peak_bytes_est"] = est["peak_bytes"]
        ctx.meta["peak_persistent_bytes"] = est["persistent_bytes"]
        ctx.meta["peak_at_instruction"] = est["at_instruction"]
        budget = ctx.budget_bytes
        if not budget:
            return []
        ctx.meta["peak_budget_bytes"] = budget
        frac = float(self.knobs["budget_frac"])
        if est["peak_bytes"] <= frac * budget:
            return []
        over = est["peak_bytes"] - frac * budget
        return [self.finding(
            str(self.knobs["severity"]),
            f"static peak HBM estimate {est['peak_bytes'] / 2**30:.2f} GiB "
            f"exceeds {frac:.0%} of the {budget / 2**30:.1f} GiB device "
            f"budget (peak at {est['at_instruction']!r})",
            opcode="liveness", instruction=est["at_instruction"],
            computation=ctx.module.entry,
            bytes_impact=over,
            fix_hint="shard the heaviest live buffers (FSDP the params, "
                     "microbatch the activations) or raise "
                     "remat/offload before this config OOMs on hardware",
            data=est)]


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

#: opcodes that force a device<->host round trip / pipeline bubble
_HOST_OPCODES = {"infeed", "outfeed", "send", "recv",
                 "send-done", "recv-done"}
_HOST_CUSTOM_RE = re.compile(
    r'custom_call_target="([^"]*(?:[Hh]ost|[Cc]allback|Pin|'
    r'annotate_device_placement)[^"]*)"')


@register_pass("host-sync")
class HostSyncPass(AnalysisPass):
    KNOBS = {"min_donate_bytes": 1 << 20, "severity": "warn"}

    def run(self, ctx):
        out = []
        if ctx.module is None or not ctx.module.computations:
            return out
        for cname, comp in ctx.module.computations.items():
            for iname in comp.order:
                ins = comp.instructions[iname]
                hit = ""
                if ins.opcode in _HOST_OPCODES:
                    hit = ins.opcode
                elif "is_host_transfer=true" in ins.attrs:
                    hit = f"{ins.opcode} (host transfer)"
                elif ins.opcode == "custom-call":
                    m = _HOST_CUSTOM_RE.search(ins.attrs)
                    if m:
                        hit = f"custom-call {m.group(1)}"
                if not hit:
                    continue
                byts = float(max(shape_bytes(ins.shape),
                                 sum(shape_bytes(comp.shape_of(o))
                                     for o in ins.operands)))
                out.append(self.finding(
                    str(self.knobs["severity"]),
                    f"{hit} in {cname!r} forces a device-host sync "
                    f"({byts / 1e6:.2f} MB)",
                    opcode=ins.opcode, instruction=iname, computation=cname,
                    bytes_impact=byts,
                    fix_hint="hot paths must stay on device: move the "
                             "callback/transfer off the step or batch it "
                             "behind an async copy",
                    data={"target": hit}))
        out.extend(self._missed_donations(ctx))
        return out

    def _missed_donations(self, ctx) -> list:
        """Large state-shaped inputs that are not donated: every step pays
        a copy and double residency."""
        out = []
        module = ctx.module
        entry = module.entry_computation()
        aliased = getattr(module, "aliased_params", None)
        if aliased is None:
            return out          # artifact carries no alias info: skip
        root = next((entry.instructions[n] for n in entry.order
                     if entry.instructions[n].is_root), None)
        if root is None:
            return out
        if root.opcode == "tuple":
            out_shapes = {_norm_shape(entry.shape_of(o))
                          for o in root.operands}
        else:
            out_shapes = {_norm_shape(root.shape)}
        min_bytes = float(self.knobs["min_donate_bytes"])
        for iname in entry.order:
            ins = entry.instructions[iname]
            if ins.opcode != "parameter" or not ins.operands:
                continue
            try:
                pidx = int(ins.operands[0])
            except ValueError:
                continue
            byts = float(shape_bytes(ins.shape))
            if byts < min_bytes or pidx in aliased:
                continue
            if _norm_shape(ins.shape) not in out_shapes:
                continue
            out.append(self.finding(
                str(self.knobs["severity"]),
                f"parameter {iname!r} ({byts / 2**20:.1f} MiB) matches an "
                f"output shape but is not donated — XLA double-buffers it "
                f"and copies every step",
                opcode="parameter", instruction=iname,
                computation=entry.name,
                bytes_impact=byts,
                fix_hint="donate the state argument "
                         "(jax.jit(..., donate_argnums=...)) so the "
                         "update happens in place",
                data={"param_index": pidx}))
        return out
