"""Typed findings for the ahead-of-time lint passes.

A :class:`Finding` is one defect (or observation) a static pass extracted
from a compiled artifact: severity, the instruction/opcode it anchors to,
the bytes/seconds it costs, and a fix hint.  :class:`Findings` is the
per-artifact report — JSON-exportable, CI-gateable, and suppressible
against a *baseline file* of known-accepted findings so a green grid can
be enforced at "zero unsuppressed findings" without hiding real history.

Baseline file format (JSON)::

    {"version": 1,
     "suppress": [
        {"key": "exposed-collectives:all-reduce:main/ar.1",
         "reason": "pod sync is blocking on purpose in this config"},
        {"key": "implicit-reshard:*", "reason": "glob ok too"}
     ]}

Keys are matched exactly first, then as ``fnmatch`` globs, so one entry
can accept a family of findings (e.g. every instance inside an unrolled
loop).  ``Findings.write_baseline`` emits a file accepting everything
currently firing — the workflow for adopting lint on a brownfield config.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json

#: severity ladder, least to most severe
SEVERITIES = ("info", "warn", "error")


def severity_rank(severity: str) -> int:
    try:
        return SEVERITIES.index(severity)
    except ValueError:
        return len(SEVERITIES)          # unknown sorts as most severe


@dataclasses.dataclass
class Finding:
    """One static-analysis finding, anchored to a compiled instruction."""

    pass_name: str                      # registry name of the emitting pass
    severity: str                       # "info" | "warn" | "error"
    message: str
    opcode: str = ""                    # HLO opcode (or jaxpr primitive)
    instruction: str = ""               # instruction name in the artifact
    computation: str = ""               # owning computation
    op_name: str = ""                   # source metadata op_name, if any
    bytes_impact: float = 0.0           # bytes moved/wasted per execution
    seconds_impact: float = 0.0         # modelled seconds of impact
    fix_hint: str = ""
    data: dict = dataclasses.field(default_factory=dict)
    suppressed: bool = False
    suppressed_reason: str = ""

    @property
    def key(self) -> str:
        """Stable identity used by baseline suppression: the pass, the
        opcode class, and where in the module it anchors."""
        loc = f"{self.computation}/{self.instruction}" if self.instruction \
            else self.computation or "-"
        return f"{self.pass_name}:{self.opcode or '-'}:{loc}"

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["key"] = self.key
        return d


@dataclasses.dataclass
class Baseline:
    """Known-accepted findings: exact keys and fnmatch patterns."""

    entries: list = dataclasses.field(default_factory=list)

    @classmethod
    def load(cls, path_or_dict) -> "Baseline":
        if isinstance(path_or_dict, Baseline):
            return path_or_dict
        if isinstance(path_or_dict, dict):
            doc = path_or_dict
        else:
            with open(path_or_dict) as f:
                doc = json.load(f)
        entries = []
        for e in doc.get("suppress", []):
            if isinstance(e, str):
                e = {"key": e}
            if e.get("key"):
                entries.append({"key": e["key"],
                                "reason": e.get("reason", "")})
        return cls(entries)

    def match(self, key: str) -> dict | None:
        for e in self.entries:
            if e["key"] == key:
                return e
        for e in self.entries:
            if fnmatch.fnmatchcase(key, e["key"]):
                return e
        return None

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"version": 1, "suppress": self.entries}, f, indent=1)
            f.write("\n")


class Findings:
    """Ordered collection of findings for one analyzed artifact."""

    def __init__(self, label: str = "", spec: str = "",
                 meta: dict | None = None):
        self.label = label
        self.spec = spec                # canonical pass-spec string used
        self.meta = dict(meta or {})    # estimates etc. passes want to expose
        self.findings: list = []
        self.warnings: dict = {}        # parser/pass warnings (counted)

    # ------------------------------------------------------------ building
    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def warn(self, key: str, n: int = 1) -> None:
        self.warnings[key] = self.warnings.get(key, 0) + n

    # ----------------------------------------------------------- filtering
    def __iter__(self):
        return iter(self.findings)

    def __len__(self) -> int:
        return len(self.findings)

    def unsuppressed(self, min_severity: str = "info") -> list:
        rank = severity_rank(min_severity)
        return [f for f in self.findings
                if not f.suppressed and severity_rank(f.severity) >= rank]

    def by_pass(self, name: str) -> list:
        return [f for f in self.findings if f.pass_name == name]

    def max_severity(self) -> str | None:
        live = self.unsuppressed()
        if not live:
            return None
        return max(live, key=lambda f: severity_rank(f.severity)).severity

    # ------------------------------------------------------------ baseline
    def apply_baseline(self, baseline) -> int:
        """Mark findings matching the baseline as suppressed; returns the
        number suppressed.  ``baseline`` is a :class:`Baseline`, a path, a
        dict, or ``None`` (no-op)."""
        if baseline is None:
            return 0
        bl = Baseline.load(baseline)
        n = 0
        for f in self.findings:
            hit = bl.match(f.key)
            if hit is not None:
                f.suppressed = True
                f.suppressed_reason = hit.get("reason", "")
                n += 1
        return n

    def write_baseline(self, path: str, reason: str = "accepted") -> None:
        """Emit a baseline accepting every currently-unsuppressed finding."""
        seen: dict = {}
        for f in self.unsuppressed():
            seen.setdefault(f.key, {"key": f.key, "reason": reason})
        Baseline(list(seen.values())).save(path)

    # ------------------------------------------------------------- reports
    def counts(self) -> dict:
        """``{pass_name: {severity: n}}`` over unsuppressed findings (the
        dryrun JSON ``lint`` section shape)."""
        out: dict = {}
        for f in self.findings:
            if f.suppressed:
                continue
            out.setdefault(f.pass_name, {})
            out[f.pass_name][f.severity] = \
                out[f.pass_name].get(f.severity, 0) + 1
        return out

    def summary(self) -> dict:
        return {
            "spec": self.spec,
            "passes": self.counts(),
            "n_findings": len(self.findings),
            "n_unsuppressed": len(self.unsuppressed()),
            "n_suppressed": sum(1 for f in self.findings if f.suppressed),
            "max_severity": self.max_severity(),
            "warnings": dict(self.warnings),
            "meta": dict(self.meta),
        }

    def as_dict(self) -> dict:
        return {"label": self.label, **self.summary(),
                "findings": [f.as_dict() for f in self.findings]}

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.as_dict(), indent=indent, default=str)

    def __repr__(self) -> str:
        c = self.counts()
        return f"Findings({self.label!r}, {len(self.findings)} findings, " \
               f"passes={sorted(c)})"
