"""Pass framework for the ahead-of-time static analyzer.

Mirrors the PASTA *tool* registry (``repro.core.tools.base``) one level
earlier in the lifecycle: where tools consume events from a run, an
:class:`AnalysisPass` consumes the *compiled artifact itself* — the parsed
HLO module plus the overlap-aware rollup ``core.hlo.analyze`` already
derives from it — and returns typed :class:`~repro.analysis.findings.Finding`
records without executing anything.

Passes register under a string key::

    @register_pass("exposed-collectives")
    class ExposedCollectivesPass(AnalysisPass): ...

and are selectable by the same spec-string grammar as tools
(``"exposed-collectives:threshold_frac=0.2,peak-memory"``), so the launch
drivers accept ``--lint-passes`` exactly like ``--pasta-tools``.

:func:`run_passes` is the one-call entry point: parse + roll up once,
hand every pass the shared :class:`AnalysisContext`, collect findings,
apply the baseline, and (when a session is active) emit each finding as a
``FINDING`` event so dynamic tools can correlate static predictions with
measured behaviour.
"""

from __future__ import annotations

import dataclasses

from ..core import hlo as hlo_mod
from ..core.events import Event, EventKind
from ..core.tools.base import parse_tool_spec
from .findings import Finding, Findings

#: the standard pass suite, in execution order
DEFAULT_SPEC = ("exposed-collectives,implicit-reshard,dtype-promotion,"
                "peak-memory,host-sync")


@dataclasses.dataclass
class AnalysisContext:
    """Everything a pass may consult — shared across the suite so the
    artifact is parsed and rolled up exactly once."""

    module: hlo_mod.HloModule | None = None
    stats: hlo_mod.HloStats | None = None
    text: str = ""
    hw: dict = dataclasses.field(default_factory=dict)
    #: ordered mesh axis sizes, e.g. {"pod": 2, "data": 2, "model": 2}
    mesh_axes: dict = dataclasses.field(default_factory=dict)
    #: logical->physical sharding rule table in force for the compile
    rules: dict = dataclasses.field(default_factory=dict)
    #: cell kind: "train" | "prefill" | "decode" | "" (unknown)
    kind: str = ""
    #: pod topology forwarded to the overlap model
    pods: int | None = None
    n_devices: int | None = None
    #: per-device HBM budget in bytes (defaults to hw["hbm_bytes"])
    device_budget: float | None = None
    #: [(name, jaxpr)] pairs for pre-lowering dtype analysis
    jaxprs: list = dataclasses.field(default_factory=list)
    default_trip: int = 1
    label: str = ""
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def budget_bytes(self) -> float:
        if self.device_budget:
            return float(self.device_budget)
        return float(self.hw.get("hbm_bytes", 0.0))


class AnalysisPass:
    """One static lint pass.  Subclass and override :meth:`run`; declare
    tunables in ``KNOBS`` so spec strings can override them."""

    KNOBS: dict = {}

    def __init__(self, **knobs):
        self.knobs = dict(self.KNOBS)
        unknown = set(knobs) - set(self.KNOBS)
        if unknown:
            raise TypeError(
                f"unknown knob(s) {sorted(unknown)} for pass "
                f"{getattr(self, 'REGISTRY_NAME', type(self).__name__)!r}; "
                f"known: {sorted(self.KNOBS)}")
        self.knobs.update(knobs)

    def run(self, ctx: AnalysisContext) -> list:
        """Return a list of Findings.  Must never raise on malformed input:
        skip what cannot be analyzed (``run_passes`` converts an escape into
        a ``pass-error`` finding as a backstop)."""
        raise NotImplementedError

    def finding(self, severity: str, message: str, **kw) -> Finding:
        return Finding(pass_name=getattr(self, "REGISTRY_NAME",
                                         type(self).__name__),
                       severity=severity, message=message, **kw)


# ---------------------------------------------------------------------------
# registry + spec strings (same grammar as the tool registry)
# ---------------------------------------------------------------------------

#: registry name -> AnalysisPass subclass (populated by @register_pass)
PASS_REGISTRY: dict = {}


def register_pass(name: str):
    """Class decorator mirroring ``core.tools.base.register``."""
    def deco(cls):
        prev = PASS_REGISTRY.get(name)
        if prev is not None and prev is not cls:
            raise ValueError(f"pass name {name!r} is already registered to "
                             f"{prev.__name__}")
        PASS_REGISTRY[name] = cls
        cls.REGISTRY_NAME = name
        return cls
    return deco


def parse_pass_spec(spec: str) -> list:
    """``"name[:knob=val[,knob=val...]][,name...]"`` →
    ``[(name, {knob: value}), ...]`` — the tool-spec grammar verbatim."""
    return parse_tool_spec(spec)


def format_pass_spec(entries) -> str:
    """Canonical spec string for ``[(name, knobs)]`` — the round-trip
    inverse of :func:`parse_pass_spec` (knob order is sorted)."""
    segs = []
    for name, knobs in entries:
        if knobs:
            kv = ",".join(f"{k}={_fmt_knob(v)}"
                          for k, v in sorted(knobs.items()))
            segs.append(f"{name}:{kv}")
        else:
            segs.append(name)
    return ",".join(segs)


def _fmt_knob(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


def resolve_passes(spec=None) -> list:
    """Instantiate passes from a spec string / list (``None`` → the default
    suite).  Accepts instances, classes, names, specs, and (name, kwargs)
    pairs — mirrors ``resolve_tools``."""
    if spec is None:
        spec = DEFAULT_SPEC

    def build(name: str, knobs: dict):
        if name not in PASS_REGISTRY:
            raise KeyError(f"unknown analysis pass {name!r}; "
                           f"known: {sorted(PASS_REGISTRY)}")
        return PASS_REGISTRY[name](**knobs)

    if isinstance(spec, AnalysisPass):
        return [spec]
    if isinstance(spec, str):
        return [build(n, k) for n, k in parse_pass_spec(spec)]
    out = []
    for item in spec:
        if isinstance(item, AnalysisPass):
            out.append(item)
        elif isinstance(item, type) and issubclass(item, AnalysisPass):
            out.append(item())
        elif isinstance(item, str):
            out.extend(build(n, k) for n, k in parse_pass_spec(item))
        elif isinstance(item, tuple) and len(item) == 2:
            out.append(build(item[0], dict(item[1])))
        else:
            raise TypeError(f"cannot resolve pass spec item {item!r}")
    return out


def spec_of(passes) -> str:
    """Canonical spec string of instantiated passes (records what ran)."""
    return format_pass_spec(
        [(getattr(p, "REGISTRY_NAME", type(p).__name__),
          {k: v for k, v in p.knobs.items() if v != p.KNOBS.get(k)})
         for p in passes])


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

def build_context(target, *, stats=None, hw=None, default_trip: int = 1,
                  pods=None, n_devices=None, mesh_axes=None, rules=None,
                  kind: str = "", jaxprs=(), device_budget=None,
                  label: str = "", meta=None) -> AnalysisContext:
    """Parse + roll up ``target`` (HLO text, an ``HloModule``, or a compiled
    executable with ``as_text()``) into a shared pass context."""
    text = ""
    if isinstance(target, hlo_mod.HloModule):
        module = target
    else:
        text = target if isinstance(target, str) else target.as_text()
        module = hlo_mod.parse_hlo(text)
    if hw is None:
        hw = hlo_mod._default_hw()
    if stats is None:
        stats = hlo_mod.analyze(module, default_trip=default_trip, hw=hw,
                                pods=pods, n_devices=n_devices)
    if mesh_axes is not None and not isinstance(mesh_axes, dict):
        mesh_axes = dict(mesh_axes.shape)       # a jax Mesh
    return AnalysisContext(
        module=module, stats=stats, text=text, hw=dict(hw),
        mesh_axes=dict(mesh_axes or {}), rules=dict(rules or {}),
        kind=kind, pods=pods, n_devices=n_devices,
        device_budget=device_budget, jaxprs=list(jaxprs),
        default_trip=default_trip, label=label, meta=dict(meta or {}))


def run_passes(target, passes=None, *, baseline=None, session=None,
               emit_events: bool = True, **ctx_kw) -> Findings:
    """Run a pass suite over one compiled artifact and return the findings.

    ``target``/``ctx_kw`` feed :func:`build_context` (pass a precomputed
    ``stats=`` to skip the re-rollup when the artifact was already walked,
    e.g. by ``Session.capture_compiled``).  ``baseline`` suppresses
    known-accepted findings.  Findings are additionally emitted as
    ``FINDING`` events into ``session`` (default: the active session) so
    dynamic tools can correlate them; pass ``emit_events=False`` to skip.

    Never raises on malformed artifacts: a pass that escapes is recorded
    as a single ``pass-error`` finding and the suite continues.
    """
    suite = resolve_passes(passes)
    ctx = build_context(target, **ctx_kw)
    out = Findings(label=ctx.label, spec=spec_of(suite),
                   meta=dict(ctx.meta))
    for key, n in getattr(ctx.stats, "warnings", {}).items():
        out.warn(key, n)
    for p in suite:
        name = getattr(p, "REGISTRY_NAME", type(p).__name__)
        try:
            found = p.run(ctx) or []
        except Exception as e:                              # noqa: BLE001
            out.warn(f"pass-error:{name}")
            found = [Finding(pass_name=name, severity="error",
                             opcode="pass-error",
                             message=f"pass crashed: {type(e).__name__}: {e}",
                             fix_hint="file a bug against repro.analysis; "
                                      "the artifact confused the pass")]
        out.extend(found)
    out.meta.update(ctx.meta)       # passes may publish estimates via ctx
    out.apply_baseline(baseline)
    if emit_events:
        _emit_findings(out, session)
    return out


def _emit_findings(findings: Findings, session=None) -> None:
    if session is None:
        from ..core.session import active_session
        session = active_session()
    if session is None:
        return
    for f in findings:
        session.handler.emit(Event(
            EventKind.FINDING, name=f.pass_name,
            size=int(f.bytes_impact),
            attrs={"severity": f.severity, "key": f.key,
                   "opcode": f.opcode, "instruction": f.instruction,
                   "message": f.message, "suppressed": f.suppressed,
                   "seconds_impact": f.seconds_impact,
                   "label": findings.label}))
