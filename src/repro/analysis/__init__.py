"""repro.analysis — ahead-of-time static lint over compiled HLO / jaxprs.

The static half of the PASTA framework: where ``repro.core`` tools observe
a *run*, these passes judge the *compiled artifact* — exposed collectives,
unintended reshards, dtype leaks, static peak memory, host syncs — so a
sharding or overlap regression is caught in CI before it burns hardware.

Quick start::

    from repro import analysis
    findings = analysis.run_passes(compiled.as_text(),
                                   "exposed-collectives:threshold_frac=0.3",
                                   mesh_axes={"data": 4, "model": 2})
    for f in findings.unsuppressed("warn"):
        print(f.severity, f.message)

See ``python -m repro.launch.lint --help`` for the config-grid driver and
the README "Static analysis" section for the baseline-suppression
workflow.
"""

from .base import (AnalysisContext, AnalysisPass, DEFAULT_SPEC,
                   PASS_REGISTRY, build_context, format_pass_spec,
                   parse_pass_spec, register_pass, resolve_passes,
                   run_passes, spec_of)
from .findings import (Baseline, Finding, Findings, SEVERITIES,
                       severity_rank)

# importing the builtin pass modules populates PASS_REGISTRY
from . import collectives as _collectives           # noqa: F401
from . import dtype as _dtype                       # noqa: F401
from . import memory as _memory                     # noqa: F401

from .memory import estimate_peak_bytes

__all__ = [
    "AnalysisContext", "AnalysisPass", "Baseline", "DEFAULT_SPEC",
    "Finding", "Findings", "PASS_REGISTRY", "SEVERITIES", "build_context",
    "estimate_peak_bytes", "format_pass_spec", "parse_pass_spec",
    "register_pass", "resolve_passes", "run_passes", "severity_rank",
    "spec_of",
]
