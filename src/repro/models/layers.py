"""Shared transformer layers: RMSNorm, RoPE/M-RoPE, GQA attention, GLU MLPs.

Numerics: matmuls in the config compute dtype (bf16), softmax/norm statistics
in f32.  Attention paths:

  * ``dense``   — full (S×T) scores; training and short prefill.
  * ``blocked`` — lax.scan over KV chunks with online softmax (flash-style);
    long prefill where S² scores would not fit.
  * ``decode``  — one (or few) query tokens against a KV cache whose sequence
    dim may be sharded over the ``model`` mesh axis; softmax statistics
    reduce globally (GSPMD inserts the small all-reduces), which is the
    flash-decode/sequence-parallel pattern for long contexts.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.instrument import op_hook
from repro.dist.sharding import shard
from .config import ModelConfig

NEG_INF = -1e30


# --------------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               m_rope: bool = False) -> jax.Array:
    """x: (B, S, H, D). positions: (B, S) or (B, S, 3) for M-RoPE."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    inv = rope_freqs(head_dim, theta)                       # (half,)
    if m_rope:
        if positions.ndim == 2:                             # text-only stub
            positions = jnp.broadcast_to(positions[..., None],
                                         (*positions.shape, 3))
        # sectioned rotary (temporal / height / width)
        s1 = half // 3
        s2 = (half - s1) // 2
        sections = [s1, s2, half - s1 - s2]
        parts = []
        off = 0
        for sec_i, sec in enumerate(sections):
            ang = positions[..., sec_i].astype(jnp.float32)[..., None] \
                * inv[off:off + sec]
            parts.append(ang)
            off += sec
        angles = jnp.concatenate(parts, axis=-1)            # (B, S, half)
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, q, kv, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, cfg.n_heads, hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, cfg.n_kv_heads, hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, cfg.n_kv_heads, hd), dtype) * s,
        "wo": jax.random.normal(k4, (cfg.n_heads, hd, d), dtype)
        * (1.0 / math.sqrt(cfg.q_dim)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attention_param_axes() -> dict:
    return {
        "wq": ("p_embed", "p_heads", None),
        "wk": ("p_embed", "p_kv_heads", None),
        "wv": ("p_embed", "p_kv_heads", None),
        "wo": ("p_heads", None, "p_embed"),
        "q_norm": (None,),
        "k_norm": (None,),
    }


def _qkv(p, x, cfg: ModelConfig, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rmsnorm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.m_rope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.m_rope)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    op_hook("attn.qkv_proj", (x, p["wq"], p["wk"], p["wv"]), (q, k, v))
    return q, k, v


def _group(q, n_kv: int):
    """(B,S,H,D) -> (B,S,Hkv,G,D) grouping query heads onto KV heads."""
    b, s, h, d_ = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d_)


def _sdpa_dense(q, k, v, causal: bool, q_offset=0,
                softmax_dtype=jnp.float32):
    """q:(B,S,Hkv,G,D) k/v:(B,T,Hkv,D). Full-scores attention.

    ``softmax_dtype=bfloat16`` keeps the (S×T) score tensors in bf16 (the
    row-max subtraction still stabilizes the exp) — halves the dominant
    HBM-traffic term of 4k-seq training at <1e-2 logit error (validated in
    tests); f32 is the paper-faithful default."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k).astype(softmax_dtype) \
        * jnp.asarray(scale, softmax_dtype)
    if causal:
        s_len, t_len = scores.shape[-2], scores.shape[-1]
        qi = jnp.arange(s_len)[:, None] + q_offset
        ki = jnp.arange(t_len)[None, :]
        scores = jnp.where(ki <= qi, scores,
                           jnp.asarray(NEG_INF, softmax_dtype))
    m = jax.lax.stop_gradient(scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m)
    w = (p / p.sum(axis=-1, keepdims=True)).astype(q.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", w, v)
    return out.reshape(*out.shape[:2], -1, out.shape[-1])    # (B,S,H,D)


def _sdpa_blocked(q, k, v, causal: bool, chunk: int = 1024):
    """Flash-style online-softmax scan over KV chunks. q:(B,S,Hkv,G,D)."""
    b, s, hkv, g, d_ = q.shape
    t = k.shape[1]
    chunk = min(chunk, t)
    while t % chunk:           # shapes are powers of two in practice
        chunk //= 2
    n_chunks = t // chunk
    scale = 1.0 / math.sqrt(d_)
    k_c = k.reshape(b, n_chunks, chunk, hkv, d_).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, n_chunks, chunk, hkv, d_).transpose(1, 0, 2, 3, 4)
    qi = jnp.arange(s)[:, None]

    def body(carry, kv_i):
        acc, m, l, ci = carry
        kc, vc = kv_i
        sc = jnp.einsum("bshgd,bthd->bhgst", q, kc).astype(jnp.float32) * scale
        if causal:
            ki = ci * chunk + jnp.arange(chunk)[None, :]
            sc = jnp.where(ki <= qi, sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p.astype(q.dtype), vc).astype(jnp.float32)
        return (acc, m_new, l_new, ci + 1), None

    acc0 = jnp.zeros((b, hkv, g, s, d_), jnp.float32)
    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(body, (acc0, m0, l0, 0), (k_c, v_c))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    out = out.transpose(0, 3, 1, 2, 4)                       # (B,S,Hkv,G,D)
    return out.reshape(b, s, hkv * g, d_)


def _sdpa_decode_partial(q, k_cache, v_cache, lengths, base=None):
    """Partial-softmax decode stats over one KV segment.

    q:(B,S,Hkv,G,D), cache:(B,T,Hkv,D). Returns (acc, m, l) f32 where
    ``acc`` is the un-normalized weighted V sum — mergeable across segments
    (flash-decode two-tier / sequence-parallel merging).

    ``base`` (B,) int32: per-row sequence length *before* this append.  When
    given with S>1 (chunked suffix prefill against a cache, the serving
    prefix-reuse path), masking is causal per query token — query ``s`` sits
    at absolute position ``base+s`` and sees keys ``ki <= base+s``.  Without
    it all S queries share the (B,)-length mask (the pre-existing one-token
    decode semantics, which ``base`` reproduces exactly at S=1)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k_cache).astype(jnp.float32) \
        * scale
    t = k_cache.shape[1]
    s = q.shape[1]
    if base is None or s == 1:
        mask = jnp.arange(t)[None, :] < lengths[:, None]     # (B,T)
        scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    else:
        limit = base[:, None] + jnp.arange(1, s + 1)[None, :]   # (B,S)
        mask = jnp.arange(t)[None, None, :] < limit[:, :, None]  # (B,S,T)
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    m = scores.max(axis=-1)                                  # (B,H,G,S)
    p = jnp.exp(scores - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgst,bthd->bhgsd", p.astype(q.dtype),
                     v_cache).astype(jnp.float32)
    return acc, m, l


def _merge_partials(parts):
    """Merge flash-decode partials [(acc, m, l), ...] exactly."""
    accs, ms, ls = zip(*parts)
    m_all = jnp.stack(ms).max(axis=0)
    acc = sum(a * jnp.exp(m - m_all)[..., None] for a, m in zip(accs, ms))
    l_all = sum(l * jnp.exp(m - m_all) for l, m in zip(ls, ms))
    return acc / jnp.maximum(l_all, 1e-30)[..., None], m_all, l_all


def _sdpa_decode(q, k_cache, v_cache, lengths, base=None):
    """Decode: q:(B,S,Hkv,G,D), cache:(B,T,Hkv,D) possibly seq-sharded over
    the model axis; masked softmax over the cache with global statistics.
    ``base``: per-row pre-append lengths for causal multi-token appends."""
    acc, m, l = _sdpa_decode_partial(q, k_cache, v_cache, lengths, base=base)
    out = (acc / jnp.maximum(l, 1e-30)[..., None])
    out = out.astype(q.dtype).transpose(0, 3, 1, 2, 4)       # (B,S,Hkv,G,D)
    b, s = out.shape[:2]
    return out.reshape(b, s, -1, out.shape[-1])


def attention(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              cache: dict | None = None, blocked_threshold: int | None = None):
    """Returns (out, new_cache). ``cache``: {"k","v": (B,T,Hkv,D),
    "length": (B,) int32} — decode appends at ``length``."""
    if blocked_threshold is None:
        blocked_threshold = cfg.attn_blocked_threshold
    q, k, v = _qkv(p, x, cfg, positions)
    qg = _group(q, cfg.n_kv_heads)
    if cache is None:
        if x.shape[1] > blocked_threshold:
            out = _sdpa_blocked(qg, k, v, cfg.causal)
        else:
            out = _sdpa_dense(qg, k, v, cfg.causal,
                              softmax_dtype=jnp.dtype(cfg.attn_softmax_dtype))
        new_cache = {"k": k, "v": v,
                     "length": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}
    elif "rk" in cache:
        # two-tier decode: the big seq-sharded main cache stays FROZEN (no
        # per-layer masked-select rewrite); new tokens append into a small
        # replicated recent buffer; partial softmaxes merge exactly.
        lengths = cache["length"] + x.shape[1]
        main_len = cache["main_len"]
        pos_r = (cache["length"] - main_len)[0]
        rk = jax.lax.dynamic_update_slice_in_dim(cache["rk"], k, pos_r, 1)
        rv = jax.lax.dynamic_update_slice_in_dim(cache["rv"], v, pos_r, 1)
        p_main = _sdpa_decode_partial(qg, cache["k"], cache["v"], main_len)
        p_rec = _sdpa_decode_partial(qg, rk, rv, lengths - main_len)
        norm, _m, _l = _merge_partials([p_main, p_rec])
        out = norm.astype(q.dtype).transpose(0, 3, 1, 2, 4)
        out = out.reshape(out.shape[0], out.shape[1], -1, out.shape[-1])
        new_cache = {"k": cache["k"], "v": cache["v"], "rk": rk, "rv": rv,
                     "length": lengths, "main_len": main_len}
    elif "pk" in cache:
        # paged decode/append: {"pk","pv": (n_blocks, bs, Hkv, D),
        # "bt": (B, blocks_per_seq) int32 block tables (sentinel = n_blocks),
        # "length": (B,)}.  New K/V scatter through each row's block table
        # at absolute positions base..base+S-1; the softmax then gathers the
        # row's window back as a contiguous (B, T) view — same values the
        # dense slot layout would hold, so numerics match it exactly.
        pk, pv, bt = cache["pk"], cache["pv"], cache["bt"]
        base = cache["length"]
        n_blocks, bs_blk = pk.shape[0], pk.shape[1]
        b, s = x.shape[0], x.shape[1]
        t = bt.shape[1] * bs_blk
        pos = base[:, None] + jnp.arange(s)[None, :]             # (B,S)
        col = jnp.minimum(pos // bs_blk, bt.shape[1] - 1)
        blk = jnp.take_along_axis(bt, col, axis=1)               # (B,S)
        # rows parked at length >= T (free slots, mid-prefill rows riding a
        # fused decode) resolve to the sentinel: their writes drop
        blk = jnp.where(pos < t, blk, n_blocks)
        off = pos % bs_blk
        pk = pk.at[blk, off].set(k, mode="drop")
        pv = pv.at[blk, off].set(v, mode="drop")
        k_cache = pk[bt].reshape(b, t, *pk.shape[2:])
        v_cache = pv[bt].reshape(b, t, *pv.shape[2:])
        lengths = base + s
        out = _sdpa_decode(qg, k_cache, v_cache, lengths,
                           base=base if s > 1 else None)
        new_cache = {"pk": pk, "pv": pv, "bt": bt, "length": lengths}
    else:
        base = cache["length"]
        if x.shape[1] == 1:
            # ragged-slot decode: each row appends at ITS OWN length (the
            # serving pool interleaves requests at different positions);
            # out-of-range rows (stale free slots at max_seq) drop the write
            b_idx = jnp.arange(k.shape[0])
            k_cache = cache["k"].at[b_idx, base].set(k[:, 0], mode="drop")
            v_cache = cache["v"].at[b_idx, base].set(v[:, 0], mode="drop")
        else:
            # multi-token append (chunked/suffix prefill, fused speculative
            # verify): each row writes at ITS OWN base — the verify batch
            # interleaves requests at different positions, and single-row
            # suffix prefill is just the B=1 case.  Out-of-range positions
            # (parked rows at max_seq, draft spill past the horizon) drop.
            b_idx = jnp.arange(k.shape[0])[:, None]
            pos = base[:, None] + jnp.arange(x.shape[1])[None, :]
            k_cache = cache["k"].at[b_idx, pos].set(k, mode="drop")
            v_cache = cache["v"].at[b_idx, pos].set(v, mode="drop")
        k_cache = shard(k_cache, "batch", "seq_sp", None, "head_dim")
        v_cache = shard(v_cache, "batch", "seq_sp", None, "head_dim")
        lengths = base + x.shape[1]
        out = _sdpa_decode(qg, k_cache, v_cache, lengths, base=base)
        new_cache = {"k": k_cache, "v": v_cache, "length": lengths}
    out = shard(out, "batch", "seq", "heads", "head_dim")
    op_hook("attn.sdpa", (q, k, v), (out,))
    y = jnp.einsum("bshd,hdm->bsm", out, p["wo"].astype(x.dtype))
    y = shard(y, "batch", "seq", "embed")
    op_hook("attn.out_proj", (out, p["wo"]), (y,))
    return y, new_cache


# ----------------------------------------------------------------------- mlp
def init_mlp(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "w_gate": jax.random.normal(k1, (d, f), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d, f), dtype) * s_in,
        "w_down": jax.random.normal(k3, (f, d), dtype) * s_out,
    }


def mlp_param_axes() -> dict:
    return {"w_gate": ("p_embed", "p_ff"), "w_up": ("p_embed", "p_ff"),
            "w_down": ("p_ff", "p_embed")}


def mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    g = shard(g, "batch", "seq", "ff")
    u = shard(u, "batch", "seq", "ff")
    act = jax.nn.gelu(g) if cfg.mlp == "geglu" else jax.nn.silu(g)
    h = act * u
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    y = shard(y, "batch", "seq", "embed")
    op_hook("mlp.glu", (x, p["w_gate"], p["w_up"], p["w_down"]), (g, u, y))
    return y
