"""Model substrate: unified decoder LM (dense/GQA, MoE, Mamba2-SSD, hybrid)."""

from .config import ModelConfig
from .lm import (init_params, param_axes, forward, init_cache, cache_axes,
                 cross_entropy)
from . import layers, mamba2, moe
