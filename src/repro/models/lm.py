"""Unified decoder LM covering all assigned families.

  * dense / vlm / audio — GQA attention + GLU MLP blocks (vlm/audio differ
    only in the stubbed modality frontend and M-RoPE);
  * moe   — attention + sort-based capacity MoE blocks;
  * ssm   — Mamba2 (SSD) blocks, attention-free;
  * hybrid — Mamba2 backbone with ONE weight-shared transformer block applied
    after every ``shared_attn_every`` SSM layers (Zamba2): layers are scanned
    in (group, layer-in-group) shape so each shared-attention application has
    its own KV-cache slot while the block weights stay shared.

All layer stacks run under ``jax.lax.scan`` (compact HLO, fast compiles at
512 devices) with configurable remat.  Instrumented eager execution for the
PASTA tools goes through :mod:`repro.core.instrument` hooks, which are
no-ops under tracing.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.instrument import op_hook
from repro.dist.sharding import shard
from .config import ModelConfig
from . import layers as L
from . import mamba2 as M
from . import moe as MOE


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg: ModelConfig) -> dict:
    dt = _pdtype(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {}
    if cfg.frontend == "none":
        p["embed"] = jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), dt) * 0.02
    if not cfg.tie_embeddings and cfg.vocab_size:
        p["lm_head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), dt) \
            / math.sqrt(cfg.d_model)
    p["final_norm"] = jnp.zeros((cfg.d_model,), dt)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def one(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            blk = {"ln1": jnp.zeros((cfg.d_model,), dt),
                   "ln2": jnp.zeros((cfg.d_model,), dt),
                   "attn": L.init_attention(k1, cfg, dt)}
            if cfg.family == "moe":
                blk["moe"] = MOE.init_moe(k2, cfg, dt)
            else:
                blk["mlp"] = L.init_mlp(k3, cfg, dt)
            return blk
        p["layers"] = _stack_init(one, keys[2], cfg.n_layers)
    elif cfg.family == "ssm":
        def one(k):
            return {"ln": jnp.zeros((cfg.d_model,), dt),
                    "mamba": M.init_mamba2(k, cfg, dt)}
        p["layers"] = _stack_init(one, keys[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every

        def one(k):
            return {"ln": jnp.zeros((cfg.d_model,), dt),
                    "mamba": M.init_mamba2(k, cfg, dt)}
        grouped = _stack_init(one, keys[2], n_groups * every)
        p["groups"] = jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:]), grouped)
        if tail:
            p["tail"] = _stack_init(one, keys[3], tail)
        p["shared"] = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": L.init_attention(keys[4], cfg, dt),
            "mlp": L.init_mlp(keys[5], cfg, dt),
        }
    else:
        raise ValueError(cfg.family)
    return p


def param_axes(cfg: ModelConfig) -> dict:
    """Logical sharding axes mirroring the param tree (leading 'p_layers'
    prepended for stacked leaves)."""
    def stack(d, extra=1):
        return jax.tree.map(lambda ax: ("p_layers",) * extra + tuple(ax), d,
                            is_leaf=lambda x: isinstance(x, tuple))

    axes: dict = {"final_norm": (None,)}
    if cfg.frontend == "none":
        axes["embed"] = ("p_vocab", "p_embed")
    if not cfg.tie_embeddings and cfg.vocab_size:
        axes["lm_head"] = ("p_embed", "p_vocab")
    blk_attn = {"ln1": (None,), "ln2": (None,),
                "attn": L.attention_param_axes()}
    if cfg.qk_norm is False:
        blk_attn["attn"] = {k: v for k, v in blk_attn["attn"].items()
                            if k not in ("q_norm", "k_norm")}
    if cfg.family in ("dense", "vlm", "audio"):
        axes["layers"] = stack({**blk_attn, "mlp": L.mlp_param_axes()})
    elif cfg.family == "moe":
        axes["layers"] = stack({**blk_attn, "moe": MOE.moe_param_axes(cfg)})
    elif cfg.family == "ssm":
        axes["layers"] = stack({"ln": (None,), "mamba": M.mamba2_param_axes()})
    elif cfg.family == "hybrid":
        axes["groups"] = stack({"ln": (None,),
                                "mamba": M.mamba2_param_axes()}, extra=2)
        every = cfg.shared_attn_every
        if cfg.n_layers % every:
            axes["tail"] = stack({"ln": (None,),
                                  "mamba": M.mamba2_param_axes()})
        axes["shared"] = {**blk_attn, "mlp": L.mlp_param_axes()}
    return axes


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _instrumented_eager(x) -> bool:
    """True when a PASTA eager instrumenter is active and we are NOT under
    tracing: layer stacks then run as Python loops instead of lax.scan (scan
    always traces its body, which would silence the operator hooks)."""
    from repro.core import instrument
    return instrument.ACTIVE is not None \
        and not isinstance(x, jax.core.Tracer)


def _tree_at(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _attn_block(blk, h, cfg, positions, cache=None):
    a, new_cache = L.attention(blk["attn"], L.rmsnorm(h, blk["ln1"],
                                                      cfg.rmsnorm_eps),
                               cfg, positions, cache)
    h = h + a
    if "moe" in blk:
        y, aux = MOE.moe_layer(blk["moe"], L.rmsnorm(h, blk["ln2"],
                                                     cfg.rmsnorm_eps), cfg)
    else:
        y = L.mlp(blk["mlp"], L.rmsnorm(h, blk["ln2"], cfg.rmsnorm_eps), cfg)
        aux = {}
    return h + y, new_cache, aux


def _mamba_block(blk, h, cfg, state=None):
    y, new_state = M.mamba2_layer(blk["mamba"],
                                  L.rmsnorm(h, blk["ln"], cfg.rmsnorm_eps),
                                  cfg, state)
    return h + y, new_state


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Decode caches, zero-initialized (filled by prefill)."""
    dt = _dtype(cfg)

    def kv(n):
        out = {
            "k": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "length": jnp.zeros((batch,), jnp.int32),
        }
        if cfg.kv_two_tier and cfg.family != "hybrid":
            out["rk"] = jnp.zeros((n, batch, cfg.kv_recent_len,
                                   cfg.n_kv_heads, cfg.head_dim), dt)
            out["rv"] = jnp.zeros_like(out["rk"])
            out["main_len"] = jnp.zeros((batch,), jnp.int32)
        return out
    ssm = lambda *lead: {                                  # noqa: E731
        "conv_x": jnp.zeros((*lead, batch, cfg.ssm_conv_width - 1,
                             cfg.d_inner), dt),
        "conv_B": jnp.zeros((*lead, batch, cfg.ssm_conv_width - 1,
                             cfg.ssm_groups * cfg.ssm_state), dt),
        "conv_C": jnp.zeros((*lead, batch, cfg.ssm_conv_width - 1,
                             cfg.ssm_groups * cfg.ssm_state), dt),
        "ssm": jnp.zeros((*lead, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
    }
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        return {"kv": kv(cfg.n_layers)}
    if cfg.family == "ssm":
        return {"ssm": ssm(cfg.n_layers),
                "length": jnp.zeros((batch,), jnp.int32)}
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    tail = cfg.n_layers - n_groups * every
    out = {"kv": kv(n_groups), "ssm_groups": ssm(n_groups, every)}
    if tail:
        out["ssm_tail"] = ssm(tail)
    return out


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical sharding for caches: KV sequence shards over `model` (SP
    flash-decode), batch over data axes; SSM heads over `model`."""
    kv = {"k": (None, "batch", "seq_sp", None, None),
          "v": (None, "batch", "seq_sp", None, None),
          "length": (None,)}
    if cfg.kv_two_tier and cfg.family != "hybrid":
        kv.update({"rk": (None, "batch", None, None, None),
                   "rv": (None, "batch", None, None, None),
                   "main_len": (None,)})
    ssm = lambda n: {                                      # noqa: E731
        "conv_x": (None,) * n + ("batch", None, "p_ssm_inner"),
        "conv_B": (None,) * n + ("batch", None, None),
        "conv_C": (None,) * n + ("batch", None, None),
        "ssm": (None,) * n + ("batch", "ssm_heads", None, None),
    }
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        return {"kv": kv}
    if cfg.family == "ssm":
        return {"ssm": ssm(1), "length": (None,)}
    out = {"kv": kv, "ssm_groups": ssm(2)}
    every = cfg.shared_attn_every
    if cfg.n_layers % every:
        out["ssm_tail"] = ssm(1)
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params: dict, inputs: jax.Array, cfg: ModelConfig,
            cache: dict | None = None, positions: jax.Array | None = None,
            return_cache: bool = False, logits_mode: str = "all",
            logits_index: jax.Array | None = None):
    """inputs: (B,S) int tokens or (B,S,d) embeddings (frontend stub).
    Returns (logits, new_cache_or_None).  ``return_cache=True`` without an
    input cache collects the prefill KV/SSM caches.  ``logits_mode="index"``
    runs the lm_head on per-row positions gathered from ``logits_index`` —
    (B,) for ragged right-padded serving prefill (each row's last real token
    sits at a different offset), or (B, P) to read logits at several
    positions per row (speculative verify reads every draft position)."""
    dt = _dtype(cfg)
    if inputs.ndim == 2 and cfg.frontend == "none":
        h = params["embed"].astype(dt)[inputs]
        op_hook("embed.lookup", (inputs, params["embed"]), (h,))
    else:
        h = inputs.astype(dt)
    b, s = h.shape[0], h.shape[1]
    h = shard(h, "batch", "seq", "embed")
    if positions is None:
        if cache is not None:
            base = _cache_length(cache, cfg)
            positions = base[:, None] + jnp.arange(s)[None, :]
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        h, new_cache = _run_stacked_attn(params, h, cfg, positions, cache,
                                         return_cache)
    elif cfg.family == "ssm":
        h, new_cache = _run_stacked_ssm(params, h, cfg, cache, return_cache)
    else:
        h, new_cache = _run_hybrid(params, h, cfg, positions, cache,
                                   return_cache)

    h = L.rmsnorm(h, params["final_norm"], cfg.rmsnorm_eps)
    if logits_mode == "last":
        h = h[:, -1:, :]          # serving: lm_head on the new token only
    elif logits_mode == "index":
        if logits_index.ndim == 2:
            h = jnp.take_along_axis(h, logits_index[..., None], axis=1)
        else:
            h = h[jnp.arange(h.shape[0])[:, None], logits_index[:, None]]
    if cfg.tie_embeddings and "embed" in params:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(dt))
    elif "lm_head" in params:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(dt))
    else:
        logits = h
    logits = shard(logits, "batch", "seq", "vocab")
    op_hook("lm_head", (h,), (logits,))
    return logits, new_cache


def _cache_length(cache: dict, cfg: ModelConfig):
    if "kv" in cache:
        return cache["kv"]["length"]
    # pure ssm: track via a length entry added by the serve engine
    return cache.get("length", jnp.zeros((1,), jnp.int32))


def _run_stacked_attn(params, h, cfg, positions, cache, return_cache=False):
    layers = params["layers"]
    if cache is None and not return_cache and _instrumented_eager(h):
        n = jax.tree.leaves(layers)[0].shape[0]
        for i in range(n):
            op_hook(f"layer{i}", (h,), ())
            h, _kv, _aux = _attn_block(_tree_at(layers, i), h, cfg,
                                       positions, None)
        return h, None

    def body(carry, xs):
        hh = carry
        if cache is None:
            blk = xs
            hh2, kv, _aux = _attn_block(blk, hh, cfg, positions, None)
            ys = {"k": kv["k"], "v": kv["v"]} if return_cache else None
            return hh2, ys
        blk, kv_slice = xs
        hh2, new_kv, _aux = _attn_block(blk, hh, cfg, positions, kv_slice)
        if "rk" in new_kv:
            # two-tier: the frozen main cache is NOT re-emitted (no rewrite)
            return hh2, {"rk": new_kv["rk"], "rv": new_kv["rv"]}
        if "pk" in new_kv:
            # paged: only the block pool is per-layer state
            return hh2, {"pk": new_kv["pk"], "pv": new_kv["pv"]}
        return hh2, {"k": new_kv["k"], "v": new_kv["v"]}

    body = _remat(cfg, body)
    if cache is None:
        h, kv = jax.lax.scan(body, h, layers)
        if not return_cache:
            return h, None
        length = jnp.full((h.shape[0],), h.shape[1], jnp.int32)
        return h, {"kv": {"k": kv["k"], "v": kv["v"], "length": length}}
    kv = cache["kv"]
    if "pk" in kv:
        n_layers = kv["pk"].shape[0]
    else:
        n_layers = kv["k"].shape[0]
    bcast = lambda a: jnp.broadcast_to(a, (n_layers, *a.shape))  # noqa: E731
    if "pk" in kv:
        # paged: the block pool carries the layer axis; block tables and
        # lengths are shared across layers (broadcast like lengths below)
        per_layer = {"pk": kv["pk"], "pv": kv["pv"], "bt": bcast(kv["bt"]),
                     "length": bcast(kv["length"])}
        h, new_kv = jax.lax.scan(body, h, (layers, per_layer))
        return h, {"kv": {"pk": new_kv["pk"], "pv": new_kv["pv"],
                          "bt": kv["bt"],
                          "length": kv["length"] + h.shape[1]}}
    per_layer = {"k": kv["k"], "v": kv["v"], "length": bcast(kv["length"])}
    if "rk" in kv:
        per_layer.update({"rk": kv["rk"], "rv": kv["rv"],
                          "main_len": bcast(kv["main_len"])})
    h, new_kv = jax.lax.scan(body, h, (layers, per_layer))
    if "rk" in kv:
        new_cache = {"kv": {"k": kv["k"], "v": kv["v"],
                            "rk": new_kv["rk"], "rv": new_kv["rv"],
                            "main_len": kv["main_len"],
                            "length": kv["length"] + h.shape[1]}}
    else:
        new_cache = {"kv": {"k": new_kv["k"], "v": new_kv["v"],
                            "length": kv["length"] + h.shape[1]}}
    return h, new_cache


def _run_stacked_ssm(params, h, cfg, cache, return_cache=False):
    layers = params["layers"]
    if cache is None and not return_cache and _instrumented_eager(h):
        n = jax.tree.leaves(layers)[0].shape[0]
        for i in range(n):
            op_hook(f"layer{i}", (h,), ())
            h, _st = _mamba_block(_tree_at(layers, i), h, cfg, None)
        return h, None

    def body(carry, xs):
        hh = carry
        if cache is None:
            blk = xs
            hh2, st = _mamba_block(blk, hh, cfg, None)
            return hh2, (st if return_cache else None)
        blk, st = xs
        hh2, new_st = _mamba_block(blk, hh, cfg, st)
        return hh2, new_st

    body = _remat(cfg, body)
    if cache is None:
        h, states = jax.lax.scan(body, h, layers)
        if not return_cache:
            return h, None
        return h, {"ssm": states,
                   "length": jnp.full((h.shape[0],), h.shape[1], jnp.int32)}
    h, new_ssm = jax.lax.scan(body, h, (layers, cache["ssm"]))
    new_cache = {"ssm": new_ssm,
                 "length": cache.get("length", 0) + h.shape[1]}
    return h, new_cache


def _run_hybrid(params, h, cfg, positions, cache, return_cache=False):
    shared = params["shared"]
    if cache is None and not return_cache and _instrumented_eager(h):
        groups = params["groups"]
        n_g = jax.tree.leaves(groups)[0].shape[0]
        every = jax.tree.leaves(groups)[0].shape[1]
        for gi in range(n_g):
            for li in range(every):
                op_hook(f"group{gi}.layer{li}", (h,), ())
                h, _ = _mamba_block(_tree_at(_tree_at(groups, gi), li),
                                    h, cfg, None)
            op_hook(f"group{gi}.shared_attn", (h,), ())
            h, _kv, _aux = _attn_block(shared, h, cfg, positions, None)
        if "tail" in params:
            n_t = jax.tree.leaves(params["tail"])[0].shape[0]
            for ti in range(n_t):
                h, _ = _mamba_block(_tree_at(params["tail"], ti), h, cfg,
                                    None)
        return h, None

    def group_body(carry, xs):
        hh = carry
        if cache is None:
            grp = xs
            def inner(c, blk):
                c2, st = _mamba_block(blk, c, cfg, None)
                return c2, (st if return_cache else None)
            hh, states = jax.lax.scan(inner, hh, grp)
            hh, kv, _aux = _attn_block(shared, hh, cfg, positions, None)
            if return_cache:
                return hh, (states, {"k": kv["k"], "v": kv["v"]})
            return hh, None
        grp, sstates, kv_slice = xs
        def inner(c, blk_st):
            blk, st = blk_st
            c2, new_st = _mamba_block(blk, c, cfg, st)
            return c2, new_st
        hh, new_states = jax.lax.scan(inner, hh, (grp, sstates))
        hh, new_kv, _aux = _attn_block(shared, hh, cfg, positions, kv_slice)
        return hh, (new_states, {"k": new_kv["k"], "v": new_kv["v"]})

    group_body = _remat(cfg, group_body)
    if cache is None:
        h, ys = jax.lax.scan(group_body, h, params["groups"])
        new_cache = None
        if return_cache:
            states, kv = ys
            length = jnp.full((h.shape[0],), h.shape[1], jnp.int32)
            new_cache = {"kv": {"k": kv["k"], "v": kv["v"], "length": length},
                         "ssm_groups": states}
        if "tail" in params:
            def tail_body(c, blk):
                c2, st = _mamba_block(blk, c, cfg, None)
                return c2, (st if return_cache else None)
            tail_body = _remat(cfg, tail_body)
            h, tail_states = jax.lax.scan(tail_body, h, params["tail"])
            if return_cache:
                new_cache["ssm_tail"] = tail_states
        return h, new_cache

    kv = cache["kv"]
    n_groups = kv["k"].shape[0]
    per_group_kv = {"k": kv["k"], "v": kv["v"],
                    "length": jnp.broadcast_to(kv["length"],
                                               (n_groups,
                                                *kv["length"].shape))}
    h, (new_ssm_g, new_kv) = jax.lax.scan(
        group_body, h, (params["groups"], cache["ssm_groups"], per_group_kv))
    new_cache = {"kv": {"k": new_kv["k"], "v": new_kv["v"],
                        "length": kv["length"] + h.shape[1]},
                 "ssm_groups": new_ssm_g}
    if "tail" in params:
        def tail_body(c, blk_st):
            blk, st = blk_st
            c2, new_st = _mamba_block(blk, c, cfg, st)
            return c2, new_st
        tail_body = _remat(cfg, tail_body)
        h, new_tail = jax.lax.scan(tail_body, h,
                                   (params["tail"], cache["ssm_tail"]))
        new_cache["ssm_tail"] = new_tail
    return h, new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4):
    """Mean next-token CE in f32 (+ z-loss for logit drift)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zl = z_loss * jnp.square(lse)
    return (nll + zl).mean(), {"ce": nll.mean(), "z": zl.mean()}
