"""Model configuration schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # flavor
    mlp: str = "swiglu"          # swiglu | geglu
    qk_norm: bool = False
    rope_theta: float = 1e4
    m_rope: bool = False         # sectioned multimodal RoPE (qwen2-vl)
    causal: bool = True
    tie_embeddings: bool = False
    rmsnorm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # hybrid layout: shared attention block applied after every k SSM layers
    shared_attn_every: int = 0

    # modality frontend ("none" = token ids; "embed" = precomputed
    # frame/patch embeddings supplied by input_specs — the assignment's stub)
    frontend: str = "none"

    # numerics / parallelism profile
    dtype: str = "bfloat16"
    param_dtype: str = "float32"       # master params ("float32"|"bfloat16")
    opt_moment_dtype: str = "float32"  # Adam moments ("float32"|"int8")
    remat: str = "full"                # full | dots | none
    # perf knobs (hillclimb levers; defaults = paper-faithful baseline)
    attn_softmax_dtype: str = "float32"   # "float32" | "bfloat16"
    attn_blocked_threshold: int = 8192    # seq len above which the flash-
                                          # style blocked kernel is used
    moe_parallelism: str = "tp"           # "tp" (hidden-dim) | "ep" (experts)
    gather_params_once: bool = False      # hoist FSDP all-gathers out of the
                                          # microbatch loop (ZeRO-2-style)
    kv_two_tier: bool = False             # decode: frozen seq-sharded main
                                          # cache + small replicated append
                                          # buffer (kills the per-layer
                                          # masked-select cache rewrite)
    kv_recent_len: int = 128              # append-buffer slots
    # attention-free archs can run 0.5M-token shapes; full-attention skip
    supports_long_context: bool = False

    # ---------------------------------------------------------------- derived
    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.family in ("ssm",):
            return False
        if self.family == "hybrid":
            return self.shared_attn_every > 0 and \
                (i + 1) % self.shared_attn_every == 0
        return True

    def is_ssm_layer(self, i: int) -> bool:
        return self.family == "ssm" or self.family == "hybrid"

    # --------------------------------------------------------- param counts
    def embed_params(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n *= 2
        return n

    def attn_params_per_layer(self) -> int:
        return (self.d_model * self.q_dim          # Wq
                + 2 * self.d_model * self.kv_dim   # Wk, Wv
                + self.q_dim * self.d_model)       # Wo

    def mlp_params_per_layer(self) -> int:
        if self.family in ("moe",) and self.n_experts:
            per_e = 3 * self.d_model * self.d_ff_expert
            return (self.n_experts + self.n_shared_experts) * per_e \
                + self.d_model * self.n_experts        # router
        return 3 * self.d_ff * self.d_model            # swiglu/geglu

    def mlp_active_params_per_layer(self) -> int:
        if self.family in ("moe",) and self.n_experts:
            per_e = 3 * self.d_model * self.d_ff_expert
            return (self.n_experts_active + self.n_shared_experts) * per_e \
                + self.d_model * self.n_experts
        return self.mlp_params_per_layer()

    def ssm_params_per_layer(self) -> int:
        di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
        g = self.ssm_groups
        in_proj = self.d_model * (2 * di + 2 * g * ds + nh)
        conv = self.ssm_conv_width * (di + 2 * g * ds)
        out_proj = di * self.d_model
        return in_proj + conv + out_proj + 3 * nh      # A, dt_bias, D

    def params_per_layer(self, i: int) -> int:
        if self.family == "ssm":
            return self.ssm_params_per_layer()
        if self.family == "hybrid":
            return self.ssm_params_per_layer()         # shared attn counted once
        return self.attn_params_per_layer() + self.mlp_params_per_layer()

    @property
    def n_params(self) -> int:
        total = self.embed_params()
        total += sum(self.params_per_layer(i) for i in range(self.n_layers))
        if self.family == "hybrid" and self.shared_attn_every:
            # one shared transformer block (attn + mlp), weights shared
            total += self.attn_params_per_layer() + 3 * self.d_ff * self.d_model
        return total

    @property
    def n_active_params(self) -> int:
        if self.family != "moe":
            return self.n_params
        total = self.embed_params()
        total += self.n_layers * (self.attn_params_per_layer()
                                  + self.mlp_active_params_per_layer())
        return total
