"""Mixture-of-Experts layer: sort-based capacity dispatch + block-diagonal
expert matmuls.

Dispatch avoids the classic (tokens × experts × capacity) one-hot einsum —
whose FLOPs would swamp the real compute at 384 experts — in favour of a
sort/scatter pipeline whose arithmetic cost is negligible and whose expert
matmuls cost exactly ``2 · E · C · d · f`` = active-FLOPs × capacity factor:

  1. router: softmax(x @ Wg) → top-k experts + gates per token;
  2. stable argsort of the (T·k) expert assignments → contiguous groups;
  3. rank-in-group via group starts (searchsorted); tokens past the per-
     expert capacity C are dropped (standard capacity semantics);
  4. scatter token rows into the (E, C, d) buffer; two batched einsums
     (SwiGLU) over the expert dim; gather back; gate-weighted sum over k.

Parallelism: expert *hidden* dim shards over the ``model`` axis (TP-MoE —
routing stays local, no all-to-all; the classic EP all-to-all variant is a
perf-loop alternative), experts' leading dim shards over ``data`` for ZeRO-3.
Shared experts (Kimi-style) run densely for every token.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.instrument import op_hook
from repro.dist.sharding import shard
from .config import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * s_out,
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["ws_gate"] = jax.random.normal(k1, (d, fs), dtype) * s_in
        p["ws_up"] = jax.random.normal(k2, (d, fs), dtype) * s_in
        p["ws_down"] = jax.random.normal(k3, (fs, d), dtype) * s_out
    return p


def moe_param_axes(cfg: ModelConfig) -> dict:
    if cfg.moe_parallelism == "ep":
        # experts over `model`, d_model over `data` (FSDP); hidden dim local
        axes = {
            "router": ("p_embed", None),
            "w_gate": ("p_experts_ep", "p_embed", None),
            "w_up": ("p_experts_ep", "p_embed", None),
            "w_down": ("p_experts_ep", None, "p_embed"),
        }
    else:
        axes = {
            "router": ("p_embed", None),
            "w_gate": ("p_experts", "p_embed", "p_expert_ff"),
            "w_up": ("p_experts", "p_embed", "p_expert_ff"),
            "w_down": ("p_experts", "p_expert_ff", "p_embed"),
        }
    if cfg.n_shared_experts:
        axes.update({"ws_gate": ("p_embed", "p_expert_ff"),
                     "ws_up": ("p_embed", "p_expert_ff"),
                     "ws_down": ("p_expert_ff", "p_embed")})
    return axes


def _dispatch_group(xt, probs, k: int, e: int, cap: int, dt):
    """Sort-based capacity dispatch for ONE token group (vmapped over the
    data-parallel group dim so routing never crosses shards)."""
    t = xt.shape[0]
    gates, topk = jax.lax.top_k(probs, k)                  # (t,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    flat_e = topk.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)               # (t·k,)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=sorted_e.dtype))
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < cap
    slot = sorted_e.astype(jnp.int32) * cap + jnp.clip(rank, 0, cap - 1)
    slot = jnp.where(keep, slot, e * cap)                  # overflow row
    src = order // k                                       # source token copy
    d = xt.shape[-1]
    xe = jnp.zeros((e * cap + 1, d), dt).at[slot].set(
        xt[src], mode="drop", unique_indices=False)
    return xe[:e * cap].reshape(e, cap, d), (gates, order, slot, keep)


def _combine_group(ye, gates, order, slot, keep, k: int, dt):
    e, cap, d = ye.shape
    t = gates.shape[0]
    ye_flat = jnp.concatenate([ye.reshape(e * cap, d),
                               jnp.zeros((1, d), dt)], axis=0)
    y_copies = jnp.where(keep[:, None], ye_flat[slot], 0)  # (t·k, d)
    y_sorted = jnp.zeros((t * k, d), dt).at[order].set(y_copies)
    return (y_sorted.reshape(t, k, d) * gates.astype(dt)[..., None]).sum(1)


def moe_layer(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B,S,d). Returns (y, aux) with load-balancing stats.

    Tokens are reshaped to (G, t/G, d) with G = the data-parallel degree and
    the group dim sharded over it, so dispatch (argsort/scatter) is local to
    each data shard and expert matmuls carry exactly the active FLOPs ×
    capacity factor per device.  Expert hidden dim shards over ``model``
    (TP-MoE: no all-to-all; the EP all-to-all variant is a perf-loop
    alternative — see repro.dist).
    """
    from repro.dist.sharding import mesh_axis_size
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    t = b * s
    g = mesh_axis_size("pod") * mesh_axis_size("data")
    while g > 1 and t % g:
        g //= 2
    tl = t // g
    xt = x.reshape(g, tl, d)
    xt = shard(xt, "batch", None, "embed")

    # ---- router (f32) -----------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)

    cap = int(math.ceil(tl * k / e * cfg.capacity_factor))
    cap = max(4, min(cap, tl))
    xe, meta = jax.vmap(
        lambda xg, pg: _dispatch_group(xg, pg, k, e, cap, dt))(xt, probs)
    ep = cfg.moe_parallelism == "ep"
    e_ax = "experts_ep" if ep else "experts"
    f_ax = None if ep else "expert_ff"
    # EP: this constraint is the token all-to-all (capacity rows redistribute
    # from data-sharded groups to expert-sharded devices); TP: replicated
    # expert dim, hidden dim sharded — no token movement.
    xe = shard(xe, "batch", e_ax, None, "embed")           # (g,e,cap,d)

    # ---- expert SwiGLU (block-diagonal over experts) ------------------------
    gt = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    gt = shard(gt, "batch", e_ax, None, f_ax)
    u = shard(u, "batch", e_ax, None, f_ax)
    h = jax.nn.silu(gt) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    ye = shard(ye, "batch", e_ax, None, "embed")
    op_hook("moe.experts", (xe, p["w_gate"], p["w_up"], p["w_down"]), (ye,))

    y = jax.vmap(lambda yg, m: _combine_group(yg, *m, k, dt))(ye, meta)

    # ---- shared experts (dense) --------------------------------------------
    if cfg.n_shared_experts:
        sg = jnp.einsum("gtd,df->gtf", xt, p["ws_gate"].astype(dt))
        su = jnp.einsum("gtd,df->gtf", xt, p["ws_up"].astype(dt))
        sg = shard(sg, "batch", None, "expert_ff")
        su = shard(su, "batch", None, "expert_ff")
        y = y + jnp.einsum("gtf,fd->gtd", jax.nn.silu(sg) * su,
                           p["ws_down"].astype(dt))

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))                           # (e,)
    _gates, _order, slot, keep = meta
    flat_e = jnp.clip(slot // cap, 0, e - 1)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(
        keep.reshape(-1).astype(jnp.float32)) / (t * k)
    aux = {"lb_loss": e * jnp.sum(me * ce),
           "dropped_frac": 1.0 - keep.mean()}
    y = y.reshape(b, s, d)
    return shard(y, "batch", "seq", "embed"), aux
