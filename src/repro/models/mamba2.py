"""Mamba2 (SSD — state-space duality) blocks, chunked for TPU.

Training/prefill uses the SSD chunked algorithm (arXiv:2405.21060): the
sequence is split into chunks of Q tokens; within a chunk the recurrence is
materialized as a (Q×Q) lower-triangular "attention-like" matrix (MXU
friendly), and chunk states are passed through a scan — O(S·Q) instead of
O(S²), O(1) state for decode.

State decay products are computed in log space (segment-sum trick) in f32;
projections run in the compute dtype.  SSM heads shard over the ``model``
mesh axis (the TPU-native analogue of Mamba2's head parallelism); the state
dim stays local so the scan carries no collectives.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.instrument import op_hook
from repro.dist.sharding import shard
from .config import ModelConfig
from .layers import rmsnorm


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, ds, nh, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    ks = jax.random.split(key, 9)
    s = 1.0 / math.sqrt(d)
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dtype) * s,
        "w_x": jax.random.normal(ks[1], (d, di), dtype) * s,
        "w_B": jax.random.normal(ks[2], (d, g * ds), dtype) * s,
        "w_C": jax.random.normal(ks[3], (d, g * ds), dtype) * s,
        "w_dt": jax.random.normal(ks[4], (d, nh), dtype) * s,
        "conv_x": jax.random.normal(ks[5], (w, di), dtype) * 0.1,
        "conv_B": jax.random.normal(ks[6], (w, g * ds), dtype) * 0.1,
        "conv_C": jax.random.normal(ks[7], (w, g * ds), dtype) * 0.1,
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "w_out": jax.random.normal(ks[8], (di, d), dtype) / math.sqrt(di),
    }


def mamba2_param_axes() -> dict:
    return {
        "w_z": ("p_embed", "p_ssm_inner"), "w_x": ("p_embed", "p_ssm_inner"),
        "w_B": ("p_embed", None), "w_C": ("p_embed", None),
        "w_dt": ("p_embed", "p_ssm_inner"),
        "conv_x": (None, "p_ssm_inner"), "conv_B": (None, None),
        "conv_C": (None, None),
        "A_log": ("p_ssm_inner",), "dt_bias": ("p_ssm_inner",),
        "D": ("p_ssm_inner",), "norm": ("p_ssm_inner",),
        "w_out": ("p_ssm_inner", "p_embed"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv. x:(B,S,C), w:(W,C). Returns (y, new_state)
    where state holds the trailing W-1 inputs for streaming decode."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    return jax.nn.silu(y), xp[:, -(width - 1):, :]


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) → L (..., Q, Q) with L[i,j]=exp(Σ_{k=j+1..i} dA) for j≤i."""
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    q = dA.shape[-1]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD over chunks.

    x: (b,s,h,p) f32 | dt: (b,s,h) f32 | A: (h,) f32 (negative)
    B,C: (b,s,h,n) f32 (group-broadcast done by caller)
    Returns y (b,s,h,p) f32 and final state (b,h,p,n) f32.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, h, n)
    Cc = C.reshape(b, nc, chunk, h, n)
    dA = dtc * A[None, None, None, :]                     # (b,nc,q,h)
    dA_h = dA.transpose(0, 1, 3, 2)                       # (b,nc,h,q)
    cs = jnp.cumsum(dA_h, axis=-1)                        # (b,nc,h,q)
    L = _segsum(dA_h)                                     # (b,nc,h,q,q)

    # intra-chunk (the "attention-like" quadratic-in-Q term)
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc)
    scores = scores * L * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # per-chunk boundary states
    decay_to_end = jnp.exp(cs[..., -1:] - cs)             # (b,nc,h,q)
    state_c = jnp.einsum("bchj,bcjh,bcjhn,bcjhp->bchpn",
                         decay_to_end, dtc, Bc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[..., -1])                    # (b,nc,h)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def body(s_prev, inp):
        st_c, dec = inp                                   # (b,h,p,n), (b,h)
        s_new = s_prev * dec[..., None, None] + st_c
        return s_new, s_prev

    final_state, s_prevs = jax.lax.scan(
        body, init_state,
        (state_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)            # (b,nc,h,p,n)

    in_decay = jnp.exp(cs)                                # (b,nc,h,q)
    y_inter = jnp.einsum("bcihn,bchpn,bchi->bcihp", Cc, s_prevs, in_decay)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final_state


def ssd_ref(x, dt, A, B, C, init_state=None):
    """Naive sequential recurrence oracle (tests)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    st = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None \
        else init_state

    def body(st, t):
        xt, dtt, Bt, Ct = x[:, t], dt[:, t], B[:, t], C[:, t]
        dec = jnp.exp(dtt * A[None, :])                  # (b,h)
        st = st * dec[..., None, None] \
            + jnp.einsum("bh,bhn,bhp->bhpn", dtt, Bt, xt)
        yt = jnp.einsum("bhn,bhpn->bhp", Ct, st)
        return st, yt

    st, ys = jax.lax.scan(body, st, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3), st


def mamba2_layer(p: dict, x: jax.Array, cfg: ModelConfig,
                 state: dict | None = None):
    """x: (B,S,d_model). state (decode): {"conv_x","conv_B","conv_C","ssm"}."""
    dt_ = x.dtype
    b, s, _ = x.shape
    nh, pd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt_))
    Bv = jnp.einsum("bsd,de->bse", x, p["w_B"].astype(dt_))
    Cv = jnp.einsum("bsd,de->bse", x, p["w_C"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt_))
    z = shard(z, "batch", "seq", "p_ssm_inner")
    xs = shard(xs, "batch", "seq", "p_ssm_inner")

    st = state or {}
    xs, conv_x = _causal_conv(xs, p["conv_x"].astype(dt_), st.get("conv_x"))
    Bv, conv_B = _causal_conv(Bv, p["conv_B"].astype(dt_), st.get("conv_B"))
    Cv, conv_C = _causal_conv(Cv, p["conv_C"].astype(dt_), st.get("conv_C"))

    A = -jnp.exp(p["A_log"])                              # (h,) negative
    dt_act = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"][None, None, :])
    xh = xs.reshape(b, s, nh, pd).astype(jnp.float32)
    heads_per_group = nh // g
    Bh = jnp.repeat(Bv.reshape(b, s, g, n), heads_per_group, axis=2)
    Ch = jnp.repeat(Cv.reshape(b, s, g, n), heads_per_group, axis=2)
    Bh = Bh.astype(jnp.float32)
    Ch = Ch.astype(jnp.float32)

    if state is None and s > 1:
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:
            # pad with dt=0 steps: decay exp(0)=1 and zero input, so the
            # final state is exact; padded outputs are sliced off.
            zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)]            # noqa: E731
                                     + [(0, 0)] * (a.ndim - 2))
            y, ssm = ssd_chunked(zpad(xh), zpad(dt_act), A, zpad(Bh),
                                 zpad(Ch), chunk)
            y = y[:, :s]
        else:
            y, ssm = ssd_chunked(xh, dt_act, A, Bh, Ch, chunk)
    else:
        y, ssm = ssd_ref(xh, dt_act, A, Bh, Ch, st.get("ssm"))
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, nh * pd).astype(dt_)
    y = shard(y, "batch", "seq", "p_ssm_inner")

    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.rmsnorm_eps)
    op_hook("mamba.ssd", (xs, Bv, Cv, dt_raw), (y,))
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    out = shard(out, "batch", "seq", "embed")
    op_hook("mamba.out_proj", (y, p["w_out"]), (out,))
    new_state = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
                 "ssm": ssm}
    return out, new_state
