"""Continuous-batching scheduler: policy-ordered admission over a fixed
slot set.

The scheduler owns request lifecycle bookkeeping and nothing device-side:
``waiting`` is an arrival-ordered queue, ``running`` maps KV-pool slot →
request, and admission (:meth:`Scheduler.admit`) moves requests into free
slots — the engine prefills them into those slots the same tick.  An
:class:`~repro.serve.slo.SLOPolicy` may stable-sort the waiting queue
first (:meth:`Scheduler.reorder`); with no policy (or FCFS) admission is
pure arrival order, byte-identical to the policy-free scheduler.
Retirement (:meth:`Scheduler.release`) returns the slot to the allocator;
the pool bytes are reused in place by the next admission.  Preemption
(:meth:`Scheduler.preempt`) is the inverse of admission: the slot returns
to the allocator and the request rejoins the FRONT of the waiting queue
still carrying its generated tokens — the engine parks its KV blocks in
the prefix store so re-admission aliases them back.

Ragged prompt handling is right-padding: :func:`pad_group` pads a cold
admission group to a shared power-of-two bucket.  Causality makes the pad
exact — a right-pad token can only influence positions after it, all of
which are discarded — so a padded group prefill produces bit-identical
per-row K/V and logits to each request prefilling alone.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import time

import numpy as np

from .cache import bucket


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy (immutable: safe to share across requests)."""

    max_new_tokens: int = 16
    temperature: float = 0.0
    stop_token: int | None = None
    #: per-request RNG seed for temperature>0 sampling; ``None`` derives a
    #: key from the engine seed and the request id, so sampled streams are
    #: independent of how requests happen to batch together
    seed: int | None = None


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"
    ABORTED = "aborted"
    #: terminal fault-tolerance outcomes: retries exhausted on a blamed
    #: request / SLO deadline elapsed / shed at admission (degrade level 3)
    FAILED = "failed"
    TIMEOUT = "timeout"
    REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    """One served generation: prompt + params + lifecycle bookkeeping."""

    rid: int
    prompt: np.ndarray
    params: SamplingParams
    state: RequestState = RequestState.QUEUED
    slot: int | None = None
    tokens: list = dataclasses.field(default_factory=list)
    #: prompt tokens skipped at prefill via the prefix cache
    cached_tokens: int = 0
    #: prompt tokens already materialized in the KV cache (prefix aliases +
    #: chunks prefilled so far) — the chunked-prefill progress cursor
    progress: int = 0
    #: speculative-decode lifetime counters: draft tokens verified for this
    #: request / how many of them the target accepted
    drafted: int = 0
    accepted: int = 0
    submit_time: float = 0.0
    admit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    #: per-request child pasta.Session spanning the whole lifetime
    session: object = None
    #: transient: prefix-cache entry chosen at admission
    prefix_kv: dict | None = None
    #: service-level objectives + tenant/priority tags (None = untagged)
    slo: object = None
    #: times this request was preempted (evicted-and-requeued)
    preemptions: int = 0
    #: times this request was blamed for a fault and requeued for a full
    #: recompute; the engine fails it past ``max_request_retries``
    retries: int = 0
    #: earliest re-admission time while serving a retry backoff
    retry_at: float = 0.0
    #: context length the CURRENT admission must prefill to before the
    #: request can decode — ``prompt_len`` on a fresh admission, and
    #: ``prompt_len + len(tokens)`` when resuming after preemption (the
    #: generated prefix must be back in the cache first).  None = fresh.
    prefill_len: int | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def context(self) -> np.ndarray:
        """Prompt plus every committed token — what a resumed prefill must
        (re)materialize in the KV cache.  Equals ``prompt`` before the
        first token."""
        if not self.tokens:
            return self.prompt
        return np.concatenate([self.prompt,
                               np.asarray(self.tokens, np.int32)])

    @property
    def context_len(self) -> int:
        return self.prompt_len + len(self.tokens)

    @property
    def tenant(self) -> str:
        return self.slo.tenant if self.slo is not None else "default"

    @property
    def prefilled(self) -> bool:
        """The admission's whole context is in the cache — the request
        decodes from here."""
        target = self.prompt_len if self.prefill_len is None \
            else self.prefill_len
        return self.progress >= target

    @property
    def done(self) -> bool:
        if len(self.tokens) >= self.params.max_new_tokens:
            return True
        stop = self.params.stop_token
        return stop is not None and len(self.tokens) > 0 \
            and self.tokens[-1] == stop


class Scheduler:
    """Continuous batching: admit into free slots (policy-ordered, FCFS by
    default), release on retire, preempt back to the queue head."""

    def __init__(self, max_slots: int, policy=None):
        self.max_slots = max_slots
        self.policy = policy                    # SLOPolicy | None
        self.waiting: collections.deque = collections.deque()
        self.running: dict = {}                 # slot -> Request
        self._free = list(range(max_slots - 1, -1, -1))   # pop() -> ascending

    # -------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return len(self.running)

    @property
    def n_queued(self) -> int:
        return len(self.waiting)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> None:
        req.submit_time = req.submit_time or time.perf_counter()
        self.waiting.append(req)

    def reorder(self, now: float | None = None) -> None:
        """Stable-sort the waiting queue by the policy's key.  Ties keep
        arrival order; FCFS (``orders=False``) and no-policy skip the sort
        entirely, so the default path stays byte-identical."""
        if self.policy is None or not getattr(self.policy, "orders", False) \
                or len(self.waiting) < 2:
            return
        now = time.perf_counter() if now is None else now
        key = self.policy.key
        self.waiting = collections.deque(
            sorted(self.waiting, key=lambda r: key(r, now)))

    def admit(self, fits=None) -> list:
        """Move waiting requests into free slots in queue order (arrival
        order unless :meth:`reorder` ran first); returns the admitted
        requests with ``slot``/``state``/``admit_time`` assigned.  ``fits``
        (req -> bool) gates admission on resources beyond slots (the paged
        engine passes a block-availability check); queue order is
        preserved — a head-of-line request that does not fit blocks the
        queue rather than being overtaken."""
        out = []
        now = time.perf_counter()
        while self.waiting and self._free:
            if fits is not None and not fits(self.waiting[0]):
                break
            req = self.waiting.popleft()
            req.slot = self._free.pop()
            req.state = RequestState.RUNNING
            req.admit_time = now
            self.running[req.slot] = req
            out.append(req)
        return out

    def release(self, req: Request, state=RequestState.FINISHED) -> None:
        """Retire: free the request's slot (pool bytes reused in place)."""
        if req.slot is None or self.running.get(req.slot) is not req:
            raise ValueError(f"request {req.rid} does not hold a slot")
        del self.running[req.slot]
        self._free.append(req.slot)
        self._free.sort(reverse=True)           # deterministic ascending pops
        req.slot = None
        req.state = state
        req.finish_time = time.perf_counter()

    def vacate(self, req: Request) -> None:
        """Take the slot back WITHOUT enqueueing the request anywhere:
        state returns to QUEUED and the caller decides where it waits (the
        engine's retry-backoff pen uses this so a blamed request cannot
        head-of-line block the real queue while backing off)."""
        if req.slot is None or self.running.get(req.slot) is not req:
            raise ValueError(f"request {req.rid} does not hold a slot")
        del self.running[req.slot]
        self._free.append(req.slot)
        self._free.sort(reverse=True)           # deterministic ascending pops
        req.slot = None
        req.state = RequestState.QUEUED

    def preempt(self, req: Request) -> None:
        """Evict-and-requeue: return the slot to the allocator and put the
        request back at the FRONT of the waiting queue, still carrying its
        generated tokens (state QUEUED — it competes for re-admission like
        any arrival, but a policy reorder sees its original submit time /
        priority).  The engine parks its KV first; see
        ``ServeEngine.preempt``."""
        self.vacate(req)
        req.preemptions += 1
        self.waiting.appendleft(req)

    def remove_waiting(self, req: Request,
                       state=RequestState.ABORTED) -> bool:
        """Drop a still-queued request (abort/timeout path); False if not
        queued."""
        try:
            self.waiting.remove(req)
        except ValueError:
            return False
        req.state = state
        return True


def pad_group(prompts: list, pow2: bool = True, max_len: int | None = None):
    """Right-pad ragged prompts to a shared length.

    Returns ``(tokens (G, S) int32, lens (G,) int32)`` with ``S`` the
    power-of-two bucket of the longest prompt (``pow2=False``: exact max) —
    bucketing bounds distinct prefill compile shapes to O(log max_seq).
    ``max_len`` caps the bucket at the KV pool's sequence bound: a non-pow2
    ``max_seq`` must not compile a wider prefill than the pool can hold
    (positions past ``max_seq`` would be computed only to be cropped at the
    slot write) — the same cap the suffix-prefill path applies.
    """
    lens = np.asarray([len(p) for p in prompts], np.int32)
    s = int(lens.max())
    if pow2:
        s = bucket(s)
    if max_len is not None:
        if int(lens.max()) > max_len:
            raise ValueError(
                f"prompt of length {int(lens.max())} exceeds the pool bound "
                f"max_len={max_len}")
        s = min(s, max_len)
    toks = np.zeros((len(prompts), s), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    return toks, lens
