"""Slot-indexed KV/SSM cache pool + hash-keyed prompt-prefix cache.

The serving cache is a fixed ``(slots, max_seq)`` pool: slot ``i`` of every
cache leaf (KV rows, SSM states, per-slot lengths) belongs to the request
currently occupying slot ``i``.  Requests of different lengths interleave
freely — decode writes land at each slot's own length (per-row scatter in
:func:`repro.models.layers.attention`) and the per-row length masks keep
stale bytes from retired requests invisible.  Admitting a request is one
donated-buffer ``dynamic_update_slice`` per leaf (:meth:`KVSlotPool.insert`);
retiring is free (the slot index just returns to the allocator).

:class:`PrefixCache` is the cross-request reuse layer: completed prefills
publish their prompt K/V under hash keys at block-aligned prefix lengths, and
a new request whose prompt prefix matches a stored key skips prefilling those
tokens — its slot is seeded with the stored K/V and only the suffix runs
through the model (RoPE keys are absolute-position, so a shared prefix at
positions ``0..L-1`` is bit-reusable).  Prefix reuse is KV-only: SSM/hybrid
states summarize the whole prefix nonlinearly and are not block-addressable,
so those families always prefill cold (hit rate 0 by construction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache
from repro.models.config import ModelConfig

#: marker in the slot-axis spec tree for per-slot int leaves ("length",
#: "main_len") whose pool value is overridden with the request's true length
#: (a right-padded group prefill reports the padded length for every row)
LENGTH = "length"


def slot_axes(cache: dict) -> dict:
    """Tree parallel to ``cache`` giving each leaf's slot (batch) axis.

    Mirrors the layout knowledge of :func:`repro.models.lm.init_cache`:
    KV leaves carry batch at axis 1 under a leading layer axis, SSM leaves at
    axis 1 (axis 2 for the hybrid ``ssm_groups`` with its extra
    layer-in-group axis), and length-like vectors at axis 0 (marked
    :data:`LENGTH`).
    """
    spec: dict = {}
    for key, val in cache.items():
        if key == "kv":
            spec["kv"] = {k: (LENGTH if k in ("length", "main_len") else 1)
                          for k in val}
        elif key == "length":
            spec["length"] = LENGTH
        elif key in ("ssm", "ssm_tail"):
            spec[key] = {k: 1 for k in val}
        elif key == "ssm_groups":
            spec[key] = {k: 2 for k in val}
        else:
            raise ValueError(f"unknown cache entry {key!r}")
    return spec


def _slot_put(pool_leaf, src_leaf, ax, slot, row, length):
    if ax == LENGTH:
        val = jnp.full((1,), length, pool_leaf.dtype)
        return jax.lax.dynamic_update_slice(pool_leaf, val, (slot,))
    sl = jax.lax.dynamic_slice_in_dim(src_leaf, row, 1, axis=ax)
    # prefill caches may differ from the pool along non-slot dims (seq at
    # the prompt bucket vs max_seq): crop then zero-pad — submit() bounds
    # real content by max_seq, so cropping only drops right-pad junk, and
    # bytes beyond the slot's length are masked at decode anyway
    sl = sl[tuple(slice(0, n) for n in pool_leaf.shape[:sl.ndim])]
    pad = [(0, pool_leaf.shape[i] - sl.shape[i]) for i in range(sl.ndim)]
    pad[ax] = (0, 0)
    sl = jnp.pad(sl, pad)
    starts = [0] * sl.ndim
    starts[ax] = slot
    return jax.lax.dynamic_update_slice(pool_leaf, sl.astype(pool_leaf.dtype),
                                        tuple(starts))


def slot_insert(pool: dict, src: dict, slot, row, length) -> dict:
    """Copy row ``row`` of prefill cache ``src`` into slot ``slot`` of the
    pool, overriding length leaves with the request's true ``length``."""
    spec = slot_axes(pool)
    return jax.tree.map(
        lambda p, s, ax: _slot_put(p, s, ax, slot, row, length),
        pool, src, spec)


def bucket(n: int, floor: int = 8) -> int:
    """Next power-of-two ≥ n (≥ floor) — the right-padding bucket for ragged
    prompts, bounding prefill recompiles to O(log max_seq) shapes."""
    b = floor
    while b < n:
        b *= 2
    return b


class KVSlotPool:
    """Fixed ``(slots, max_seq)`` decode cache pool with per-slot lengths.

    ``cache`` is the live device tree (same pytree the model's decode path
    consumes); callers reassign it after donated decode steps.  ``insert``
    is jitted with the pool donated, so admission is an in-place scatter.
    """

    def __init__(self, cfg: ModelConfig, slots: int, max_seq: int):
        if cfg.kv_two_tier:
            raise NotImplementedError(
                "the slotted serving pool manages raggedness itself; "
                "kv_two_tier's frozen-main/recent-buffer split is a "
                "long-context decode layout, not a slot pool")
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, slots, max_seq)
        self._insert = jax.jit(slot_insert, donate_argnums=(0,))

    def insert(self, src_cache: dict, slot: int, row: int,
               length: int) -> None:
        self.cache = self._insert(self.cache, src_cache, jnp.int32(slot),
                                  jnp.int32(row), jnp.int32(length))

    # ------------------------------------------------------- prefix plumbing
    def extract_kv(self, slot: int, upto: int) -> dict:
        """Host copy of slot's K/V for the first ``upto`` positions —
        ``{"k","v"}: (n_layers, upto, n_kv_heads, head_dim)`` numpy."""
        kv = self.cache["kv"]
        return {"k": np.asarray(kv["k"][:, slot, :upto]),
                "v": np.asarray(kv["v"][:, slot, :upto])}

    def seeded_prefill_cache(self, kv_prefix: dict | None,
                             batch: int = 1) -> dict:
        """A fresh single-request prefill cache (attention families only),
        optionally seeded with a stored prefix at positions ``0..L-1`` so
        only the prompt suffix needs prefilling."""
        dt = jnp.dtype(self.cfg.dtype)
        n = self.cache["kv"]["k"].shape[0]
        hkv, hd = self.cfg.n_kv_heads, self.cfg.head_dim
        k = np.zeros((n, batch, self.max_seq, hkv, hd), dt)
        v = np.zeros_like(k)
        length = np.zeros((batch,), np.int32)
        if kv_prefix is not None:
            pl = kv_prefix["k"].shape[1]
            k[:, 0, :pl] = kv_prefix["k"]
            v[:, 0, :pl] = kv_prefix["v"]
            length[0] = pl
        return {"kv": {"k": jnp.asarray(k), "v": jnp.asarray(v),
                       "length": jnp.asarray(length)}}


class PrefixCache:
    """Hash-keyed prompt-prefix store (block-aligned keys, LRU-bounded).

    ``insert(tokens, kv)`` publishes a finished prefill under keys for every
    ``block``-multiple prefix length plus the full prompt, all referencing
    the same backing arrays (numpy views — no copies).  ``lookup(tokens)``
    returns the longest stored prefix strictly shorter than the prompt (at
    least one real token must run through the model to produce logits).
    """

    def __init__(self, block: int = 16, capacity: int = 64):
        self.block = block
        self.capacity = capacity
        self._store: dict = {}          # (L, prefix_bytes) -> {"k","v"}
        self._order: list = []          # LRU over keys
        self.lookups = 0
        self.hits = 0
        self.reused_tokens = 0
        self.prompt_tokens = 0

    def __len__(self) -> int:
        return len(self._store)

    def _touch(self, key) -> None:
        if key in self._order:
            self._order.remove(key)
        self._order.append(key)

    def covers(self, tokens: np.ndarray) -> bool:
        """True when this exact prompt was already published (its full-
        length key exists — block keys are inserted alongside it), so a
        re-insert would transfer identical KV for nothing."""
        key = (len(tokens), tokens.tobytes())
        if key in self._store:
            self._touch(key)
            return True
        return False

    def lookup(self, tokens: np.ndarray):
        """Longest-match lookup: ``(hit_len, {"k","v"}) | (0, None)``."""
        self.lookups += 1
        n = len(tokens)
        self.prompt_tokens += n
        lens = sorted({L for (L, _) in self._store if L < n}, reverse=True)
        for L in lens:
            key = (L, tokens[:L].tobytes())
            ent = self._store.get(key)
            if ent is not None:
                self.hits += 1
                self.reused_tokens += L
                self._touch(key)
                return L, ent
        return 0, None

    def insert(self, tokens: np.ndarray, kv: dict) -> None:
        """``kv``: {"k","v"} (n_layers, len(tokens), heads, head_dim)."""
        n = len(tokens)
        lens = {L for L in range(self.block, n, self.block)} | {n}
        for L in lens:
            key = (L, tokens[:L].tobytes())
            self._store[key] = {"k": kv["k"][:, :L], "v": kv["v"][:, :L]}
            self._touch(key)
        while len(self._store) > self.capacity:
            old = self._order.pop(0)
            self._store.pop(old, None)

    def stats(self) -> dict:
        return {
            "entries": len(self._store),
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "reused_tokens": self.reused_tokens,
            "prompt_tokens": self.prompt_tokens,
            "reused_frac": (self.reused_tokens / self.prompt_tokens
                            if self.prompt_tokens else 0.0),
        }
