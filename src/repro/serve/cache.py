"""Slot-indexed KV/SSM cache pool + hash-keyed prompt-prefix cache.

The serving cache is a fixed ``(slots, max_seq)`` pool: slot ``i`` of every
cache leaf (KV rows, SSM states, per-slot lengths) belongs to the request
currently occupying slot ``i``.  Requests of different lengths interleave
freely — decode writes land at each slot's own length (per-row scatter in
:func:`repro.models.layers.attention`) and the per-row length masks keep
stale bytes from retired requests invisible.  Admitting a request is one
donated-buffer ``dynamic_update_slice`` per leaf (:meth:`KVSlotPool.insert`);
retiring is free (the slot index just returns to the allocator).

:class:`PagedKVPool` replaces the dense rows for the KV-only (``dense``)
family: one ``(n_layers, n_blocks, block_size, heads, head_dim)`` K/V block
pool plus per-slot *block tables*, so live decode state and the prefix store
reference the **same** device blocks.  A prefix hit is block-table aliasing
plus a refcount bump — no device→host ``extract_kv`` copy ever sits on the
prefill critical path, and publishing a finished prefill duplicates zero
bytes.  Block sharing is write-safe by construction: prefix keys exist only
at block-aligned lengths, so a hit's suffix (and all later decode appends)
land in freshly-allocated blocks, never in a shared one.

:class:`PrefixCache` is the cross-request reuse layer: completed prefills
publish their prompt K/V under hash keys at block-aligned prefix lengths, and
a new request whose prompt prefix matches a stored key skips prefilling those
tokens (RoPE keys are absolute-position, so a shared prefix at positions
``0..L-1`` is bit-reusable).  Entries are opaque: block-id tuples in paged
mode (:meth:`PrefixCache.insert_blocks`, zero-copy) or host K/V views in the
legacy dense-row mode (:meth:`PrefixCache.insert`).  Prefix reuse is
KV-only: SSM/hybrid states summarize the whole prefix nonlinearly and are
not block-addressable, so those families always prefill cold (hit rate 0 by
construction).
"""

from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache
from repro.models.config import ModelConfig

#: marker in the slot-axis spec tree for per-slot int leaves ("length",
#: "main_len") whose pool value is overridden with the request's true length
#: (a right-padded group prefill reports the padded length for every row)
LENGTH = "length"


def slot_axes(cache: dict) -> dict:
    """Tree parallel to ``cache`` giving each leaf's slot (batch) axis.

    Mirrors the layout knowledge of :func:`repro.models.lm.init_cache`:
    KV leaves carry batch at axis 1 under a leading layer axis, SSM leaves at
    axis 1 (axis 2 for the hybrid ``ssm_groups`` with its extra
    layer-in-group axis), and length-like vectors at axis 0 (marked
    :data:`LENGTH`).
    """
    spec: dict = {}
    for key, val in cache.items():
        if key == "kv":
            spec["kv"] = {k: (LENGTH if k in ("length", "main_len") else 1)
                          for k in val}
        elif key == "length":
            spec["length"] = LENGTH
        elif key in ("ssm", "ssm_tail"):
            spec[key] = {k: 1 for k in val}
        elif key == "ssm_groups":
            spec[key] = {k: 2 for k in val}
        else:
            raise ValueError(f"unknown cache entry {key!r}")
    return spec


def _slot_put(pool_leaf, src_leaf, ax, slot, row, length):
    if ax == LENGTH:
        val = jnp.full((1,), length, pool_leaf.dtype)
        return jax.lax.dynamic_update_slice(pool_leaf, val, (slot,))
    sl = jax.lax.dynamic_slice_in_dim(src_leaf, row, 1, axis=ax)
    # prefill caches may differ from the pool along non-slot dims (seq at
    # the prompt bucket vs max_seq): crop then zero-pad — submit() bounds
    # real content by max_seq, so cropping only drops right-pad junk, and
    # bytes beyond the slot's length are masked at decode anyway
    sl = sl[tuple(slice(0, n) for n in pool_leaf.shape[:sl.ndim])]
    pad = [(0, pool_leaf.shape[i] - sl.shape[i]) for i in range(sl.ndim)]
    pad[ax] = (0, 0)
    sl = jnp.pad(sl, pad)
    starts = [0] * sl.ndim
    starts[ax] = slot
    return jax.lax.dynamic_update_slice(pool_leaf, sl.astype(pool_leaf.dtype),
                                        tuple(starts))


def slot_insert(pool: dict, src: dict, slot, row, length) -> dict:
    """Copy row ``row`` of prefill cache ``src`` into slot ``slot`` of the
    pool, overriding length leaves with the request's true ``length``."""
    spec = slot_axes(pool)
    return jax.tree.map(
        lambda p, s, ax: _slot_put(p, s, ax, slot, row, length),
        pool, src, spec)


def bucket(n: int, floor: int = 8) -> int:
    """Next power-of-two ≥ n (≥ floor) — the right-padding bucket for ragged
    prompts, bounding prefill recompiles to O(log max_seq) shapes."""
    b = floor
    while b < n:
        b *= 2
    return b


def pad_cache_to(cache: dict, cfg: ModelConfig, max_seq: int) -> dict:
    """Grow a prefill KV cache's sequence dim to ``max_seq`` slots."""
    if "kv" not in cache:
        return cache
    kv = cache["kv"]
    cur = kv["k"].shape[2]
    if cur >= max_seq:
        return cache
    pad = max_seq - cur

    def grow(x):
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, pad)
        return jnp.pad(x, widths)

    cache = dict(cache)
    cache["kv"] = {"k": grow(kv["k"]), "v": grow(kv["v"]),
                   "length": kv["length"]}
    return cache


class KVSlotPool:
    """Fixed ``(slots, max_seq)`` decode cache pool with per-slot lengths.

    ``cache`` is the live device tree (same pytree the model's decode path
    consumes); callers reassign it after donated decode steps.  ``insert``
    is jitted with the pool donated, so admission is an in-place scatter.
    """

    def __init__(self, cfg: ModelConfig, slots: int, max_seq: int):
        if cfg.kv_two_tier:
            raise NotImplementedError(
                "the slotted serving pool manages raggedness itself; "
                "kv_two_tier's frozen-main/recent-buffer split is a "
                "long-context decode layout, not a slot pool")
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, slots, max_seq)
        self._insert = jax.jit(slot_insert, donate_argnums=(0,))

    def insert(self, src_cache: dict, slot: int, row: int,
               length: int) -> None:
        self.cache = self._insert(self.cache, src_cache, jnp.int32(slot),
                                  jnp.int32(row), jnp.int32(length))

    # ------------------------------------------------------- prefix plumbing
    def extract_kv(self, slot: int, upto: int) -> dict:
        """Host copy of slot's K/V for the first ``upto`` positions —
        ``{"k","v"}: (n_layers, upto, n_kv_heads, head_dim)`` numpy."""
        kv = self.cache["kv"]
        return {"k": np.asarray(kv["k"][:, slot, :upto]),
                "v": np.asarray(kv["v"][:, slot, :upto])}

    def seeded_prefill_cache(self, kv_prefix: dict | None,
                             batch: int = 1) -> dict:
        """A fresh single-request prefill cache (attention families only),
        optionally seeded with a stored prefix at positions ``0..L-1`` so
        only the prompt suffix needs prefilling."""
        dt = jnp.dtype(self.cfg.dtype)
        n = self.cache["kv"]["k"].shape[0]
        hkv, hd = self.cfg.n_kv_heads, self.cfg.head_dim
        k = np.zeros((n, batch, self.max_seq, hkv, hd), dt)
        v = np.zeros_like(k)
        length = np.zeros((batch,), np.int32)
        if kv_prefix is not None:
            pl = kv_prefix["k"].shape[1]
            k[:, 0, :pl] = kv_prefix["k"]
            v[:, 0, :pl] = kv_prefix["v"]
            length[0] = pl
        return {"kv": {"k": jnp.asarray(k), "v": jnp.asarray(v),
                       "length": jnp.asarray(length)}}


class PagedKVPool:
    """Paged KV block pool: one device block array + per-slot block tables.

    Device state is a single ``(n_layers, n_blocks, block_size, heads,
    head_dim)`` pool for K and V.  Host state is the allocator: a free list,
    per-block refcounts (live request references + prefix-store references
    counted separately so evictability is exact), and a ``(slots,
    blocks_per_seq)`` block-table row per slot, sentinel-padded with
    ``n_blocks`` (out-of-bounds → gathers clamp harmlessly, scatters drop).

    Memory sharing is the point: a prefix hit binds the stored blocks into
    the new slot's table (refcount bump, zero bytes moved), and publishing a
    finished prefill retains the slot's own blocks under store keys —
    ``duplicate_copy_bytes`` is 0 by construction.  Under block pressure the
    allocator evicts LRU prefix-store entries via ``evict_cb``; blocks
    referenced by a live request are never reclaimed.
    """

    def __init__(self, cfg: ModelConfig, slots: int, max_seq: int,
                 block_size: int = 16, n_blocks: int | None = None):
        if cfg.kv_two_tier:
            raise NotImplementedError(
                "the paged serving pool manages raggedness itself; "
                "kv_two_tier's frozen-main/recent-buffer split is a "
                "long-context decode layout, not a block pool")
        if cfg.family not in ("dense", "vlm", "audio", "moe"):
            raise NotImplementedError(
                "paged KV blocks are attention-only; SSM/hybrid state is "
                "not block-addressable (use KVSlotPool)")
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_seq = -(-max_seq // block_size)
        #: default sizing: dense-pool parity per slot plus two sequences'
        #: worth of headroom so the prefix store can retain blocks without
        #: starving admission
        self.n_blocks = (n_blocks if n_blocks is not None
                         else (slots + 2) * self.blocks_per_seq)
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, self.n_blocks, block_size,
                 cfg.n_kv_heads, cfg.head_dim)
        self.pk = jnp.zeros(shape, dt)
        self.pv = jnp.zeros(shape, dt)
        self._free = list(range(self.n_blocks - 1, -1, -1))  # pop ascending
        self._refs = np.zeros((self.n_blocks,), np.int32)
        self._store_refs = np.zeros((self.n_blocks,), np.int32)
        #: sentinel-filled block tables; gathers clamp, scatters drop
        self.tables = np.full((slots, self.blocks_per_seq), self.n_blocks,
                              np.int32)
        self.evict_cb = None          # () -> bool, frees store blocks
        #: blocks freed by truncate() whose device bytes are still rejected
        #: draft KV — dead (every reader masks at its committed length), so
        #: scrubbing is deferred and batched instead of paid per tick
        self._dirty: set = set()
        self._insert = jax.jit(_paged_insert, donate_argnums=(0, 1),
                               static_argnames=("crop",))
        self._zero = jax.jit(
            lambda pk, pv, ids: (pk.at[:, ids].set(0, mode="drop"),
                                 pv.at[:, ids].set(0, mode="drop")),
            donate_argnums=(0, 1))

    # -------------------------------------------------------- allocator
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_blocks - len(self._free)

    def n_evictable(self) -> int:
        """Blocks currently reclaimable by evicting prefix-store entries:
        every reference on them is a store reference (no live request)."""
        held = (self._refs > 0) & (self._refs == self._store_refs)
        return int(held.sum())

    def available(self) -> int:
        return self.n_free + self.n_evictable()

    def alloc(self, n: int) -> list | None:
        """Allocate ``n`` fresh blocks (refcount 1 each), evicting LRU
        prefix-store entries under pressure; ``None`` when the pool cannot
        satisfy the request even after evicting everything evictable."""
        while len(self._free) < n and self.evict_cb is not None \
                and self.evict_cb():
            pass
        if len(self._free) < n:
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._refs[ids] += 1
        #: a re-allocated block must never be scrubbed later — its new owner
        #: overwrites it before reading, and a deferred zero would clobber
        #: live KV
        self._dirty.difference_update(ids)
        return ids

    def retain(self, ids, store: bool = False) -> None:
        ids = list(ids)
        self._refs[ids] += 1
        if store:
            self._store_refs[ids] += 1

    def release(self, ids, store: bool = False) -> list:
        """Drop one reference per block; returns the ids that became fully
        free (refcount hit zero) so callers can scrub them."""
        freed = []
        for b in ids:
            b = int(b)
            self._refs[b] -= 1
            if store:
                self._store_refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)
                freed.append(b)
        self._free.sort(reverse=True)          # deterministic ascending pops
        return freed

    # ------------------------------------------------------- slot tables
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def bind_slot(self, slot: int, shared_ids, fresh_ids) -> None:
        """Install a slot's block table: ``shared_ids`` (prefix-store
        aliases, already retained by the caller) followed by ``fresh_ids``
        (owned by this request)."""
        row = list(shared_ids) + list(fresh_ids)
        assert len(row) <= self.blocks_per_seq
        self.tables[slot] = self.n_blocks
        self.tables[slot, :len(row)] = row

    def free_slot(self, slot: int) -> None:
        """Release every real block the slot references (shared blocks just
        drop this request's refcount; store references keep them alive)."""
        row = self.tables[slot]
        real = row[row < self.n_blocks]
        self.release([int(b) for b in real])
        self.tables[slot] = self.n_blocks

    def ensure(self, slot: int, n_tokens: int) -> int:
        """Grow the slot's table to cover ``n_tokens`` positions (lazy block
        binding: decode and speculative spill allocate just-in-time instead
        of reserving the whole horizon upfront).  Returns the number of
        blocks allocated; raises if the pool cannot satisfy it — admission's
        reservation accounting is supposed to make that impossible."""
        span = self.blocks_per_seq * self.block_size
        need = self.blocks_for(min(n_tokens, span))
        row = self.tables[slot]
        have = int((row < self.n_blocks).sum())
        if have >= need:
            return 0
        fresh = self.alloc(need - have)
        if fresh is None:
            raise RuntimeError(
                f"paged pool exhausted growing slot {slot} to {n_tokens} "
                f"tokens ({need - have} blocks short) — admission "
                f"reservation accounting is broken")
        self.tables[slot, have:have + len(fresh)] = fresh
        return len(fresh)

    def truncate(self, slot: int, n_tokens: int) -> int:
        """Roll the slot back to ``n_tokens`` valid positions: drop table
        blocks past ``blocks_for(n_tokens)`` and release them (store
        refcounts respected — a block the prefix store still holds is only
        deref'd, never scrubbed).  Blocks that became fully free still hold
        rejected-draft KV, but those bytes are dead — no table references
        them and any future owner overwrites before its mask exposes them —
        so scrubbing is deferred to :meth:`scrub` and batched.  Returns the
        number of blocks dropped from the table."""
        keep = self.blocks_for(n_tokens)
        row = self.tables[slot]
        real = row[row < self.n_blocks]
        if len(real) <= keep:
            return 0
        tail = [int(b) for b in real[keep:]]
        self.tables[slot, keep:] = self.n_blocks
        self._dirty.update(self.release(tail))
        if len(self._dirty) >= 32:
            self.scrub()
        return len(tail)

    def scrub(self) -> None:
        """Zero the device bytes of every truncate-freed block still
        pending (one batched scatter).  Called automatically once enough
        blocks accumulate; call explicitly for a deterministic pool image
        (tests, checkpointing an idle engine)."""
        self._zero_blocks(sorted(self._dirty))
        self._dirty.clear()

    def _zero_blocks(self, ids: list) -> None:
        """Scrub freed blocks' device bytes in one donated scatter (ids
        padded to a pow2 width with the drop sentinel, bounding
        recompiles)."""
        if not ids:
            return
        width = 1
        while width < len(ids):
            width *= 2
        pad = np.full((width,), self.n_blocks, np.int32)
        pad[:len(ids)] = ids
        self.pk, self.pv = self._zero(self.pk, self.pv, jnp.asarray(pad))

    # ------------------------------------------------------ device views
    def cache_view(self, lengths: np.ndarray, rows=None) -> dict:
        """The cache pytree the model's paged attention consumes.  ``rows``
        selects a subset of slots (e.g. one prefilling request); default is
        the full slot set (the fused decode)."""
        bt = self.tables if rows is None else self.tables[rows]
        return {"kv": {"pk": self.pk, "pv": self.pv,
                       "bt": jnp.asarray(bt),
                       "length": jnp.asarray(lengths, jnp.int32)}}

    def adopt(self, cache: dict) -> None:
        """Re-own the (donated) pool arrays returned by a jitted step."""
        self.pk = cache["kv"]["pk"]
        self.pv = cache["kv"]["pv"]

    def insert_prefill(self, src_cache: dict, slot: int, row: int) -> None:
        """Scatter one row of a dense grouped-prefill cache into the slot's
        blocks (crops the right-pad bucket to the table span)."""
        ids = jnp.asarray(self.tables[slot])
        span = self.blocks_per_seq * self.block_size
        kv = src_cache["kv"]
        self.pk, self.pv = self._insert(
            self.pk, self.pv, kv["k"], kv["v"], jnp.int32(row), ids,
            crop=min(span, kv["k"].shape[2]))

    def stats(self) -> dict:
        evictable = self.n_evictable()
        return {
            "paged": True,
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "blocks_used": self.n_used,
            "blocks_free": self.n_free,
            # disjoint occupancy split: live (a request holds a non-store
            # reference) + evictable (store-only) + free == n_blocks
            "blocks_live": self.n_used - evictable,
            "blocks_evictable": evictable,
            "store_blocks": int((self._store_refs > 0).sum()),
            "utilization": self.n_used / self.n_blocks,
        }


def _paged_insert(pk, pv, src_k, src_v, row, ids, *, crop):
    """Scatter row ``row`` of a dense prefill cache (n_layers, G, S, H, D)
    into pool blocks ``ids`` ((blocks_per_seq,) int32, sentinel-padded —
    sentinel scatters drop).  ``crop``: static token span to write."""
    bs = pk.shape[2]
    span = ids.shape[0] * bs

    def put(pool, src):
        sl = jax.lax.dynamic_slice_in_dim(src, row, 1, axis=1)[:, 0]
        sl = sl[:, :crop]
        if crop < span:
            sl = jnp.pad(sl, [(0, 0), (0, span - crop), (0, 0), (0, 0)])
        blocks = sl.reshape(sl.shape[0], ids.shape[0], bs, *sl.shape[2:])
        return pool.at[:, ids].set(blocks.astype(pool.dtype), mode="drop")

    return put(pk, src_k), put(pv, src_v)


class PrefixCache:
    """Hash-keyed prompt-prefix store (block-aligned keys, LRU-bounded).

    Entries are opaque values under ``(length, prefix_bytes)`` keys:

    * :meth:`insert_blocks` (paged mode) publishes block-id tuples for every
      ``block``-multiple prefix length — zero-copy aliases into the
      :class:`PagedKVPool`, retained/evicted through the ``on_retain`` /
      ``on_evict`` hooks so refcounts stay exact;
    * :meth:`insert` (legacy dense-row mode) publishes host K/V array views
      for every block-multiple length plus the full prompt.

    ``lookup(tokens)`` returns the longest stored prefix strictly shorter
    than the prompt (at least one real token must run through the model to
    produce logits).  LRU order is an ``OrderedDict`` (``move_to_end`` on
    touch — O(1), not the old O(n) ``list.remove``).  Stats discipline:
    **only ``lookup()`` counts traffic**; ``covers()`` is a pure query (no
    counter, no LRU touch), so ``stats()`` reflects exactly the admission
    lookups the engine performed.
    """

    def __init__(self, block: int = 16, capacity: int = 64, on_evict=None):
        self.block = block
        self.capacity = capacity
        self.on_evict = on_evict        # entry -> None (paged: release ids)
        self._store: collections.OrderedDict = collections.OrderedDict()
        self.lookups = 0
        self.hits = 0
        self.reused_tokens = 0
        self.prompt_tokens = 0

    def __len__(self) -> int:
        return len(self._store)

    def _touch(self, key) -> None:
        self._store.move_to_end(key)

    def covers(self, tokens: np.ndarray, length: int | None = None) -> bool:
        """Pure query: is the length-``length`` prefix (default: the full
        prompt) already published?  Does NOT count as a lookup and does not
        touch LRU recency — stats track admission traffic only."""
        n = len(tokens) if length is None else length
        if n <= 0:
            return True                  # nothing to publish
        return (n, tokens[:n].tobytes()) in self._store

    def lookup(self, tokens: np.ndarray):
        """Longest-match lookup: ``(hit_len, entry) | (0, None)``."""
        self.lookups += 1
        n = len(tokens)
        self.prompt_tokens += n
        lens = sorted({L for (L, _) in self._store if L < n}, reverse=True)
        for L in lens:
            key = (L, tokens[:L].tobytes())
            ent = self._store.get(key)
            if ent is not None:
                self.hits += 1
                self.reused_tokens += L
                self._touch(key)
                return L, ent
        return 0, None

    def _put(self, key, entry) -> None:
        self._store[key] = entry
        self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self.evict_one()

    def evict_one(self) -> bool:
        """Drop the LRU entry (``on_evict`` releases its blocks in paged
        mode).  Returns False when the store is empty."""
        if not self._store:
            return False
        _key, entry = self._store.popitem(last=False)
        if self.on_evict is not None:
            self.on_evict(entry)
        return True

    def insert(self, tokens: np.ndarray, kv: dict) -> None:
        """Legacy dense-row publish: ``kv`` {"k","v"} host arrays of shape
        (n_layers, len(tokens), heads, head_dim); entries are views."""
        n = len(tokens)
        lens = {L for L in range(self.block, n, self.block)} | {n}
        for L in sorted(lens):
            key = (L, tokens[:L].tobytes())
            if key in self._store:
                self._touch(key)
                continue
            self._put(key, {"k": kv["k"][:, :L], "v": kv["v"][:, :L]})

    def insert_blocks(self, tokens: np.ndarray, ids, on_retain) -> None:
        """Paged publish: for every block-multiple prefix length, store the
        covering block-id tuple (``ids`` is the slot's table row).  New
        entries call ``on_retain(entry)`` so the pool's store refcounts
        stay exact; already-present keys are just touched."""
        aligned = (len(tokens) // self.block) * self.block
        for L in range(self.block, aligned + 1, self.block):
            key = (L, tokens[:L].tobytes())
            if key in self._store:
                self._touch(key)
                continue
            entry = tuple(int(b) for b in ids[:L // self.block])
            on_retain(entry)
            self._put(key, entry)

    def stats(self) -> dict:
        return {
            "entries": len(self._store),
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "reused_tokens": self.reused_tokens,
            "prompt_tokens": self.prompt_tokens,
            "reused_frac": (self.reused_tokens / self.prompt_tokens
                            if self.prompt_tokens else 0.0),
        }
