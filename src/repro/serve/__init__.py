"""Serving substrate: request-lifecycle engine over a slotted KV pool.

``ServeEngine.submit()/step()/run()/stream()`` is the continuous-batching
API; ``generate()`` survives as a deprecated one-shot shim.  See
``serve.scheduler`` (FCFS admission, ragged right-padding) and
``serve.cache`` (KV slot pool, hash-keyed prefix reuse).
"""

from .engine import ServeEngine
from .scheduler import Request, RequestState, SamplingParams, Scheduler
from .cache import KVSlotPool, PrefixCache

__all__ = ["ServeEngine", "Request", "RequestState", "SamplingParams",
           "Scheduler", "KVSlotPool", "PrefixCache"]
