"""Serving substrate: request-lifecycle engine over a paged KV block pool.

``ServeEngine.submit()/step()/run()/stream()`` is the continuous-batching
API; ``generate()`` survives as a deprecated one-shot shim.  See
``serve.scheduler`` (FCFS admission, ragged right-padding, chunked-prefill
cursors) and ``serve.cache`` (paged block pool + block tables, legacy KV
slot pool, hash-keyed zero-copy prefix reuse).
"""

from .engine import ServeEngine
from .scheduler import Request, RequestState, SamplingParams, Scheduler
from .cache import KVSlotPool, PagedKVPool, PrefixCache
from .draft import DraftModelProposer, NgramProposer

__all__ = ["ServeEngine", "Request", "RequestState", "SamplingParams",
           "Scheduler", "KVSlotPool", "PagedKVPool", "PrefixCache",
           "NgramProposer", "DraftModelProposer"]
