"""Serving substrate: request-lifecycle engine over a paged KV block pool.

``ServeEngine.submit()/step()/run()/stream()`` is the continuous-batching
API; ``generate()`` survives as a deprecated one-shot shim.  See
``serve.scheduler`` (policy-ordered admission, preemption requeue, ragged
right-padding, chunked-prefill cursors), ``serve.slo`` (SLO specs +
FCFS/priority/EDF/fair-share scheduling policies), ``serve.traffic``
(seeded multi-tenant trace generation, JSONL replay), ``serve.faults``
(deterministic chaos plans driving the engine's blame-and-retry recovery)
and ``serve.cache`` (paged block pool + block tables, legacy KV slot
pool, hash-keyed zero-copy prefix reuse).
"""

from .engine import ServeEngine
from .scheduler import Request, RequestState, SamplingParams, Scheduler
from .cache import KVSlotPool, PagedKVPool, PrefixCache
from .draft import DraftModelProposer, NgramProposer
from .faults import (FaultInjected, FaultPlan, FaultSpec, PRESETS,
                     get_plan)
from .slo import (EDFPolicy, FairSharePolicy, FCFSPolicy, POLICIES,
                  PriorityPolicy, SLOPolicy, SLOSpec, get_policy)
from .traffic import (TenantSpec, TraceRequest, load_trace, make_trace,
                      max_seq_for, save_trace, two_tenant_bursty)

__all__ = ["ServeEngine", "Request", "RequestState", "SamplingParams",
           "Scheduler", "KVSlotPool", "PagedKVPool", "PrefixCache",
           "NgramProposer", "DraftModelProposer",
           "FaultInjected", "FaultPlan", "FaultSpec", "PRESETS", "get_plan",
           "SLOSpec", "SLOPolicy", "FCFSPolicy", "PriorityPolicy",
           "EDFPolicy", "FairSharePolicy", "POLICIES", "get_policy",
           "TenantSpec", "TraceRequest", "make_trace", "max_seq_for",
           "save_trace", "load_trace", "two_tenant_bursty"]
