"""Serving substrate: KV/SSM cache management + batched engine."""

from .engine import ServeEngine
