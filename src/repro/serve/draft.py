"""Draft-token proposers for speculative decoding.

Speculative decode splits each serving tick into *propose* (cheap guesses at
the next ``k`` tokens per active request) and *verify* (one fused target
forward scores all ``k+1`` positions; the accepted prefix commits, the rest
rolls back).  The proposer only affects *speed* — a bad draft costs wasted
verify positions, never wrong output, because the target model gates every
committed token.

Two sources:

* :class:`NgramProposer` — prompt-lookup self-draft.  No second model: the
  most recent occurrence of the context's trailing n-gram predicts its
  historical continuation.  Free to run, and effective exactly when decode
  output is repetitive (templated generation, code, the shared-prefix
  serving traces this repo benchmarks).
* :class:`DraftModelProposer` — a small autoregressive draft model sharing
  the target's config machinery (same vocab required).  Runs a greedy
  ``k``-token rollout per tick: one batched prefill over each request's
  committed context, then ``k-1`` cached decode steps.  Deliberately
  stateless across ticks (it re-prefills the context each tick) — simple and
  always consistent with rollbacks, at the cost of redundant draft compute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_params
from repro.models.lm import forward

from .cache import pad_cache_to
from .scheduler import pad_group


class NgramProposer:
    """Prompt-lookup drafting: match the context's trailing n-gram against
    its own history (longest n first), propose the ``k`` tokens that
    followed the most recent earlier occurrence.  Returns fewer than ``k``
    (possibly zero) tokens when no n-gram recurs."""

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        assert max_ngram >= min_ngram >= 1
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, contexts, k: int) -> list:
        return [self._one(np.asarray(c), k) for c in contexts]

    def _one(self, ctx: np.ndarray, k: int) -> np.ndarray:
        n_ctx = len(ctx)
        for n in range(self.max_ngram, self.min_ngram - 1, -1):
            if n_ctx <= n:
                continue
            pat = ctx[-n:]
            # windows over ctx[:-1]: every earlier position the n-gram ends
            # at (the final occurrence itself is excluded by the slice)
            win = np.lib.stride_tricks.sliding_window_view(ctx[:-1], n)
            hits = np.flatnonzero((win == pat[None, :]).all(axis=1))
            if len(hits):
                # most recent occurrence with a full k-token continuation;
                # an occurrence right at the context end would predict
                # almost nothing (its continuation is cut off)
                full = hits[hits + n + k <= n_ctx]
                start = int(full[-1] if len(full) else hits[-1]) + n
                d = ctx[start:start + k]
                if len(d):
                    return d.astype(np.int32)
        return np.zeros((0,), np.int32)


class DraftModelProposer:
    """Greedy ``k``-token rollout from a small draft LM (same vocab as the
    target).  ``params=None`` draws a fresh init from ``seed`` — with
    ``cfg``/``params`` equal to the target's, every draft token is accepted
    (the degenerate self-draft sanity case)."""

    def __init__(self, cfg, params=None, seed: int = 1):
        if cfg.family != "dense":
            raise NotImplementedError(
                "draft models must be dense attention LMs (the rollout "
                "appends through a KV cache)")
        self.cfg = cfg
        self.params = (params if params is not None
                       else init_params(jax.random.PRNGKey(seed), cfg))
        self._prefill = jax.jit(functools.partial(_draft_prefill, cfg))
        self._decode = jax.jit(functools.partial(_draft_decode, cfg),
                               donate_argnums=(1,))

    def propose(self, contexts, k: int) -> list:
        toks, lens = pad_group([np.asarray(c) for c in contexts], pow2=True)
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      jnp.asarray(lens - 1))
        # decode appends need k-1 extra cache positions past the bucket; the
        # per-row length override then hides each row's right-pad junk
        cache = pad_cache_to(cache, self.cfg, toks.shape[1] + k)
        cache["kv"] = dict(cache["kv"], length=jnp.asarray(lens))
        out = np.zeros((len(contexts), k), np.int32)
        tok = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        out[:, 0] = tok
        for i in range(1, k):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tok[:, None]))
            tok = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            out[:, i] = tok
        return [out[i] for i in range(len(contexts))]


def _draft_prefill(cfg, params, toks, last_idx):
    logits, cache = forward(params, toks, cfg, return_cache=True,
                            logits_mode="index", logits_index=last_idx)
    return logits[:, 0, :], cache


def _draft_decode(cfg, params, cache, toks):
    logits, cache = forward(params, toks, cfg, cache=cache,
                            logits_mode="last")
    return logits[:, -1, :], cache
