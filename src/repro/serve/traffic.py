"""Heterogeneous multi-tenant trace generation for the serving engine.

A trace is a list of :class:`TraceRequest` — ``(arrival_s, prompt,
max_new_tokens, SLOSpec)`` — sorted by arrival time.  Each
:class:`TenantSpec` describes one tenant's traffic: how many requests,
when they arrive (Poisson / Gamma-renewal / bursty), how long their
prompts and generations are, how much prompt they share (a per-tenant
pool of common prefixes, the prefix-cache workload), and the SLO tags
every request carries.

Everything derives from one seed, so a trace is reproducible from
``(tenants, seed)`` alone — and :func:`save_trace` / :func:`load_trace`
round-trip the materialized trace through JSONL so a run can be replayed
exactly (``launch/serve.py --trace-file``) regardless of generator
changes.

Arrival processes (all with mean rate ``rate`` req/s from ``start_s``):

* ``poisson`` — i.i.d. exponential interarrivals; CV² = 1.
* ``gamma``  — Gamma-renewal interarrivals with squared coefficient of
  variation ``cv2`` (> 1 = burstier than Poisson, < 1 = smoother).
* ``burst``  — arrivals land in simultaneous clumps of ``burst_size``;
  clumps are spaced exponentially so the long-run rate still holds.
* ``rate == 0`` — the whole tenant arrives at once at ``start_s``.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from .slo import SLOSpec


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic model + SLO tags."""

    name: str = "default"
    n_requests: int = 8
    #: mean arrival rate in req/s (0 = everything at ``start_s``)
    rate: float = 0.0
    #: ``poisson`` | ``gamma`` | ``burst``
    arrival: str = "poisson"
    #: squared coefficient of variation of gamma interarrivals
    cv2: float = 4.0
    #: arrivals per clump for ``arrival="burst"``
    burst_size: int = 4
    #: offset added to every arrival time
    start_s: float = 0.0
    #: inclusive uniform range of fresh prompt tokens per request
    prompt_len: tuple = (8, 32)
    #: inclusive uniform range of generation lengths
    max_new_tokens: tuple = (8, 16)
    #: tokens of tenant-shared prefix prepended to every prompt
    shared_prefix: int = 0
    #: distinct shared prefixes the tenant draws from (1 = one system
    #: prompt for the whole tenant)
    prefix_pool: int = 1
    priority: int = 0
    ttft_target_s: float | None = None
    tpot_target_s: float | None = None

    @property
    def slo(self) -> SLOSpec:
        return SLOSpec(ttft_target_s=self.ttft_target_s,
                       tpot_target_s=self.tpot_target_s,
                       tenant=self.name, priority=self.priority)


@dataclasses.dataclass
class TraceRequest:
    """One materialized arrival: everything ``submit()`` needs."""

    arrival_s: float
    prompt: np.ndarray
    max_new_tokens: int
    #: None = untagged (no SLO, default tenant, priority 0)
    slo: SLOSpec | None = None

    @property
    def tenant(self) -> str:
        return self.slo.tenant if self.slo is not None else "default"


def _interarrivals(spec: TenantSpec, rng) -> np.ndarray:
    n = spec.n_requests
    if spec.rate <= 0:
        return np.zeros(n)
    if spec.arrival == "poisson":
        return np.cumsum(rng.exponential(1.0 / spec.rate, n))
    if spec.arrival == "gamma":
        # Gamma(shape k, scale θ): mean kθ, CV² = 1/k — pick k from the
        # requested burstiness, θ to keep the mean interarrival 1/rate
        k = 1.0 / max(spec.cv2, 1e-6)
        theta = 1.0 / (spec.rate * k)
        return np.cumsum(rng.gamma(k, theta, n))
    if spec.arrival == "burst":
        n_bursts = -(-n // spec.burst_size)
        # clump spacing keeps the long-run rate: burst_size arrivals per
        # exponential(burst_size/rate) gap
        gaps = np.cumsum(rng.exponential(spec.burst_size / spec.rate,
                                         n_bursts))
        return np.repeat(gaps, spec.burst_size)[:n]
    raise ValueError(f"unknown arrival process {spec.arrival!r} "
                     f"(poisson | gamma | burst)")


def make_trace(tenants, vocab: int, seed: int = 0) -> list:
    """Materialize every tenant's arrivals into one merged trace, sorted
    by arrival time (ties keep tenant listing order).  Deterministic in
    ``(tenants, vocab, seed)``; each tenant draws from its own
    seed-derived stream, so adding a tenant never perturbs another's
    trace."""
    out = []
    for ti, spec in enumerate(tenants):
        rng = np.random.default_rng([seed, ti])
        pool = [rng.integers(0, vocab, (spec.shared_prefix,), dtype=np.int32)
                for _ in range(max(spec.prefix_pool, 1))]
        arrivals = spec.start_s + _interarrivals(spec, rng)
        lens = rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1,
                            spec.n_requests)
        news = rng.integers(spec.max_new_tokens[0],
                            spec.max_new_tokens[1] + 1, spec.n_requests)
        picks = rng.integers(0, len(pool), spec.n_requests)
        slo = spec.slo
        for i in range(spec.n_requests):
            fresh = rng.integers(0, vocab, (int(lens[i]),), dtype=np.int32)
            prompt = np.concatenate([pool[int(picks[i])], fresh]) \
                if spec.shared_prefix else fresh
            out.append(TraceRequest(arrival_s=float(arrivals[i]),
                                    prompt=prompt,
                                    max_new_tokens=int(news[i]), slo=slo))
    out.sort(key=lambda t: t.arrival_s)
    return out


def max_seq_for(trace, pad: int = 0) -> int:
    """Tightest engine ``max_seq`` that fits every request in ``trace``."""
    return max(len(t.prompt) + t.max_new_tokens for t in trace) + pad


#: JSONL trace schema version written by :func:`save_trace`.  Bump on any
#: incompatible field change; :func:`load_trace` refuses unknown versions
#: instead of silently misreading a future trace.
TRACE_SCHEMA = 1


def save_trace(path: str, trace, seed: int | None = None,
               meta: dict | None = None) -> None:
    """Write a trace as JSONL: one ``_meta`` header line (schema version +
    seed + anything in ``meta``), then one request per line."""
    with open(path, "w") as f:
        f.write(json.dumps({"_meta": dict(meta or {}, schema=TRACE_SCHEMA,
                                          seed=seed,
                                          n_requests=len(trace))}) + "\n")
        for t in trace:
            f.write(json.dumps({
                "arrival_s": t.arrival_s,
                "prompt": [int(x) for x in t.prompt],
                "max_new_tokens": t.max_new_tokens,
                **(t.slo.to_dict() if t.slo is not None else {})}) + "\n")


def load_trace(path: str):
    """Replay a JSONL trace; returns ``(trace, meta)``.  Traces written by
    a newer schema are rejected with a readable error (a header with no
    ``schema`` field is the legacy v0 layout, which v1 reads fine)."""
    trace, meta = [], {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "_meta" in d:
                meta = d["_meta"]
                schema = meta.get("schema", 0)
                if schema > TRACE_SCHEMA:
                    raise ValueError(
                        f"{path}: trace schema v{schema} is newer than "
                        f"this reader (v{TRACE_SCHEMA}) — regenerate the "
                        f"trace or upgrade repro.serve.traffic")
                continue
            trace.append(TraceRequest(
                arrival_s=float(d.get("arrival_s", 0.0)),
                prompt=np.asarray(d["prompt"], np.int32),
                max_new_tokens=int(d.get("max_new_tokens", 16)),
                slo=SLOSpec.from_dict(d)))
    trace.sort(key=lambda t: t.arrival_s)
    return trace, meta


def two_tenant_bursty(vocab: int, seed: int = 0, n_lo: int = 4,
                      n_hi: int = 4, lo_new: int = 224, hi_new: int = 8,
                      hi_start_s: float = 0.15,
                      hi_ttft_s: float | None = 1.0) -> list:
    """The benchmark/CI scenario: a batch tenant floods the engine with
    long generations at t=0, then a latency-sensitive tenant bursts in
    shortly after.  Under FCFS the ``hi`` burst queues behind the ``lo``
    drain; under priority/EDF it preempts into service — high-priority
    TTFT should collapse while total goodput stays (token totals are
    policy-invariant and preempted work is parked, not lost)."""
    lo = TenantSpec(name="lo", n_requests=n_lo, rate=0.0, start_s=0.0,
                    prompt_len=(16, 24), max_new_tokens=(lo_new, lo_new),
                    shared_prefix=16, priority=0, ttft_target_s=60.0)
    hi = TenantSpec(name="hi", n_requests=n_hi, rate=0.0,
                    start_s=hi_start_s, prompt_len=(8, 16),
                    max_new_tokens=(hi_new, hi_new), shared_prefix=16,
                    priority=5, ttft_target_s=hi_ttft_s)
    return make_trace([lo, hi], vocab, seed=seed)


#: named presets for the launch driver's ``--traffic`` flag
PRESETS = {"two-tenant-bursty": two_tenant_bursty}
