"""Batched serving engine: prefill → decode with functional caches.

The cache layout follows the dry-run cells: KV sequence dim shards over the
``model`` mesh axis for long contexts (flash-decode with global softmax
statistics, see models.layers._sdpa_decode); SSM archs carry O(1) recurrent
state.  Prefill produces the cache directly from the chunked forward; decode
is one jitted step per token with donated cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pasta
from repro.models import forward, init_cache
from repro.models.config import ModelConfig


def _pad_cache_to(cache: dict, cfg: ModelConfig, max_seq: int) -> dict:
    """Grow the prefill KV cache's sequence dim to ``max_seq`` slots."""
    if "kv" not in cache:
        return cache
    kv = cache["kv"]
    cur = kv["k"].shape[2]
    if cur >= max_seq:
        return cache
    pad = max_seq - cur

    def grow(x):
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, pad)
        return jnp.pad(x, widths)

    cache = dict(cache)
    cache["kv"] = {"k": grow(kv["k"]), "v": grow(kv["v"]),
                   "length": kv["length"]}
    return cache


class ServeEngine:
    """Greedy/temperature batched generation over the unified LM."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 512,
                 handler=None, rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.handler = handler or pasta.default_handler()
        self._key = jax.random.PRNGKey(rng_seed)
        self._prefill = jax.jit(functools.partial(self._prefill_impl, cfg))
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg),
                               donate_argnums=(1,))

    @staticmethod
    def _prefill_impl(cfg, params, tokens):
        logits, cache = forward(params, tokens, cfg, return_cache=True,
                                logits_mode="last")
        return logits[:, -1, :], cache

    @staticmethod
    def _decode_impl(cfg, params, cache, tokens):
        logits, cache = forward(params, tokens, cfg, cache=cache,
                                logits_mode="last")
        return logits[:, -1, :], cache

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(k, logits / temperature, axis=-1)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 temperature: float = 0.0) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for equal-length batches). Returns (B, max_new_tokens)."""
        self.handler.operator_start("serve.prefill",
                                    batch=int(prompts.shape[0]),
                                    prompt_len=int(prompts.shape[1]))
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        cache = _pad_cache_to(cache, self.cfg, self.max_seq)
        self.handler.operator_end("serve.prefill")
        out = []
        tok = self._sample(logits, temperature)
        out.append(tok)
        for i in range(max_new_tokens - 1):
            self.handler.operator_start("serve.decode", step=i)
            logits, cache = self._decode(self.params, cache, tok[:, None])
            tok = self._sample(logits, temperature)
            out.append(tok)
            self.handler.operator_end("serve.decode")
        return np.asarray(jnp.stack(out, axis=1))
