"""Batched serving engine: prefill → decode with functional caches.

The cache layout follows the dry-run cells: KV sequence dim shards over the
``model`` mesh axis for long contexts (flash-decode with global softmax
statistics, see models.layers._sdpa_decode); SSM archs carry O(1) recurrent
state.  Prefill produces the cache directly from the chunked forward; decode
is one jitted step per token with donated cache.

PASTA instrumentation is *per request*: every ``generate`` call runs inside
a child :class:`~repro.core.Session` of the engine's session, so each
request gets isolated tool reports (``request_reports``) while the parent
session still receives every event for fleet-wide aggregates.
"""

from __future__ import annotations

import collections
import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pasta
from repro.models import forward, init_cache
from repro.models.config import ModelConfig


def _pad_cache_to(cache: dict, cfg: ModelConfig, max_seq: int) -> dict:
    """Grow the prefill KV cache's sequence dim to ``max_seq`` slots."""
    if "kv" not in cache:
        return cache
    kv = cache["kv"]
    cur = kv["k"].shape[2]
    if cur >= max_seq:
        return cache
    pad = max_seq - cur

    def grow(x):
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, pad)
        return jnp.pad(x, widths)

    cache = dict(cache)
    cache["kv"] = {"k": grow(kv["k"]), "v": grow(kv["v"]),
                   "length": kv["length"]}
    return cache


class ServeEngine:
    """Greedy/temperature batched generation over the unified LM."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 512,
                 handler=None, session: "pasta.Session | None" = None,
                 rng_seed: int = 0, request_tools=None,
                 max_request_reports: int = 64):
        """``session``: parent Session for per-request child sessions (the
        innermost active session when omitted).  ``request_tools``: tool
        spec instantiated fresh for every request's child session; its
        reports land in ``request_reports``.  ``handler``: legacy pinned
        event sink — disables per-request sessions (compat path)."""
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.session = session
        self._handler = handler
        self.request_tools = request_tools
        self.request_reports: collections.deque = collections.deque(
            maxlen=max_request_reports)
        self._req_ids = itertools.count()
        self._key = jax.random.PRNGKey(rng_seed)
        self._prefill = jax.jit(functools.partial(self._prefill_impl, cfg))
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg),
                               donate_argnums=(1,))

    @staticmethod
    def _prefill_impl(cfg, params, tokens):
        logits, cache = forward(params, tokens, cfg, return_cache=True,
                                logits_mode="last")
        return logits[:, -1, :], cache

    @staticmethod
    def _decode_impl(cfg, params, cache, tokens):
        logits, cache = forward(params, tokens, cfg, cache=cache,
                                logits_mode="last")
        return logits[:, -1, :], cache

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(k, logits / temperature, axis=-1)

    @property
    def handler(self):
        """The engine's event sink: the pinned legacy handler, the parent
        session's handler, or the innermost active session's."""
        if self._handler is not None:
            return self._handler
        if self.session is not None:
            return self.session.handler
        return pasta.current_handler()

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 temperature: float = 0.0) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for equal-length batches). Returns (B, max_new_tokens)."""
        if self._handler is not None:
            # legacy pinned-handler path: emit directly, no child session
            return self._generate(self._handler, prompts, max_new_tokens,
                                  temperature)
        parent = self.session or pasta.current_session()
        rid = next(self._req_ids)
        # tools default to none, NOT the PASTA_TOOL env fallback — a
        # request pipeline is only built when the engine asked for one
        with parent.child(tools=self.request_tools or (),
                          name=f"{parent.name}/request{rid}") as req:
            out = self._generate(req.handler, prompts, max_new_tokens,
                                 temperature)
        if self.request_tools:
            self.request_reports.append(req.reports())
        req.close()       # drop the per-request pipeline (reports kept)
        return out

    def _generate(self, handler, prompts, max_new_tokens: int,
                  temperature: float) -> np.ndarray:
        handler.operator_start("serve.prefill",
                               batch=int(prompts.shape[0]),
                               prompt_len=int(prompts.shape[1]))
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        cache = _pad_cache_to(cache, self.cfg, self.max_seq)
        handler.operator_end("serve.prefill")
        out = []
        tok = self._sample(logits, temperature)
        out.append(tok)
        for i in range(max_new_tokens - 1):
            handler.operator_start("serve.decode", step=i)
            logits, cache = self._decode(self.params, cache, tok[:, None])
            tok = self._sample(logits, temperature)
            out.append(tok)
            handler.operator_end("serve.decode")
        return np.asarray(jnp.stack(out, axis=1))
