"""Request-lifecycle serving engine: continuous batching over a paged KV pool.

The engine is a scheduler tick loop, not a one-shot call::

    engine = ServeEngine(cfg, params, max_seq=256, max_slots=4)
    rid = engine.submit(prompt, SamplingParams(max_new_tokens=32))
    while engine.step()["working"]:
        ...                                    # or: engine.run(requests)

One :meth:`step` is one scheduler tick: admit waiting requests FCFS into
free KV slots (gated on block availability in paged mode), prefill them,
then one fused decode step over *all* fully-prefilled slots, then retire
finished requests.  Heterogeneous traffic therefore shares every decode
dispatch, and batch occupancy/goodput become measurable quantities instead
of a fixed batch dimension.

KV memory for the ``dense`` family is **paged**
(:class:`~repro.serve.cache.PagedKVPool`): one block pool plus per-slot
block tables, so a prefix-cache hit aliases the stored blocks into the new
request's table (refcount bump, zero bytes copied — no device→host
``extract_kv`` round-trip on the prefill critical path) and publishing a
finished prefill retains the slot's own blocks under store keys.  Long cold
prefills optionally split into **chunks** across scheduler ticks
(``prefill_chunk=``), bounding how long prefill work can stall co-resident
decodes: ``prefill_chunk`` is a per-tick prefill token budget shared FCFS
across all mid-prefill requests, spent through the per-query-causal
multi-token append path before each fused decode — so the work between two
decode dispatches never exceeds one chunk.  SSM/hybrid (and MoE) families
keep the exact-length non-paged :class:`~repro.serve.cache.KVSlotPool`
path — their recurrent state is not block-addressable.

**Scheduling is policy-pluggable** (``policy=``): an
:class:`~repro.serve.slo.SLOPolicy` stable-sorts the waiting queue each
tick (FCFS default — byte-identical to the policy-free scheduler) and,
for preemptive policies (priority/EDF), names running victims when
higher-urgency work waits with no free slot.  Preemption is
**evict-and-requeue without losing work**: the victim's committed KV
blocks are parked in the :class:`~repro.serve.cache.PrefixCache` through
the same ``insert_blocks``/refcount path a finished prefill uses (zero
bytes copied), the slot frees, and the request rejoins the queue front;
re-admission looks up its *context* (prompt + committed tokens), aliases
the parked blocks straight back, and the position-keyed sampler resumes
at exactly the next position — so a preempted request's output is
byte-identical to its unpreempted run, at any temperature.  The
``interleave=`` knob arbitrates prefill vs decode per tick:
``"chunked"`` spends the prefill budget every tick, ``"decode"`` defers
chunk work while any slot can decode.

**Speculative decoding** (``spec_decode=k``) reuses that same multi-token
append path for decode itself: a draft proposer (``draft=`` — n-gram
prompt-lookup self-draft, a small draft model, or any
``propose(contexts, k)`` object) guesses ``k`` tokens per active slot,
one fused verify forward scores all ``k+1`` positions, and the accept
loop commits the matching prefix (plus the bonus token on full
acceptance) while rejected suffixes roll back via
``PagedKVPool.truncate`` — block-table accounting only, the dead KV bytes
are overwritten before any mask exposes them.  Sampling keys derive from
``(seed-or-rid, position)`` so output is byte-identical to the
non-speculative path at ANY temperature; speculation changes speed,
never tokens.  Dense-attention families only: recurrent SSM/hybrid state
cannot roll back to an arbitrary position.

PASTA instrumentation is per request *across interleaved steps*: each
submitted request opens a child :class:`~repro.core.Session` of the engine's
session at submit time and closes it at retirement, so its lifecycle events
(``serve.request.submit/admit/first_token/finish``) and any per-request tool
reports span queueing, prefill, and every fused decode tick it participated
in, while the parent session aggregates the fleet view (the registered
``serving`` tool turns those events into TTFT/TPOT, occupancy timeline,
prefix-hit-rate, block-pool-utilization and chunk-stall reports).

**Fault tolerance** is blame-and-retry, not abort-everything.  A seeded
:class:`~repro.serve.faults.FaultPlan` (``faults=``) injects deterministic
chaos — tick exceptions, poisoned requests, NaN logits, stalls, pool
pressure, host-preemption signals — and the recovery layer turns a failed
tick into surgical cleanup: non-finite logits rows blame their request
directly, attributable tick exceptions are *bisected* over the live set
(``FaultPlan.probe``) to find the culprit(s), and every innocent runner is
losslessly re-queued by parking its committed KV blocks in the prefix
store exactly like a policy preemption (zero bytes copied, byte-identical
resumed output).  Blamed requests retry up to ``max_request_retries``
times behind a capped exponential backoff (their KV is recomputed, their
tokens are position-keyed so the output still cannot change) and then end
with status ``failed``.  ``SLOSpec.deadline_s`` is enforced every tick
(status ``timeout``, slot + blocks + owed reservation released, child
session closed).  Under sustained pool pressure or repeated slow ticks
the engine sheds load in declared order — speculative decode off, prefill
chunk budget halved, new admissions ``rejected`` — and restores each knob
as pressure clears.  ``health()`` accounts for every fault, retry,
timeout and degradation event; the session sees ``serve.fault`` /
``serve.degrade`` / ``serve.request.retry|timeout|failed|reject`` events.

``generate(prompts)`` survives as a deprecated shim over ``submit``/``run``
with the legacy observability contract (one child session per *call*).
``abort(rid)`` cancels a request at any lifecycle stage, releasing its slot,
its pool blocks and its child session; ``run``/``stream``/``generate`` keep
abort-all as the backstop for *unattributable* exceptions (anything the
recovery layer does not own), so a mid-drain failure still cannot leak KV
slots or leave sessions open forever.
"""

from __future__ import annotations

import functools
import itertools
import time
import warnings

import collections

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pasta
from repro.models import forward
from repro.models.config import ModelConfig
from .cache import (KVSlotPool, PagedKVPool, PrefixCache, bucket,
                    pad_cache_to)
from .draft import DraftModelProposer, NgramProposer
from .faults import FaultInjected, get_plan
from .scheduler import (Request, RequestState, SamplingParams, Scheduler,
                        pad_group)
from .slo import get_policy

#: kept under the old private name — external callers imported it from here
_pad_cache_to = pad_cache_to

#: families whose decode state is attention KV only — eligible for padded
#: group prefill, prefix-cache reuse, and the paged block pool.  SSM/hybrid
#: state summarizes the whole prefix nonlinearly (a pad token would mutate
#: it, unlike masked KV) and MoE routing couples tokens, so those prefill
#: alone at exact length.  vlm/audio would qualify if tokenized, but their
#: configs are embedding-frontend stubs with no autoregressive token loop.
_KV_ONLY = ("dense",)

#: lifecycle states no transition leaves — abort/cancel paths are
#: idempotent against all of them
_TERMINAL = frozenset((RequestState.FINISHED, RequestState.ABORTED,
                       RequestState.FAILED, RequestState.TIMEOUT,
                       RequestState.REJECTED))

#: degradation ladder: level -> the knob that level sheds
_DEGRADE_KNOBS = {1: "spec_decode_off", 2: "prefill_chunk_halved",
                  3: "reject_admissions"}


class ServeEngine:
    """Continuous-batching generation engine over the unified LM."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 512,
                 max_slots: int = 8, handler=None,
                 session: "pasta.Session | None" = None,
                 rng_seed: int = 0, request_tools=None,
                 max_request_reports: int = 64, prefix_cache: bool = True,
                 prefix_block: int = 16, max_retained_requests: int = 4096,
                 paged: bool | None = None, block_size: int | None = None,
                 n_blocks: int | None = None,
                 prefill_chunk: int | None = None,
                 spec_decode: int = 0, draft="ngram",
                 draft_cfg: ModelConfig | None = None, draft_params=None,
                 policy=None, interleave: str = "chunked",
                 faults=None, fault_seed: int = 0,
                 max_request_retries: int = 2,
                 retry_backoff_s: float = 0.05,
                 retry_backoff_cap_s: float = 0.5,
                 degrade: bool | None = None,
                 slow_tick_s: float = 0.05):
        """``max_slots``: concurrent requests the KV pool holds; waiting
        requests queue FCFS.  ``session``: parent Session for per-request
        child sessions (innermost active session when omitted).
        ``request_tools``: tool spec instantiated fresh for every request's
        child session; reports land in ``request_reports`` at retirement.
        ``handler``: legacy pinned event sink — disables per-request
        sessions (compat path).  ``prefix_cache``: hash-keyed prompt-prefix
        reuse (KV-only families; block-aligned keys of ``prefix_block``).
        ``paged``: block-table KV layout (default: on for KV-only families,
        impossible for SSM/hybrid).  ``block_size``: pool block width in
        tokens (defaults to ``prefix_block`` so prefix hits alias whole
        blocks).  ``n_blocks``: pool capacity (default: per-slot parity plus
        two sequences of prefix-store headroom).  ``prefill_chunk``:
        per-tick prefill token budget, shared FCFS across mid-prefill
        requests (paged mode only; ``None`` = unbounded whole-prompt
        prefills).  ``spec_decode``: draft ``k`` tokens per active slot per
        tick and verify all ``k+1`` positions in ONE fused target forward
        (``0`` = the plain one-token-per-tick path, unchanged).  ``draft``:
        ``"ngram"`` (prompt-lookup self-draft, no second model),
        ``"model"`` (greedy rollout from ``draft_cfg``/``draft_params``;
        defaults to the target itself — every draft accepted), or any
        object with ``propose(contexts, k)``.  ``policy``: scheduling
        policy name (``fcfs``/``priority``/``edf``/``fair``) or
        :class:`~repro.serve.slo.SLOPolicy` instance — orders the waiting
        queue and, preemptive policies, names running victims to
        evict-and-requeue (paged mode only; ``None``/``fcfs`` is
        byte-identical to the policy-free scheduler).  ``interleave``:
        prefill/decode arbitration per tick — ``"chunked"`` (default)
        spends the FCFS ``prefill_chunk`` budget every tick;
        ``"decode"`` defers ALL mid-prefill chunk work on ticks where any
        slot can decode (decode-priority; requires ``prefill_chunk``).
        ``faults``: a :class:`~repro.serve.faults.FaultPlan`, a preset name,
        or ``None`` — deterministic chaos injected into the tick loop
        (paged mode only: recovery parks KV via the prefix store).
        ``max_request_retries``/``retry_backoff_s``/``retry_backoff_cap_s``:
        blamed requests are re-queued (full recompute, byte-identical
        output) up to this many times behind a capped exponential backoff,
        then end ``failed``.  ``degrade``: enable the load-shedding ladder
        (``None`` = auto: on only when a fault plan is present, so plain
        engines never shed on compile spikes); ``slow_tick_s``: absolute
        floor for slow-tick detection."""
        if cfg.frontend != "none":
            raise NotImplementedError(
                "ServeEngine decodes token ids; embedding-frontend archs "
                "have no autoregressive token loop to serve")
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.session = session
        self._handler = handler
        self._route_handler = None        # legacy generate(): pin to the
        self._per_request_sessions = True  # per-call child session
        self.request_tools = request_tools
        self.request_reports: collections.deque = collections.deque(
            maxlen=max_request_reports)
        self._req_ids = itertools.count()
        self._call_ids = itertools.count()   # legacy generate() child names
        self._rng_seed = rng_seed
        self.requests: dict = {}             # rid -> Request
        # long-lived engines must not grow host memory with traffic served:
        # retired Requests (prompt + tokens) are pruned FIFO beyond this
        # bound (live requests are never pruned; the floor keeps one tick's
        # worth of retirements readable for run()/stream() collection)
        self.max_retained_requests = max(max_retained_requests, max_slots)
        self._retired: collections.deque = collections.deque()
        self.policy = get_policy(policy)
        self.sched = Scheduler(max_slots, policy=self.policy)

        # ---- fault tolerance: chaos plan, retry pen, degradation ladder
        self.faults = get_plan(faults, seed=fault_seed)
        self.max_request_retries = int(max_request_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_cap_s = float(retry_backoff_cap_s)
        self.degrade_enabled = ((self.faults is not None) if degrade is None
                                else bool(degrade))
        self.slow_tick_s = float(slow_tick_s)
        self.ticks = 0
        self.degrade_level = 0
        self.degraded_ticks = 0
        self.fault_ticks = 0
        self.tick_retries = 0
        self.request_retries = 0
        self.failed_requests = 0
        self.timeouts = 0
        self.rejections = 0
        self.isolated_innocents = 0
        self.fault_probes = 0
        self.host_preempt_signals = 0
        self.recomputed_tokens = 0
        #: blamed-but-retryable requests serving their backoff; NOT in the
        #: scheduler's waiting queue, so they cannot head-of-line block
        self._backoff: list = []
        self._tick_durs: collections.deque = collections.deque(maxlen=32)
        self._slow_streak = 0
        self._calm_streak = 0
        self._admission_blocked = False
        self._fault_streak = 0

        self.paged = (cfg.family in _KV_ONLY) if paged is None else paged
        if self.paged and cfg.family not in _KV_ONLY:
            raise ValueError(
                f"paged KV serving requires a KV-only family, not "
                f"{cfg.family!r} (SSM/hybrid state is not block-addressable)")
        if prefill_chunk is not None and not self.paged:
            raise ValueError("prefill_chunk requires the paged KV pool")
        if self.faults is not None and not self.paged:
            raise ValueError(
                "fault injection requires the paged KV pool: recovery "
                "parks innocent requests' KV in the prefix store, which "
                "non-paged recurrent state cannot re-alias")
        self.block_size = block_size if block_size is not None else \
            (prefix_block if self.paged else 16)
        if self.paged:
            # prefix keys must sit on block boundaries so a hit aliases
            # whole blocks and the suffix starts in a fresh one
            prefix_block = self.block_size
            self.pool = PagedKVPool(cfg, max_slots, max_seq,
                                    block_size=self.block_size,
                                    n_blocks=n_blocks)
        else:
            self.pool = KVSlotPool(cfg, max_slots, max_seq)
        self.prefill_chunk = None
        if prefill_chunk is not None:
            # round up to a block multiple: chunk boundaries then coincide
            # with block boundaries (tidy tables, O(log) tail shapes)
            self.prefill_chunk = -(-prefill_chunk // self.block_size) \
                * self.block_size
        if self.policy is not None and self.policy.preemptive \
                and not self.paged:
            raise ValueError(
                f"policy {self.policy!r} preempts via the prefix store, "
                f"which needs the paged KV pool; use a non-preemptive "
                f"policy (e.g. PriorityPolicy(preempt=False)) or paged "
                f"mode")
        if interleave not in ("chunked", "decode"):
            raise ValueError(
                f"interleave must be 'chunked' or 'decode', not "
                f"{interleave!r}")
        if interleave == "decode" and self.prefill_chunk is None:
            raise ValueError(
                "interleave='decode' arbitrates the chunked-prefill "
                "budget; set prefill_chunk=")
        self.interleave = interleave
        #: preemption lifetime counters: evictions, blocks parked into the
        #: prefix store at eviction, and tokens/blocks aliased back (zero
        #: recompute) at resumed admissions
        self.preemptions = 0
        self.parked_blocks = 0
        self.recovered_tokens = 0
        self.recovered_blocks = 0
        self.prefix_cache = None
        if prefix_cache and cfg.family in _KV_ONLY:
            on_evict = ((lambda ent: self.pool.release(ent, store=True))
                        if self.paged else None)
            self.prefix_cache = PrefixCache(block=prefix_block,
                                            on_evict=on_evict)
            if self.paged:
                self.pool.evict_cb = self.prefix_cache.evict_one
        #: host bytes copied to duplicate K/V for the prefix store — zero in
        #: paged mode (the store aliases pool blocks), nonzero only on the
        #: legacy extract_kv publish path
        self.duplicate_copy_bytes = 0
        self._prefilling: list = []          # paged requests mid-prefill
        #: rid -> blocks this live request may still draw from the pool
        #: (admission reserves the whole horizon incl. speculative spill;
        #: lazy binding/ensure() draws against it, truncate() pays back)
        self._owed: dict = {}
        self.last_tokens = np.zeros((max_slots,), np.int32)
        self.decode_steps = 0
        self._prefill_cold = jax.jit(
            functools.partial(self._prefill_cold_impl, cfg))
        self._prefill_suffix = jax.jit(
            functools.partial(self._prefill_suffix_impl, cfg),
            donate_argnums=(1,))
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg),
                               donate_argnums=(1,))

        self.spec_k = int(spec_decode)
        if self.spec_k < 0:
            raise ValueError("spec_decode must be >= 0")
        self.proposer = None
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        #: one full parameter read per decode dispatch — the model-bytes
        #: term of the analytic per-token bandwidth estimate
        self.params_bytes = int(sum(x.nbytes for x in jax.tree.leaves(params)))
        if self.spec_k:
            if cfg.family not in _KV_ONLY:
                raise NotImplementedError(
                    f"speculative decoding is unsupported for the "
                    f"{cfg.family!r} family: verification rolls back by "
                    f"truncating KV lengths, but SSM/hybrid recurrent state "
                    f"cannot un-absorb a rejected suffix")
            if isinstance(draft, str):
                if draft == "ngram":
                    self.proposer = NgramProposer()
                elif draft == "model":
                    dcfg = draft_cfg if draft_cfg is not None else cfg
                    if dcfg.vocab_size != cfg.vocab_size:
                        raise ValueError(
                            f"draft vocab {dcfg.vocab_size} != target "
                            f"vocab {cfg.vocab_size}")
                    dparams = (draft_params if draft_params is not None
                               else (params if draft_cfg is None else None))
                    self.proposer = DraftModelProposer(dcfg, dparams)
                else:
                    raise ValueError(f"unknown draft source {draft!r}")
            else:
                self.proposer = draft
            self._verify = jax.jit(functools.partial(self._verify_impl, cfg),
                                   donate_argnums=(1,))
            self._verify_idx = np.broadcast_to(
                np.arange(self.spec_k + 1, dtype=np.int32),
                (max_slots, self.spec_k + 1)).copy()
            #: constant per engine; transferred once, not per tick
            self._verify_idx_dev = jnp.asarray(self._verify_idx)

    # ------------------------------------------------------------- jit impls
    @staticmethod
    def _prefill_cold_impl(cfg, params, tokens, last_idx):
        logits, cache = forward(params, tokens, cfg, return_cache=True,
                                logits_mode="index", logits_index=last_idx)
        return logits[:, 0, :], cache

    @staticmethod
    def _prefill_suffix_impl(cfg, params, cache, tokens, last_idx):
        logits, cache = forward(params, tokens, cfg, cache=cache,
                                logits_mode="index", logits_index=last_idx)
        return logits[:, 0, :], cache

    @staticmethod
    def _decode_impl(cfg, params, cache, tokens):
        logits, cache = forward(params, tokens, cfg, cache=cache,
                                logits_mode="last")
        return logits[:, -1, :], cache

    @staticmethod
    def _verify_impl(cfg, params, cache, tokens, idx):
        # speculative verify: ONE fused forward appends [last, d_1..d_k] per
        # row through the per-query-causal cache path and reads logits at
        # every position — logits[:, s] is the target's next-token
        # distribution given the committed prefix plus drafts d_1..d_s
        logits, cache = forward(params, tokens, cfg, cache=cache,
                                logits_mode="index", logits_index=idx)
        return logits, cache

    # -------------------------------------------------------------- plumbing
    @property
    def handler(self):
        """The engine's fleet-level event sink: the legacy generate() route,
        the pinned legacy handler, the parent session's handler, or the
        innermost active session's."""
        if self._route_handler is not None:
            return self._route_handler
        if self._handler is not None:
            return self._handler
        if self.session is not None:
            return self.session.handler
        return pasta.current_handler()

    def _req_handler(self, req: Request):
        """Per-request events go through the request's child session (which
        forwards to the parent), or the engine sink when sessions are off."""
        if req.session is not None:
            return req.session.handler
        return self.handler

    def _sample_one(self, req: Request, logits_row: np.ndarray,
                    position: int | None = None) -> int:
        """Sample one token.  The temperature>0 key is derived purely from
        ``(seed-or-(engine seed, rid), position)`` — never from shared key
        state — so sampled streams are schedule-invariant: byte-identical
        whatever the admission interleaving, and identical between the
        speculative (sample-and-match) and sequential paths."""
        if req.params.temperature <= 0:
            return int(np.argmax(logits_row))
        position = len(req.tokens) if position is None else position
        seed = req.params.seed
        key = jax.random.PRNGKey(self._rng_seed if seed is None else seed)
        if seed is None:
            key = jax.random.fold_in(key, req.rid)
        key = jax.random.fold_in(key, position)
        return int(jax.random.categorical(
            key, jnp.asarray(logits_row) / req.params.temperature))

    def pool_stats(self) -> dict:
        """Block-pool / slot-pool memory accounting, including the bytes
        duplicated for the prefix store (zero when paged: aliased blocks)."""
        if self.paged:
            st = self.pool.stats()
        else:
            st = {"paged": False, "slots": self.pool.slots,
                  "max_seq": self.pool.max_seq}
        st["duplicate_copy_bytes"] = self.duplicate_copy_bytes
        return st

    # --------------------------------------------------------------- warmup
    def warmup(self, prompt_lens=()) -> dict:
        """Compile the steady-state dispatches before traffic arrives, so
        TTFT/TPOT percentiles measure serving latency rather than XLA.

        Warms the fused decode (and, speculative, the fused verify) at their
        one production shape, plus cold- and suffix-prefill per distinct
        pow2 bucket of ``prompt_lens``.  All warmup forwards run against
        fully *parked* rows (paged: every position resolves to the drop
        sentinel) or rows a later admission overwrites wholesale, so pool
        state stays exactly as if warmup never ran.  Call on an idle engine;
        returns ``{"compile_s", "warmed"}``."""
        assert not self.sched.has_work, "warmup() needs an idle engine"
        t0 = time.perf_counter()
        warmed = []
        slots = self.pool.slots
        zeros = jnp.zeros((slots, 1), jnp.int32)
        if self.paged:
            span = self.pool.blocks_per_seq * self.pool.block_size
            parked = np.full((slots,), span, np.int32)
            _, cache = self._decode(self.params, self.pool.cache_view(parked),
                                    zeros)
            self.pool.adopt(cache)
            if self.spec_k:
                _, cache = self._verify(
                    self.params, self.pool.cache_view(parked),
                    jnp.zeros((slots, self.spec_k + 1), jnp.int32),
                    self._verify_idx_dev)
                self.pool.adopt(cache)
        else:
            # free-slot rows absorb one junk token at their current length;
            # harmless — admission's insert() rewrites the whole slot row
            # (KV, recurrent state, length) before the slot is ever read
            if self.spec_k:
                kv = self.pool.cache["kv"]
                parked = jnp.full((slots,), self.pool.max_seq, jnp.int32)
                cache = dict(self.pool.cache, kv=dict(kv, length=parked))
                _, self.pool.cache = self._verify(
                    self.params, cache,
                    jnp.zeros((slots, self.spec_k + 1), jnp.int32),
                    self._verify_idx_dev)
            else:
                _, self.pool.cache = self._decode(self.params,
                                                  self.pool.cache, zeros)
        warmed.append(("decode", slots, self.spec_k + 1))
        buckets = sorted({min(bucket(int(n)), self.max_seq)
                          for n in prompt_lens})
        for length in buckets:
            one = jnp.zeros((1, length), jnp.int32)
            idx = jnp.zeros((1,), jnp.int32)
            self._prefill_cold(self.params, one, idx)
            warmed.append(("prefill_cold", 1, length))
            if self.paged:
                view = self.pool.cache_view(
                    np.asarray([span], np.int32), rows=[0])
                _, cache = self._prefill_suffix(self.params, view, one, idx)
                self.pool.adopt(cache)
                warmed.append(("prefill_suffix", 1, length))
            elif self.prefix_cache is not None:
                seeded = self.pool.seeded_prefill_cache(None)
                self._prefill_suffix(self.params, seeded, one, idx)
                warmed.append(("prefill_suffix", 1, length))
        return {"compile_s": time.perf_counter() - t0, "warmed": warmed}

    # ------------------------------------------------------------ submission
    def submit(self, prompt, params: SamplingParams | None = None,
               slo=None) -> int:
        """Enqueue one generation request; returns its request id.  The
        request's child Session opens here and spans queueing, prefill, and
        every fused decode step until retirement.  ``slo``: optional
        :class:`~repro.serve.slo.SLOSpec` — tenant/priority tags feed the
        scheduling policy, TTFT/TPOT targets feed the serving tool's
        goodput/attainment accounting."""
        params = params or SamplingParams()
        prompt = np.asarray(prompt, dtype=np.int32)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            raise ValueError("submit() takes ONE 1-D token prompt; use "
                             "run()/generate() for batches")
        if params.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.shape[0] + params.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new_tokens "
                f"({params.max_new_tokens}) exceeds max_seq={self.max_seq}")
        rid = next(self._req_ids)
        req = Request(rid=rid, prompt=prompt, params=params, slo=slo,
                      submit_time=time.perf_counter())
        attrs = {}
        if slo is not None:
            attrs = {"tenant": slo.tenant, "priority": slo.priority,
                     "ttft_target_s": slo.ttft_target_s,
                     "tpot_target_s": slo.tpot_target_s}
        if self.degrade_enabled and self.degrade_level >= 3:
            # shedding level 3: fail fast at the door — no child session,
            # no queue slot; callers see terminal status "rejected"
            req.state = RequestState.REJECTED
            self.requests[rid] = req
            self.rejections += 1
            self._req_handler(req).operator_start(
                "serve.request.submit", rid=rid, prompt_len=req.prompt_len,
                max_new_tokens=params.max_new_tokens, **attrs)
            self._req_handler(req).operator_start(
                "serve.request.reject", rid=rid,
                degrade_level=self.degrade_level)
            self._retired.append(rid)
            while len(self._retired) > self.max_retained_requests:
                self.requests.pop(self._retired.popleft(), None)
            return rid
        if self._per_request_sessions and self._handler is None:
            parent = self.session or pasta.current_session()
            req.session = parent.child(
                tools=self.request_tools or (),
                name=f"{parent.name}/request{rid}")
        self.requests[rid] = req
        self.sched.submit(req)
        self._req_handler(req).operator_start(
            "serve.request.submit", rid=rid, prompt_len=req.prompt_len,
            max_new_tokens=params.max_new_tokens, **attrs)
        return rid

    # ------------------------------------------------------------------ tick
    def _horizon_blocks(self, req: Request) -> int:
        """Blocks the request may ever hold at once.  Speculative verify
        writes can spill up to ``k-1`` positions past the final committed
        token before rolling back, so admission reserves that headroom too
        (capped at the table span — the attention scatter drops beyond it)."""
        horizon = req.prompt_len + req.params.max_new_tokens
        if self.spec_k:
            span = self.pool.blocks_per_seq * self.pool.block_size
            horizon = min(horizon - 1 + self.spec_k, span)
        return self.pool.blocks_for(horizon)

    def _fits(self, req: Request) -> bool:
        """Paged admission gate: enough blocks (free + store-evictable) for
        the request's whole horizon, on top of what already-admitted
        requests are still owed.  Conservative — a prefix hit will need
        fewer fresh blocks than this — and deadlock-free: aliasing a store
        entry removes at most as many evictable blocks as it saves, and
        every later draw (bind, lazy ensure) decrements the reservation by
        exactly the blocks taken, so ``available() >= sum(owed)`` is an
        invariant."""
        need = self._horizon_blocks(req)
        avail = self.pool.available() - sum(self._owed.values())
        if self.faults is not None:
            # injected pool pressure: blocks withheld from admission (never
            # from already-admitted draws, so the owed invariant holds)
            avail -= self.faults.held_blocks(self.ticks)
        if avail < need:
            self._admission_blocked = True
            return False
        self._owed[req.rid] = need
        return True

    def _bind_paged(self, req: Request, hit_len: int, entry) -> None:
        """Build the request's block table for the admission CONTEXT only
        (the prompt — plus the committed tokens, on a resumed admission):
        alias the prefix-store blocks (refcount bump, zero copies) and
        allocate fresh blocks for the rest.  Decode/speculative growth
        binds lazily (:meth:`PagedKVPool.ensure`) against the admission
        reservation."""
        need = self.pool.blocks_for(req.prefill_len)
        shared = list(entry) if hit_len else []
        if shared:
            self.pool.retain(shared)            # this request's live ref
        fresh = self.pool.alloc(need - len(shared))
        if fresh is None:                       # _fits() guarantees capacity
            raise RuntimeError(
                f"paged pool exhausted admitting rid={req.rid}: need "
                f"{need - len(shared)} fresh blocks, "
                f"{self.pool.available()} available")
        self.pool.bind_slot(req.slot, shared, fresh)
        self._owed[req.rid] = max(self._owed.get(req.rid, need) - need, 0)
        req.progress = hit_len

    def _grow_slot(self, req: Request, n_tokens: int) -> None:
        """Lazy block binding for decode/verify writes up to ``n_tokens``
        positions, drawing against the request's admission reservation."""
        grew = self.pool.ensure(req.slot, n_tokens)
        if grew:
            self._owed[req.rid] = max(self._owed.get(req.rid, 0) - grew, 0)

    @property
    def has_work(self) -> bool:
        """Live work anywhere: the scheduler's queue and slots, plus blamed
        requests serving their retry backoff in the engine's pen."""
        return self.sched.has_work or bool(self._backoff)

    def step(self) -> dict:
        """One scheduler tick: preempt victims the policy names, reorder +
        admit+prefill into free slots (at most one chunk's worth of prefill
        tokens across all mid-prefill requests), one fused decode over all
        fully-prefilled slots, retire finished requests.  Returns
        ``{"admitted","finished","new_tokens","active","queued","working"}``.

        The fault-tolerance envelope lives here: deadlines are enforced and
        expired retry backoffs re-admitted first; injected stalls /
        pool-pressure / host-preemption signals are applied; an injected
        tick exception is caught and recovered (blame bisection, retry or
        fail the culprits, park every innocent runner losslessly); and the
        degradation ladder updates from the tick's pressure signals.
        """
        self.ticks += 1
        t0 = time.perf_counter()
        self._admission_blocked = False
        self._enforce_deadlines(t0)
        self._readmit_backoff(t0)
        if self.faults is not None:
            stall = self.faults.tick_stall_s(self.ticks)
            if stall > 0:
                time.sleep(stall)
            for _ in range(self.faults.preempt_signals(self.ticks)):
                running = sorted(self.sched.running.values(),
                                 key=lambda r: r.rid)
                if not running:
                    break
                # the host wants a slot back — evict the newest runner
                # (least sunk work), parked losslessly like any victim
                self.host_preempt_signals += 1
                self._preempt(running[-1], reason="host")
        out: dict = {"admitted": [], "finished": [], "new_tokens": []}
        try:
            self._step_inner(out)
            self._fault_streak = 0
        except FaultInjected as exc:
            self._fault_streak += 1
            if self._fault_streak > 12:
                # unrecoverable storm: every recent tick died — fall back
                # to the callers' abort-all backstop instead of spinning
                raise
            self._recover(exc, out)
        self._update_degradation(time.perf_counter() - t0)
        # tick boundary marker: lets per-tick reductions (prefill-stall
        # accounting in the serving tool) close their window even on ticks
        # with no decodable slot — or on an abandoned faulty tick
        self.handler.operator_start("serve.tick", active=self.sched.n_active,
                                    queued=self.sched.n_queued,
                                    degrade_level=self.degrade_level)
        if not self.sched.has_work and self._backoff:
            # nothing runnable until a backoff expires: yield briefly so
            # run()/stream() drain loops don't spin the host CPU
            wake = min(r.retry_at for r in self._backoff)
            time.sleep(min(max(wake - time.perf_counter(), 0.0), 0.02))
        out["active"] = self.sched.n_active
        out["queued"] = self.sched.n_queued
        out["working"] = self.has_work
        return out

    def _step_inner(self, out: dict) -> None:
        """The actual tick work; ``out`` accumulates what committed, so an
        abandoned tick still reports the tokens it landed before the
        fault."""
        new_tokens: list = out["new_tokens"]
        finished: list = out["finished"]
        if self.policy is not None:
            now = time.perf_counter()
            if self.policy.preemptive and self.paged and self.sched.waiting:
                for victim in self.policy.victims(
                        list(self.sched.waiting), dict(self.sched.running),
                        self.sched.n_free, now):
                    self._preempt(victim)
            self.sched.reorder(now)
        admitted = self.sched.admit(fits=self._fits if self.paged else None)
        out["admitted"] = [r.rid for r in admitted]
        cold_group: list = []
        for req in admitted:
            # a resumed admission must re-materialize prompt + committed
            # tokens — lookups/prefill run over the CONTEXT, so the parked
            # blocks alias straight back (fresh request: context == prompt)
            ctx = req.context
            resumed = req.preemptions > 0 or req.retries > 0
            req.prefill_len = req.context_len
            hit_len, entry = 0, None
            if self.prefix_cache is not None:
                # every admission is one lookup — the cache's hit_rate and
                # the serving tool's per-admission hit_rate share the same
                # denominator by construction
                hit_len, entry = self.prefix_cache.lookup(ctx)
            req.cached_tokens = hit_len
            req.prefix_kv = entry
            recovered = hit_len // self.block_size \
                if resumed and self.paged else 0
            recomputed = (req.prefill_len - hit_len) if resumed else 0
            if resumed:
                self.recovered_tokens += hit_len
                self.recovered_blocks += recovered
                self.recomputed_tokens += recomputed
            self._req_handler(req).operator_start(
                "serve.request.admit", rid=req.rid, slot=req.slot,
                prompt_len=req.prefill_len, cached_tokens=hit_len,
                queue_s=req.admit_time - req.submit_time,
                resumed=resumed, recovered_blocks=recovered,
                recomputed_tokens=recomputed)
            if self.paged:
                self._bind_paged(req, hit_len, entry)
                req.prefix_kv = None
                if hit_len == 0 and self.prefill_chunk is None:
                    cold_group.append(req)      # grouped dense fast path
                else:
                    # hits append their suffix; with chunking on, EVERY
                    # prefill goes through the budgeted append path so the
                    # per-tick bound holds fleet-wide
                    self._prefilling.append(req)
            elif hit_len == 0 and self.cfg.family in _KV_ONLY:
                cold_group.append(req)
            else:
                self._prefill_unit([req], new_tokens, finished)
        if cold_group:
            self._prefill_unit(cold_group, new_tokens, finished)
        # chunked prefill: one shared FCFS token budget per tick — the total
        # prefill work between two fused decodes never exceeds one chunk.
        # interleave="decode" zeroes the budget whenever any slot can
        # decode: chunk work only runs on decode-idle ticks (max_new_tokens
        # bounds every decode tail, so deferral is starvation-free)
        budget = self.prefill_chunk
        if budget is not None and self.degrade_level >= 2:
            # shedding level 2: halve the per-tick prefill budget (floor
            # one block) — decode latency wins over admission ramp
            budget = max(self.block_size, budget // 2)
        if self.interleave == "decode" and self._prefilling \
                and self._decode_actives():
            budget = 0
        for req in list(self._prefilling):
            if budget is not None and budget <= 0:
                break
            budget_used = self._append_chunk(req, new_tokens, finished,
                                             budget)
            if budget is not None:
                budget -= budget_used
        if self.spec_k and self.degrade_level < 1:
            self._spec_decode_step(new_tokens, finished)
        else:
            # shedding level 1 parks speculation: plain one-token decode
            self._decode_step(new_tokens, finished)
        if self.policy is not None and new_tokens:
            # committed-token feedback (fair-share weights, etc.)
            for rid, _ in new_tokens:
                r = self.requests.get(rid)
                if r is not None:
                    self.policy.note_tokens(r)

    # -------------------------------------------------------------- prefill
    def _publish(self, req: Request) -> None:
        """Publish the finished prefill's K/V for reuse.  Paged: retain the
        slot's own blocks under block-aligned store keys of the admission
        context (zero bytes moved; the context is the prompt on a fresh
        admission, prompt + committed tokens on a resumed one).  Legacy:
        one blocking device->host extract per new prompt (counted in
        ``duplicate_copy_bytes``)."""
        if self.prefix_cache is None:
            return
        if self.paged:
            self.prefix_cache.insert_blocks(
                req.context, self.pool.tables[req.slot],
                on_retain=lambda ids: self.pool.retain(ids, store=True))
            return
        if self.prefix_cache.covers(req.prompt):
            return
        kv = self.pool.extract_kv(req.slot, req.prompt_len)
        self.duplicate_copy_bytes += kv["k"].nbytes + kv["v"].nbytes
        self.prefix_cache.insert(req.prompt, kv)

    def _first_token(self, req: Request, logits_row, new_tokens: list,
                     finished: list) -> None:
        """Sample the context's continuation once prefill completes.  On a
        resumed admission this is NOT the request's first token — the
        sampling position is ``len(req.tokens)``, exactly the position the
        unpreempted run would sample next, so preemption never changes a
        token — and the TTFT clock/event stays with the true first."""
        tok = self._sample_one(req, logits_row)
        req.tokens.append(tok)
        self.last_tokens[req.slot] = tok
        new_tokens.append((req.rid, tok))
        if not req.first_token_time:
            req.first_token_time = time.perf_counter()
            self._req_handler(req).operator_start(
                "serve.request.first_token", rid=req.rid,
                ttft_s=req.first_token_time - req.submit_time)
        if req.done:
            self._retire(req, finished)

    def _prefill_unit(self, reqs: list, new_tokens: list,
                      finished: list) -> None:
        """Prefill one admission unit: a right-padded cold group (KV-only
        families) or a single request (legacy prefix hit / SSM / hybrid /
        MoE)."""
        if self.faults is not None:
            # before the event span opens and before any device dispatch:
            # an abandoned tick leaves balanced events and untouched state
            self.faults.check_tick(self.ticks, [r.rid for r in reqs])
        hit = len(reqs) == 1 and reqs[0].cached_tokens > 0
        self.handler.operator_start(
            "serve.prefill",
            rids=tuple(r.rid for r in reqs),
            slots=tuple(r.slot for r in reqs),
            n_tokens=int(sum(r.prefill_len - r.cached_tokens
                             for r in reqs)),
            cached=int(sum(r.cached_tokens for r in reqs)),
            group=len(reqs), chunked=False)
        copied_before = self.duplicate_copy_bytes
        if hit:
            req = reqs[0]
            suffix = req.prompt[req.cached_tokens:]
            # right-pad the suffix to a pow2 bucket too (bounds recompiles;
            # causality keeps the pad exact) — capped so the append window
            # stays inside max_seq, else dynamic_update_slice would clamp
            # the start and misalign the writes
            n = len(suffix)
            s_pad = min(bucket(n), self.max_seq - req.cached_tokens)
            toks = np.zeros((1, s_pad), np.int32)
            toks[0, :n] = suffix
            cache = self.pool.seeded_prefill_cache(req.prefix_kv)
            logits, cache = self._prefill_suffix(
                self.params, cache, jnp.asarray(toks),
                jnp.asarray([n - 1], np.int32))
        else:
            # ragged group: right-pad to a power-of-two bucket CAPPED at the
            # pool bound (a non-pow2 max_seq must not compile positions the
            # pool can never hold); causality makes the pad exact for
            # attention (masked KV), so per-row results match solo prefill.
            # SSM/hybrid/MoE units are single requests prefilled at EXACT
            # length — a pad token would update the carried SSM state
            # (input-dependent dt) / MoE routing.
            pow2 = self.cfg.family in _KV_ONLY
            toks, lens = pad_group([r.context for r in reqs], pow2=pow2,
                                   max_len=self.max_seq if pow2 else None)
            logits, cache = self._prefill_cold(
                self.params, jnp.asarray(toks), jnp.asarray(lens - 1))
        logits = np.asarray(logits)
        for row, req in enumerate(reqs):
            if self.paged:
                self.pool.insert_prefill(cache, req.slot, row)
            else:
                self.pool.insert(cache, req.slot, row, req.prefill_len)
            req.progress = req.prefill_len
            self._publish(req)
            req.prefix_kv = None
        self.handler.operator_end(
            "serve.prefill", rids=tuple(r.rid for r in reqs),
            copied_bytes=self.duplicate_copy_bytes - copied_before)
        for row, req in enumerate(list(reqs)):
            self._first_token(req, logits[row], new_tokens, finished)

    def _append_chunk(self, req: Request, new_tokens: list, finished: list,
                      budget: int | None = None) -> int:
        """Advance one mid-prefill paged request by one chunk (at most
        ``budget`` tokens): scatter the chunk's K/V through the slot's block
        table (per-query causal masking keeps multi-token appends exact)
        and, on the final chunk, sample the first token and publish the
        prompt's blocks.  Returns the tokens prefilled."""
        if self.faults is not None:
            self.faults.check_tick(self.ticks, [req.rid])
        remaining = req.prefill_len - req.progress
        chunk = remaining if budget is None else min(budget, remaining)
        span = self.pool.blocks_per_seq * self.pool.block_size
        s_pad = min(bucket(chunk), span - req.progress)
        toks = np.zeros((1, s_pad), np.int32)
        ctx = req.context
        toks[0, :chunk] = ctx[req.progress:req.progress + chunk]
        first_chunk = req.progress == req.cached_tokens
        self.handler.operator_start(
            "serve.prefill", rids=(req.rid,), slots=(req.slot,),
            n_tokens=chunk, cached=req.cached_tokens if first_chunk else 0,
            group=1, chunked=self.prefill_chunk is not None,
            base=req.progress)
        cache = self.pool.cache_view(np.asarray([req.progress], np.int32),
                                     rows=[req.slot])
        logits, cache = self._prefill_suffix(
            self.params, cache, jnp.asarray(toks),
            jnp.asarray([chunk - 1], np.int32))
        self.pool.adopt(cache)
        req.progress += chunk
        self.handler.operator_end("serve.prefill", rids=(req.rid,),
                                  copied_bytes=0)
        if req.prefilled:
            self._prefilling.remove(req)
            self._publish(req)
            self._first_token(req, np.asarray(logits)[0], new_tokens,
                              finished)
        return chunk

    # --------------------------------------------------------------- decode
    def _decode_actives(self) -> dict:
        """Slots eligible for the fused decode: fully prefilled, first token
        sampled (mid-prefill rows ride along masked)."""
        return {slot: req
                for slot, req in sorted(self.sched.running.items())
                if req.prefilled and req.tokens}

    def _kv_read_bytes(self, lens, s: int) -> int:
        """Analytic KV traffic of one fused decode/verify dispatch: every
        active row streams its whole live KV window (plus the ``s`` appended
        positions) once — block-granular in paged mode, since a partially
        filled block is still a whole block off the device memory bus."""
        cfg = self.cfg
        per_pos = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim \
            * jnp.dtype(cfg.dtype).itemsize
        total = 0
        for ln in lens:
            touched = ln + s
            if self.paged:
                touched = self.pool.blocks_for(touched) * self.pool.block_size
            total += touched * per_pos
        return total

    def _decode_pool_attrs(self) -> dict:
        if not self.paged:
            return {}
        st = self.pool.stats()
        return {"blocks_used": st["blocks_used"],
                "n_blocks": st["n_blocks"],
                "store_blocks": st["store_blocks"],
                "utilization": st["utilization"]}

    def _decode_step(self, new_tokens: list, finished: list) -> None:
        """One fused decode over every fully-prefilled slot (free and
        mid-prefill slots ride along as masked no-ops; their stale bytes
        never enter any softmax and their writes drop)."""
        active = self._decode_actives()
        if not active:
            return
        if self.faults is not None:
            self.faults.check_tick(self.ticks,
                                   [r.rid for r in active.values()])
        self.decode_steps += 1
        self.handler.operator_start(
            "serve.decode", step=self.decode_steps, active=len(active),
            slots=self.pool.slots, queued=self.sched.n_queued,
            rids=tuple(r.rid for r in active.values()),
            **self._decode_pool_attrs())
        base = {slot: req.prompt_len + len(req.tokens) - 1
                for slot, req in active.items()}
        if self.paged:
            span = self.pool.blocks_per_seq * self.pool.block_size
            # rows without a decodable request park at length == span: their
            # K/V writes resolve to the sentinel block and drop
            lengths = np.full((self.pool.slots,), span, np.int32)
            for slot, req in active.items():
                lengths[slot] = base[slot]
                self._grow_slot(req, base[slot] + 1)
            cache = self.pool.cache_view(lengths)
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(self.last_tokens[:, None]))
            self.pool.adopt(cache)
        else:
            logits, self.pool.cache = self._decode(
                self.params, self.pool.cache,
                jnp.asarray(self.last_tokens[:, None]))
        logits = np.asarray(logits)
        logits, bad = self._blame_nonfinite(active, logits)
        for slot in bad:
            del active[slot]                    # blamed rows sample nothing
        for slot, req in active.items():
            tok = self._sample_one(req, logits[slot])
            req.tokens.append(tok)
            self.last_tokens[slot] = tok
            new_tokens.append((req.rid, tok))
        self.handler.operator_end(
            "serve.decode", step=self.decode_steps, active=len(active),
            committed=len(active), params_bytes=self.params_bytes,
            kv_read_bytes=self._kv_read_bytes(base.values(), 1))
        for req in list(active.values()):
            if req.done:
                self._retire(req, finished)

    def _spec_decode_step(self, new_tokens: list, finished: list) -> None:
        """Propose → fused verify → accept/rollback, one tick.

        Each active slot's verify row is ``[last_token, d_1..d_k]``
        (zero-padded past its draft).  The fused target forward appends all
        ``k+1`` positions through the per-query-causal cache path and
        returns logits at every one; per slot the accept loop then replays
        sequential decoding exactly: sample from position ``s`` (same
        argmax / same position-keyed PRNG draw the plain path would make),
        commit, and continue only while the sampled token matches draft
        ``d_{s+1}`` — so output is byte-identical to non-speculative decode
        and a fully-accepted draft commits ``k+1`` tokens (bonus token) in
        one dispatch.  Rejected suffix KV stays as dead bytes above the
        committed length (overwritten by the next append, never read);
        paged slots also roll their block tables back so draft-spill blocks
        return to the pool."""
        active = self._decode_actives()
        if not active:
            return
        if self.faults is not None:
            self.faults.check_tick(self.ticks,
                                   [r.rid for r in active.values()])
        k = self.spec_k
        t_draft = time.perf_counter()
        drafts = self.proposer.propose(
            [np.concatenate([req.prompt,
                             np.asarray(req.tokens, np.int32)])
             for req in active.values()], k)
        draft_s = time.perf_counter() - t_draft
        span = (self.pool.blocks_per_seq * self.pool.block_size
                if self.paged else self.pool.max_seq)
        toks = np.zeros((self.pool.slots, k + 1), np.int32)
        lengths = np.full((self.pool.slots,), span, np.int32)
        dlen = {}
        for (slot, req), d in zip(active.items(), drafts):
            d = np.asarray(d, np.int32)[:k]
            dlen[slot] = len(d)
            toks[slot, 0] = self.last_tokens[slot]
            toks[slot, 1:1 + len(d)] = d
            lengths[slot] = req.prompt_len + len(req.tokens) - 1
            if self.paged:
                self._grow_slot(req, min(int(lengths[slot]) + k + 1, span))
        self.decode_steps += 1
        n_drafted = sum(dlen.values())
        self.handler.operator_start(
            "serve.decode", step=self.decode_steps, active=len(active),
            slots=self.pool.slots, queued=self.sched.n_queued,
            rids=tuple(r.rid for r in active.values()), spec_k=k,
            drafted=n_drafted, **self._decode_pool_attrs())
        if self.paged:
            cache = self.pool.cache_view(lengths)
            logits, cache = self._verify(self.params, cache,
                                         jnp.asarray(toks),
                                         self._verify_idx_dev)
            self.pool.adopt(cache)
        else:
            # the device length leaf is not authoritative in spec mode (a
            # rollback never rewrites it); rebuild the mask lengths from
            # committed host state every tick, parking idle rows at max_seq
            kv = self.pool.cache["kv"]
            cache = dict(self.pool.cache,
                         kv=dict(kv, length=jnp.asarray(lengths)))
            logits, self.pool.cache = self._verify(self.params, cache,
                                                   jnp.asarray(toks),
                                                   self._verify_idx_dev)
        logits = np.asarray(logits)
        pairs = list(zip(list(active.items()), drafts))
        logits, bad = self._blame_nonfinite(active, logits)
        for slot in bad:
            del active[slot]                    # blamed rows commit nothing
        accepted = committed = 0
        for (slot, req), d in pairs:
            if slot not in active:
                continue
            len0 = len(req.tokens)
            s = 0
            while True:
                tok = self._sample_one(req, logits[slot, s],
                                       position=len0 + s)
                req.tokens.append(tok)
                new_tokens.append((req.rid, tok))
                committed += 1
                if req.done or s >= dlen[slot] or int(toks[slot, s + 1]) != tok:
                    break
                accepted += 1
                s += 1
            req.drafted += dlen[slot]
            req.accepted += s
            self.last_tokens[slot] = req.tokens[-1]
            if self.paged and not req.done:
                # rollback: keep blocks through the next write position
                # (committed prefix + the pending last token), release the
                # rejected draft spill back to the pool
                freed = self.pool.truncate(
                    req.slot, req.prompt_len + len(req.tokens))
                if freed:
                    self._owed[req.rid] = self._owed.get(req.rid, 0) + freed
        self.drafted_tokens += n_drafted
        self.accepted_tokens += accepted
        self.handler.operator_end(
            "serve.decode", step=self.decode_steps, active=len(active),
            spec_k=k, drafted=n_drafted, accepted=accepted,
            committed=committed, draft_s=draft_s,
            params_bytes=self.params_bytes,
            kv_read_bytes=self._kv_read_bytes(
                [int(lengths[s]) for s in active], k + 1))
        for req in list(active.values()):
            if req.done:
                self._retire(req, finished)

    # --------------------------------------------------------------- retire
    def _retire(self, req: Request, finished: list) -> None:
        n = len(req.tokens)
        if self.paged:
            self.pool.free_slot(req.slot)
        self._owed.pop(req.rid, None)
        self.sched.release(req)
        self._req_handler(req).operator_start(
            "serve.request.finish", rid=req.rid, n_tokens=n,
            ttft_s=req.first_token_time - req.submit_time,
            total_s=req.finish_time - req.submit_time,
            drafted=req.drafted, accepted=req.accepted,
            preemptions=req.preemptions)
        if req.session is not None:
            if self.request_tools:
                self.request_reports.append(req.session.reports())
            req.session.close()
            req.session = None
        finished.append(req.rid)
        self._retired.append(req.rid)
        while len(self._retired) > self.max_retained_requests:
            self.requests.pop(self._retired.popleft(), None)

    def preempt(self, rid: int) -> bool:
        """Evict-and-requeue a RUNNING request without losing its work:
        park its committed KV blocks in the prefix store (refcount holds,
        zero bytes copied), free the slot, and put it back at the front of
        the waiting queue.  Re-admission looks up the request's CONTEXT
        (prompt + committed tokens), aliases the parked blocks straight
        back, and resumes sampling at the exact position the unpreempted
        run would use — output is byte-identical, recompute is bounded to
        the sub-block tail.  Preemptive policies call this through
        :meth:`step`; it is also a public knob (e.g. manual load
        shedding).  Paged mode only.  Returns False for requests not
        currently RUNNING."""
        req = self.requests.get(rid)
        if req is None or req.state is not RequestState.RUNNING:
            return False
        if not self.paged:
            raise ValueError(
                "preemption parks KV in the prefix store, which needs the "
                "paged pool (non-paged recurrent state cannot re-alias)")
        self._preempt(req)
        return True

    def _preempt(self, req: Request, reason: str = "policy") -> None:
        # cached KV covers `progress` positions mid-prefill; once decoding,
        # it covers context_len - 1 (the newest sampled token is pending in
        # last_tokens — its KV is written by the NEXT decode dispatch)
        kv_len = req.progress if not req.prefilled else req.context_len - 1
        parked = 0
        if self.prefix_cache is not None and kv_len >= self.block_size:
            self.prefix_cache.insert_blocks(
                req.context[:kv_len], self.pool.tables[req.slot],
                on_retain=lambda ids: self.pool.retain(ids, store=True))
            parked = kv_len // self.block_size
        self.pool.free_slot(req.slot)           # store refs keep parked KV
        if req in self._prefilling:
            self._prefilling.remove(req)
        self._owed.pop(req.rid, None)
        self.preemptions += 1
        self.parked_blocks += parked
        self._req_handler(req).operator_start(
            "serve.request.preempt", rid=req.rid, slot=req.slot,
            n_tokens=len(req.tokens), kv_len=kv_len, parked_blocks=parked,
            reason=reason)
        req.progress = 0
        req.cached_tokens = 0
        req.prefill_len = None
        req.prefix_kv = None
        self.sched.preempt(req)

    def _cancel(self, req: Request, state: RequestState, event: str,
                **attrs) -> None:
        """Shared terminal-cancellation path (abort / timeout / fail):
        release queue position or slot (and, paged, pool blocks), clear the
        owed reservation, emit the terminal event, close the child session,
        enter retirement bookkeeping.  Callers guarantee the request is
        live (queued, running, or in the retry-backoff pen)."""
        if req in self._backoff:
            self._backoff.remove(req)
            req.state = state
        elif req.state is RequestState.QUEUED:
            self.sched.remove_waiting(req, state=state)
        else:                                   # RUNNING: holds a slot
            if self.paged:
                self.pool.free_slot(req.slot)
            if req in self._prefilling:
                self._prefilling.remove(req)
            self.sched.release(req, state=state)
        self._owed.pop(req.rid, None)
        self._req_handler(req).operator_start(
            event, rid=req.rid, n_tokens=len(req.tokens), **attrs)
        if req.session is not None:
            req.session.close()
            req.session = None
        req.prefix_kv = None
        self._retired.append(req.rid)
        while len(self._retired) > self.max_retained_requests:
            self.requests.pop(self._retired.popleft(), None)

    def abort(self, rid: int) -> bool:
        """Cancel a request at any lifecycle stage: drop it from the queue
        or release its slot (and, paged, its pool blocks), close its child
        session.  Idempotent; returns False for unknown/already-final
        requests.  This is the error-path cleanup ``run``/``stream``/
        ``generate`` invoke when a tick raises mid-drain."""
        req = self.requests.get(rid)
        if req is None or req.state in _TERMINAL:
            return False
        self._cancel(req, RequestState.ABORTED, "serve.request.abort")
        return True

    def abort_all(self) -> int:
        """Abort every queued, running, and backoff request; returns the
        count."""
        live = [r.rid for r in list(self.sched.waiting)
                + list(self.sched.running.values()) + list(self._backoff)]
        return sum(self.abort(rid) for rid in live)

    # ------------------------------------------------------ fault recovery
    def _enforce_deadlines(self, now: float) -> None:
        """Expire every live request whose ``SLOSpec.deadline_s`` has
        elapsed since submission: status ``timeout``, slot + blocks + owed
        reservation released, child session closed."""
        live = list(self.sched.waiting) + list(self.sched.running.values()) \
            + list(self._backoff)
        for req in live:
            deadline = getattr(req.slo, "deadline_s", None) \
                if req.slo is not None else None
            if deadline is None:
                continue
            elapsed = now - req.submit_time
            if elapsed > deadline:
                self.timeouts += 1
                self._cancel(req, RequestState.TIMEOUT,
                             "serve.request.timeout", deadline_s=deadline,
                             elapsed_s=elapsed)

    def _readmit_backoff(self, now: float) -> None:
        """Move blamed requests whose backoff expired back to the FRONT of
        the waiting queue (they already waited their turn once)."""
        for req in list(self._backoff):
            if req.retry_at <= now:
                self._backoff.remove(req)
                self.sched.waiting.appendleft(req)

    def _retry_requeue(self, req: Request, now: float) -> None:
        """Blamed but retryable: drop the slot and every cached byte of
        work (the fault makes its KV suspect — unlike preemption, nothing
        is parked) and hold the request in the backoff pen.  Committed
        tokens are kept: position-keyed sampling makes the recomputed
        continuation byte-identical, so a retry can change latency but
        never output."""
        self.request_retries += 1
        if req.state is RequestState.RUNNING:
            if self.paged:
                self.pool.free_slot(req.slot)
            if req in self._prefilling:
                self._prefilling.remove(req)
            self.sched.vacate(req)
        self._owed.pop(req.rid, None)
        req.progress = 0
        req.cached_tokens = 0
        req.prefill_len = None
        req.prefix_kv = None
        backoff = min(self.retry_backoff_s * (2 ** (req.retries - 1)),
                      self.retry_backoff_cap_s)
        req.retry_at = now + backoff
        self._backoff.append(req)
        self._req_handler(req).operator_start(
            "serve.request.retry", rid=req.rid, retries=req.retries,
            backoff_s=backoff, n_tokens=len(req.tokens))

    def _fail(self, req: Request, reason: str) -> None:
        """Retries exhausted (or unretryable): terminal ``failed``."""
        self.failed_requests += 1
        self._cancel(req, RequestState.FAILED, "serve.request.failed",
                     reason=reason, retries=req.retries)

    def _blame(self, blamed: list, kind: str, probes: int = 0,
               isolate: bool = False) -> None:
        """Fault attribution resolved: each blamed request retries (bounded,
        backed off) or fails; with ``isolate`` every innocent runner is
        parked losslessly first — exactly the preemption path, so resumed
        outputs stay byte-identical and zero bytes are copied."""
        self.fault_ticks += 1
        now = time.perf_counter()
        blamed_rids = tuple(r.rid for r in blamed)
        retried, failed = [], []
        for req in blamed:
            req.retries += 1
            if req.retries > self.max_request_retries:
                self._fail(req, reason=kind)
                failed.append(req.rid)
            else:
                self._retry_requeue(req, now)
                retried.append(req.rid)
        isolated = []
        if isolate:
            for req in sorted(self.sched.running.values(),
                              key=lambda r: r.rid):
                isolated.append(req.rid)
                self._preempt(req, reason="fault")
            self.isolated_innocents += len(isolated)
        self.handler.operator_start(
            "serve.fault", tick=self.ticks, kind=kind, transient=False,
            blamed=blamed_rids, probes=probes, retried=tuple(retried),
            failed=tuple(failed), isolated=tuple(isolated))

    def _blame_nonfinite(self, active: dict, logits) -> list:
        """Row-attributable blame after a fused forward: inject armed NaN
        faults, then scan every active row for non-finite logits (injected
        or a genuine numeric blowup).  Blamed requests retry or fail on the
        spot; innocents keep the tick — no bisection, no tick abandonment.
        Returns ``(logits, blamed slots)`` — the caller drops blamed slots
        from the commit loop (logits may be a writable copy: np views of
        device arrays are read-only, and injection overwrites rows)."""
        if self.faults is not None:
            if not logits.flags.writeable:
                logits = logits.copy()
            self.faults.corrupt_logits(
                self.ticks,
                {req.rid: slot for slot, req in active.items()}, logits)
        bad = [slot for slot, req in active.items()
               if not np.isfinite(logits[slot]).all()]
        if bad:
            self._blame([active[s] for s in bad], kind="nan_logits",
                        isolate=False)
        return logits, bad

    def _bisect(self, cands: list) -> tuple:
        """Find the poisoned request(s) among ``cands`` by recursive
        halving against the plan's non-consuming :meth:`FaultPlan.probe`
        oracle — O(b log n) probes for b culprits instead of n replays."""
        bad: list = []
        probes = 0
        stack = [list(cands)]
        while stack:
            group = stack.pop()
            if not group:
                continue
            probes += 1
            if not self.faults.probe([r.rid for r in group]):
                continue
            if len(group) == 1:
                bad.append(group[0])
                continue
            mid = len(group) // 2
            stack.extend([group[mid:], group[:mid]])
        self.fault_probes += probes
        return sorted(bad, key=lambda r: r.rid), probes

    def _recover(self, exc: FaultInjected, out: dict) -> None:
        """An injected exception abandoned the tick.  Device state is safe
        to abandon: faults fire before the fused dispatch, and every KV
        write position derives from host-tracked lengths, so a resumed or
        retried dispatch overwrites the same positions identically.
        Attributable faults are blame-bisected (culprits retry or fail,
        innocents park losslessly); transient ones just retry the tick."""
        blamed: list = []
        probes = 0
        if exc.attributable:
            blamed, probes = self._bisect(
                sorted(self.sched.running.values(), key=lambda r: r.rid))
        if blamed:
            self._blame(blamed, exc.kind, probes=probes, isolate=True)
        else:
            self.fault_ticks += 1
            self.tick_retries += 1
            self.handler.operator_start(
                "serve.fault", tick=self.ticks, kind=exc.kind,
                transient=True, blamed=(), probes=probes, retried=(),
                failed=(), isolated=())

    def _update_degradation(self, tick_s: float) -> None:
        """Load-shedding ladder: on pool pressure (admission blocked with
        work queued) or a slow-tick streak (3x the rolling median, floored
        at ``slow_tick_s``), shed one level per pressured tick — spec
        decode off, prefill chunk halved, admissions rejected — and
        restore one level per 4 consecutive calm ticks."""
        self._tick_durs.append(tick_s)
        if not self.degrade_enabled:
            return
        slow = False
        if len(self._tick_durs) >= 5:
            med = float(np.median(self._tick_durs))
            slow = tick_s > max(self.slow_tick_s, 3.0 * med)
        self._slow_streak = self._slow_streak + 1 if slow else 0
        pooled = self._admission_blocked and bool(self.sched.waiting)
        pressure = pooled or self._slow_streak >= 2
        if pressure:
            self._calm_streak = 0
            if self.degrade_level < 3:
                self.degrade_level += 1
                self.handler.operator_start(
                    "serve.degrade", level=self.degrade_level,
                    direction="shed",
                    reason="pool_pressure" if pooled else "slow_ticks",
                    knob=_DEGRADE_KNOBS[self.degrade_level])
        else:
            self._calm_streak += 1
            if self.degrade_level > 0 and self._calm_streak >= 4:
                restored = self.degrade_level
                self.degrade_level -= 1
                self._calm_streak = 0
                self.handler.operator_start(
                    "serve.degrade", level=self.degrade_level,
                    direction="restore", reason="pressure_cleared",
                    knob=_DEGRADE_KNOBS[restored])
        if self.degrade_level:
            self.degraded_ticks += 1

    def health(self) -> dict:
        """Fault-tolerance counters for the engine's lifetime: every fault,
        retry, timeout, rejection, and degradation event is accounted for
        here (and mirrored in the ``serving`` tool's ``health`` section)."""
        return {
            "ticks": self.ticks,
            "fault_ticks": self.fault_ticks,
            "tick_retries": self.tick_retries,
            "request_retries": self.request_retries,
            "failed": self.failed_requests,
            "timeouts": self.timeouts,
            "rejections": self.rejections,
            "isolated_innocents": self.isolated_innocents,
            "probes": self.fault_probes,
            "host_preempt_signals": self.host_preempt_signals,
            "degrade_level": self.degrade_level,
            "degraded_ticks": self.degraded_ticks,
            "recovered_tokens": self.recovered_tokens,
            "recomputed_tokens": self.recomputed_tokens,
            "retry_backlog": len(self._backoff),
            "faults_fired": len(self.faults.fired) if self.faults else 0,
        }

    # ------------------------------------------------------------ high level
    def run(self, requests=()) -> dict:
        """Submit ``requests`` (prompts, or ``(prompt, SamplingParams)``
        pairs) and tick until all queued work drains.  Returns
        ``{rid: np.ndarray tokens}`` for the requests submitted here (or for
        everything drained, when called with no new requests).  Requests
        that end ``failed``/``timeout``/``rejected`` are simply absent from
        the result (their state lives in ``engine.requests[rid].state``).
        If a tick raises past the recovery layer, all live requests are
        aborted (slots, blocks and sessions released) before the error
        propagates."""
        rids = [self.submit(*self._split(r)) for r in requests]
        # tokens are snapshotted as requests retire — a drain larger than
        # max_retained_requests must not lose early results to pruning
        drained: dict = {}
        try:
            while self.has_work:
                for rid in self.step()["finished"]:
                    drained[rid] = np.asarray(self.requests[rid].tokens,
                                              np.int32)
        except Exception:
            self.abort_all()
            raise
        if rids:
            return {rid: drained[rid] for rid in rids if rid in drained}
        return drained

    def stream(self, requests=()):
        """Streaming iterator over ``(rid, token, done)`` triples, in the
        order tokens are produced across interleaved scheduler ticks."""
        for r in requests:
            self.submit(*self._split(r))
        try:
            while self.has_work:
                out = self.step()
                # a request can land 2 tokens in one tick (prefill + fused
                # decode); only its LAST token carries the done flag
                last = {rid: i
                        for i, (rid, _) in enumerate(out["new_tokens"])}
                done = set(out["finished"])
                for i, (rid, tok) in enumerate(out["new_tokens"]):
                    yield rid, tok, rid in done and last[rid] == i
        except Exception:
            self.abort_all()
            raise

    @staticmethod
    def _split(r):
        if isinstance(r, tuple) and len(r) == 2 \
                and isinstance(r[1], SamplingParams):
            return r
        return r, None

    # ------------------------------------------------------- deprecated shim
    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 temperature: float = 0.0) -> np.ndarray:
        """Deprecated one-shot API: prompts (B, S) -> (B, max_new_tokens).

        Thin shim over ``submit``/``step`` keeping the legacy observability
        contract: the whole call runs inside ONE child session (per-call,
        not per-request), whose reports land in ``request_reports``."""
        warnings.warn(
            "ServeEngine.generate() is deprecated; use the request-"
            "lifecycle API — engine.submit(prompt, SamplingParams(...)) + "
            "engine.step()/run()/stream()",
            DeprecationWarning, stacklevel=2)
        prompts = np.asarray(prompts)
        if self._handler is not None:
            # legacy pinned-handler path: emit directly, no child session
            return self._generate_batch(prompts, max_new_tokens, temperature)
        parent = self.session or pasta.current_session()
        cid = next(self._call_ids)
        with parent.child(tools=self.request_tools or (),
                          name=f"{parent.name}/request{cid}") as call:
            prev = self._route_handler
            self._route_handler = call.handler
            try:
                out = self._generate_batch(prompts, max_new_tokens,
                                           temperature)
            finally:
                self._route_handler = prev
        if self.request_tools:
            self.request_reports.append(call.reports())
        call.close()       # drop the per-call pipeline (reports kept)
        return out

    def _generate_batch(self, prompts, max_new_tokens: int,
                        temperature: float) -> np.ndarray:
        prev = self._per_request_sessions
        self._per_request_sessions = False
        try:
            params = SamplingParams(max_new_tokens=max_new_tokens,
                                    temperature=temperature)
            rids = [self.submit(p, params) for p in prompts]
            done: dict = {}
            while self.has_work:
                for rid in self.step()["finished"]:
                    done[rid] = np.asarray(self.requests[rid].tokens,
                                           np.int32)
        except Exception:
            self.abort_all()
            raise
        finally:
            self._per_request_sessions = prev
        return np.stack([done[r] for r in rids])
