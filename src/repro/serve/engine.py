"""Request-lifecycle serving engine: continuous batching over a KV slot pool.

The engine is a scheduler tick loop, not a one-shot call::

    engine = ServeEngine(cfg, params, max_seq=256, max_slots=4)
    rid = engine.submit(prompt, SamplingParams(max_new_tokens=32))
    while engine.step()["working"]:
        ...                                    # or: engine.run(requests)

One :meth:`step` is one scheduler tick: admit waiting requests FCFS into
free KV slots and prefill them (cold requests grouped with right-padding;
prompts whose prefix matches the hash-keyed :class:`~repro.serve.cache.
PrefixCache` skip the cached tokens and prefill only the suffix), then one
fused decode step over *all* active slots (each row appends at its own
length — see the per-row scatter in ``models.layers.attention``), then
retire finished requests.  Heterogeneous traffic therefore shares every
decode dispatch, and batch occupancy/goodput become measurable quantities
instead of a fixed batch dimension.

PASTA instrumentation is per request *across interleaved steps*: each
submitted request opens a child :class:`~repro.core.Session` of the engine's
session at submit time and closes it at retirement, so its lifecycle events
(``serve.request.submit/admit/first_token/finish``) and any per-request tool
reports span queueing, prefill, and every fused decode tick it participated
in, while the parent session aggregates the fleet view (the registered
``serving`` tool turns those events into TTFT/TPOT, occupancy timeline, and
prefix-hit-rate reports).

``generate(prompts)`` survives as a deprecated shim over ``submit``/``run``
with the legacy observability contract (one child session per *call*).
"""

from __future__ import annotations

import functools
import itertools
import time
import warnings

import collections

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pasta
from repro.models import forward
from repro.models.config import ModelConfig
from .cache import KVSlotPool, PrefixCache, bucket
from .scheduler import Request, SamplingParams, Scheduler, pad_group

#: families whose decode state is attention KV only — eligible for padded
#: group prefill and prefix-cache reuse.  SSM/hybrid state summarizes the
#: whole prefix nonlinearly (a pad token would mutate it, unlike masked KV)
#: and MoE routing couples tokens, so those prefill alone at exact length.
#: vlm/audio would qualify if tokenized, but their configs are
#: embedding-frontend stubs with no autoregressive token loop to serve.
_KV_ONLY = ("dense",)


def _pad_cache_to(cache: dict, cfg: ModelConfig, max_seq: int) -> dict:
    """Grow a prefill KV cache's sequence dim to ``max_seq`` slots."""
    if "kv" not in cache:
        return cache
    kv = cache["kv"]
    cur = kv["k"].shape[2]
    if cur >= max_seq:
        return cache
    pad = max_seq - cur

    def grow(x):
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, pad)
        return jnp.pad(x, widths)

    cache = dict(cache)
    cache["kv"] = {"k": grow(kv["k"]), "v": grow(kv["v"]),
                   "length": kv["length"]}
    return cache


class ServeEngine:
    """Continuous-batching generation engine over the unified LM."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 512,
                 max_slots: int = 8, handler=None,
                 session: "pasta.Session | None" = None,
                 rng_seed: int = 0, request_tools=None,
                 max_request_reports: int = 64, prefix_cache: bool = True,
                 prefix_block: int = 16, max_retained_requests: int = 4096):
        """``max_slots``: concurrent requests the KV pool holds; waiting
        requests queue FCFS.  ``session``: parent Session for per-request
        child sessions (innermost active session when omitted).
        ``request_tools``: tool spec instantiated fresh for every request's
        child session; reports land in ``request_reports`` at retirement.
        ``handler``: legacy pinned event sink — disables per-request
        sessions (compat path).  ``prefix_cache``: hash-keyed prompt-prefix
        reuse (KV-only families; block-aligned keys of ``prefix_block``)."""
        if cfg.frontend != "none":
            raise NotImplementedError(
                "ServeEngine decodes token ids; embedding-frontend archs "
                "have no autoregressive token loop to serve")
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.session = session
        self._handler = handler
        self._route_handler = None        # legacy generate(): pin to the
        self._per_request_sessions = True  # per-call child session
        self.request_tools = request_tools
        self.request_reports: collections.deque = collections.deque(
            maxlen=max_request_reports)
        self._req_ids = itertools.count()
        self._call_ids = itertools.count()   # legacy generate() child names
        self._rng_seed = rng_seed
        self.requests: dict = {}             # rid -> Request
        # long-lived engines must not grow host memory with traffic served:
        # retired Requests (prompt + tokens) are pruned FIFO beyond this
        # bound (live requests are never pruned; the floor keeps one tick's
        # worth of retirements readable for run()/stream() collection)
        self.max_retained_requests = max(max_retained_requests, max_slots)
        self._retired: collections.deque = collections.deque()
        self.sched = Scheduler(max_slots)
        self.pool = KVSlotPool(cfg, max_slots, max_seq)
        self.prefix_cache = (PrefixCache(block=prefix_block)
                             if prefix_cache and cfg.family in _KV_ONLY
                             else None)
        self.last_tokens = np.zeros((max_slots,), np.int32)
        self.decode_steps = 0
        self._prefill_cold = jax.jit(
            functools.partial(self._prefill_cold_impl, cfg))
        self._prefill_suffix = jax.jit(
            functools.partial(self._prefill_suffix_impl, cfg),
            donate_argnums=(1,))
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg),
                               donate_argnums=(1,))

    # ------------------------------------------------------------- jit impls
    @staticmethod
    def _prefill_cold_impl(cfg, params, tokens, last_idx):
        logits, cache = forward(params, tokens, cfg, return_cache=True,
                                logits_mode="index", logits_index=last_idx)
        return logits[:, 0, :], cache

    @staticmethod
    def _prefill_suffix_impl(cfg, params, cache, tokens, last_idx):
        logits, cache = forward(params, tokens, cfg, cache=cache,
                                logits_mode="index", logits_index=last_idx)
        return logits[:, 0, :], cache

    @staticmethod
    def _decode_impl(cfg, params, cache, tokens):
        logits, cache = forward(params, tokens, cfg, cache=cache,
                                logits_mode="last")
        return logits[:, -1, :], cache

    # -------------------------------------------------------------- plumbing
    @property
    def handler(self):
        """The engine's fleet-level event sink: the legacy generate() route,
        the pinned legacy handler, the parent session's handler, or the
        innermost active session's."""
        if self._route_handler is not None:
            return self._route_handler
        if self._handler is not None:
            return self._handler
        if self.session is not None:
            return self.session.handler
        return pasta.current_handler()

    def _req_handler(self, req: Request):
        """Per-request events go through the request's child session (which
        forwards to the parent), or the engine sink when sessions are off."""
        if req.session is not None:
            return req.session.handler
        return self.handler

    def _sample_one(self, req: Request, logits_row: np.ndarray) -> int:
        if req.params.temperature <= 0:
            return int(np.argmax(logits_row))
        seed = req.params.seed
        key = jax.random.PRNGKey(self._rng_seed if seed is None else seed)
        if seed is None:
            key = jax.random.fold_in(key, req.rid)
        key = jax.random.fold_in(key, len(req.tokens))
        return int(jax.random.categorical(
            key, jnp.asarray(logits_row) / req.params.temperature))

    # ------------------------------------------------------------ submission
    def submit(self, prompt, params: SamplingParams | None = None) -> int:
        """Enqueue one generation request; returns its request id.  The
        request's child Session opens here and spans queueing, prefill, and
        every fused decode step until retirement."""
        params = params or SamplingParams()
        prompt = np.asarray(prompt, dtype=np.int32)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            raise ValueError("submit() takes ONE 1-D token prompt; use "
                             "run()/generate() for batches")
        if params.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.shape[0] + params.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new_tokens "
                f"({params.max_new_tokens}) exceeds max_seq={self.max_seq}")
        rid = next(self._req_ids)
        req = Request(rid=rid, prompt=prompt, params=params,
                      submit_time=time.perf_counter())
        if self._per_request_sessions and self._handler is None:
            parent = self.session or pasta.current_session()
            req.session = parent.child(
                tools=self.request_tools or (),
                name=f"{parent.name}/request{rid}")
        self.requests[rid] = req
        self.sched.submit(req)
        self._req_handler(req).operator_start(
            "serve.request.submit", rid=rid, prompt_len=req.prompt_len,
            max_new_tokens=params.max_new_tokens)
        return rid

    # ------------------------------------------------------------------ tick
    def step(self) -> dict:
        """One scheduler tick: admit+prefill into free slots, one fused
        decode over all active slots, retire finished requests.  Returns
        ``{"admitted","finished","new_tokens","active","queued","working"}``.
        """
        admitted = self.sched.admit()
        new_tokens: list = []
        finished: list = []
        cold_group: list = []
        for req in admitted:
            hit_len, entry = 0, None
            if self.prefix_cache is not None and req.prompt_len > 1:
                hit_len, entry = self.prefix_cache.lookup(req.prompt)
            req.cached_tokens = hit_len
            req.prefix_kv = entry
            self._req_handler(req).operator_start(
                "serve.request.admit", rid=req.rid, slot=req.slot,
                prompt_len=req.prompt_len, cached_tokens=hit_len,
                queue_s=req.admit_time - req.submit_time)
            if hit_len == 0 and self.cfg.family in _KV_ONLY:
                cold_group.append(req)
            else:
                self._prefill_unit([req], new_tokens, finished)
        if cold_group:
            self._prefill_unit(cold_group, new_tokens, finished)
        if self.sched.running:
            self._decode_step(new_tokens, finished)
        return {
            "admitted": [r.rid for r in admitted],
            "finished": finished,
            "new_tokens": new_tokens,
            "active": self.sched.n_active,
            "queued": self.sched.n_queued,
            "working": self.sched.has_work,
        }

    def _prefill_unit(self, reqs: list, new_tokens: list,
                      finished: list) -> None:
        """Prefill one admission unit: a right-padded cold group (KV-only
        families) or a single request (prefix hit / SSM / hybrid / MoE)."""
        hit = len(reqs) == 1 and reqs[0].cached_tokens > 0
        self.handler.operator_start(
            "serve.prefill",
            rids=tuple(r.rid for r in reqs),
            slots=tuple(r.slot for r in reqs),
            n_tokens=int(sum(r.prompt_len - r.cached_tokens for r in reqs)),
            cached=int(sum(r.cached_tokens for r in reqs)),
            group=len(reqs))
        if hit:
            req = reqs[0]
            suffix = req.prompt[req.cached_tokens:]
            # right-pad the suffix to a pow2 bucket too (bounds recompiles;
            # causality keeps the pad exact) — capped so the append window
            # stays inside max_seq, else dynamic_update_slice would clamp
            # the start and misalign the writes
            n = len(suffix)
            s_pad = min(bucket(n), self.max_seq - req.cached_tokens)
            toks = np.zeros((1, s_pad), np.int32)
            toks[0, :n] = suffix
            cache = self.pool.seeded_prefill_cache(req.prefix_kv)
            logits, cache = self._prefill_suffix(
                self.params, cache, jnp.asarray(toks),
                jnp.asarray([n - 1], np.int32))
        else:
            # ragged group: right-pad to a power-of-two bucket; causality
            # makes the pad exact for attention (masked KV), so per-row
            # results match solo prefill.  SSM/hybrid/MoE units are single
            # requests prefilled at EXACT length — a pad token would update
            # the carried SSM state (input-dependent dt) / MoE routing.
            toks, lens = pad_group([r.prompt for r in reqs],
                                   pow2=self.cfg.family in _KV_ONLY)
            logits, cache = self._prefill_cold(
                self.params, jnp.asarray(toks), jnp.asarray(lens - 1))
        logits = np.asarray(logits)
        for row, req in enumerate(reqs):
            self.pool.insert(cache, req.slot, row, req.prompt_len)
            if self.prefix_cache is not None \
                    and not self.prefix_cache.covers(req.prompt):
                # publish prompt KV for reuse; skipped when this exact
                # prompt is already in the store (the extract is a blocking
                # device->host copy on the prefill critical path)
                self.prefix_cache.insert(
                    req.prompt, self.pool.extract_kv(req.slot,
                                                     req.prompt_len))
            req.prefix_kv = None
            tok = self._sample_one(req, logits[row])
            req.tokens.append(tok)
            req.first_token_time = time.perf_counter()
            self.last_tokens[req.slot] = tok
            new_tokens.append((req.rid, tok))
            self._req_handler(req).operator_start(
                "serve.request.first_token", rid=req.rid,
                ttft_s=req.first_token_time - req.submit_time)
        self.handler.operator_end(
            "serve.prefill", rids=tuple(r.rid for r in reqs))
        for req in list(reqs):
            if req.done:
                self._retire(req, finished)

    def _decode_step(self, new_tokens: list, finished: list) -> None:
        """One fused decode over every active slot (free slots ride along as
        masked no-ops; their stale bytes never enter any softmax)."""
        active = dict(sorted(self.sched.running.items()))
        self.decode_steps += 1
        self.handler.operator_start(
            "serve.decode", step=self.decode_steps, active=len(active),
            slots=self.pool.slots, queued=self.sched.n_queued,
            rids=tuple(r.rid for r in active.values()))
        logits, self.pool.cache = self._decode(
            self.params, self.pool.cache,
            jnp.asarray(self.last_tokens[:, None]))
        logits = np.asarray(logits)
        for slot, req in active.items():
            tok = self._sample_one(req, logits[slot])
            req.tokens.append(tok)
            self.last_tokens[slot] = tok
            new_tokens.append((req.rid, tok))
        self.handler.operator_end("serve.decode", step=self.decode_steps,
                                  active=len(active))
        for req in list(active.values()):
            if req.done:
                self._retire(req, finished)

    def _retire(self, req: Request, finished: list) -> None:
        n = len(req.tokens)
        self.sched.release(req)
        self._req_handler(req).operator_start(
            "serve.request.finish", rid=req.rid, n_tokens=n,
            ttft_s=req.first_token_time - req.submit_time,
            total_s=req.finish_time - req.submit_time)
        if req.session is not None:
            if self.request_tools:
                self.request_reports.append(req.session.reports())
            req.session.close()
            req.session = None
        finished.append(req.rid)
        self._retired.append(req.rid)
        while len(self._retired) > self.max_retained_requests:
            self.requests.pop(self._retired.popleft(), None)

    # ------------------------------------------------------------ high level
    def run(self, requests=()) -> dict:
        """Submit ``requests`` (prompts, or ``(prompt, SamplingParams)``
        pairs) and tick until all queued work drains.  Returns
        ``{rid: np.ndarray tokens}`` for the requests submitted here (or for
        everything drained, when called with no new requests)."""
        rids = [self.submit(*self._split(r)) for r in requests]
        # tokens are snapshotted as requests retire — a drain larger than
        # max_retained_requests must not lose early results to pruning
        drained: dict = {}
        while self.sched.has_work:
            for rid in self.step()["finished"]:
                drained[rid] = np.asarray(self.requests[rid].tokens,
                                          np.int32)
        if rids:
            return {rid: drained[rid] for rid in rids}
        return drained

    def stream(self, requests=()):
        """Streaming iterator over ``(rid, token, done)`` triples, in the
        order tokens are produced across interleaved scheduler ticks."""
        for r in requests:
            self.submit(*self._split(r))
        while self.sched.has_work:
            out = self.step()
            # a request can land 2 tokens in one tick (prefill + fused
            # decode); only its LAST token carries the done flag
            last = {rid: i for i, (rid, _) in enumerate(out["new_tokens"])}
            done = set(out["finished"])
            for i, (rid, tok) in enumerate(out["new_tokens"]):
                yield rid, tok, rid in done and last[rid] == i

    @staticmethod
    def _split(r):
        if isinstance(r, tuple) and len(r) == 2 \
                and isinstance(r[1], SamplingParams):
            return r
        return r, None

    # ------------------------------------------------------- deprecated shim
    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 temperature: float = 0.0) -> np.ndarray:
        """Deprecated one-shot API: prompts (B, S) -> (B, max_new_tokens).

        Thin shim over ``submit``/``step`` keeping the legacy observability
        contract: the whole call runs inside ONE child session (per-call,
        not per-request), whose reports land in ``request_reports``."""
        warnings.warn(
            "ServeEngine.generate() is deprecated; use the request-"
            "lifecycle API — engine.submit(prompt, SamplingParams(...)) + "
            "engine.step()/run()/stream()",
            DeprecationWarning, stacklevel=2)
        prompts = np.asarray(prompts)
        if self._handler is not None:
            # legacy pinned-handler path: emit directly, no child session
            return self._generate_batch(prompts, max_new_tokens, temperature)
        parent = self.session or pasta.current_session()
        cid = next(self._call_ids)
        with parent.child(tools=self.request_tools or (),
                          name=f"{parent.name}/request{cid}") as call:
            prev = self._route_handler
            self._route_handler = call.handler
            try:
                out = self._generate_batch(prompts, max_new_tokens,
                                           temperature)
            finally:
                self._route_handler = prev
        if self.request_tools:
            self.request_reports.append(call.reports())
        call.close()       # drop the per-call pipeline (reports kept)
        return out

    def _generate_batch(self, prompts, max_new_tokens: int,
                        temperature: float) -> np.ndarray:
        prev = self._per_request_sessions
        self._per_request_sessions = False
        try:
            params = SamplingParams(max_new_tokens=max_new_tokens,
                                    temperature=temperature)
            rids = [self.submit(p, params) for p in prompts]
            done: dict = {}
            while self.sched.has_work:
                for rid in self.step()["finished"]:
                    done[rid] = np.asarray(self.requests[rid].tokens,
                                           np.int32)
        finally:
            self._per_request_sessions = prev
        return np.stack([done[r] for r in rids])
