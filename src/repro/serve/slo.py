"""SLO specs and pluggable scheduling policies for the serving engine.

An :class:`SLOSpec` tags a request with its service-level objectives —
TTFT / TPOT targets in seconds — plus the tenant it belongs to and an
integer priority.  The engine threads the spec through the request
lifecycle events so the ``serving`` tool can report per-tenant SLO
attainment, goodput (tokens from SLO-meeting requests per wall second)
and Jain fairness, and the scheduler's :class:`SLOPolicy` uses it to
decide admission order and preemption victims.

Policies are deliberately tiny: a policy is an ordering ``key`` over the
waiting queue (stable-sorted, so equal keys keep arrival order) plus an
optional ``victims`` hook naming running requests to preempt when
higher-urgency work waits.  The engine owns the *mechanism* — parking a
victim's committed KV blocks in the prefix store and requeueing it so
re-admission aliases them back (see ``ServeEngine.preempt``) — the
policy only supplies the *decision*.

Built-ins:

=========== ======================================================
``fcfs``    arrival order, never preempts — byte-identical to the
            pre-policy scheduler (and the default)
``priority`` higher ``SLOSpec.priority`` first; preempts the
            youngest lowest-priority running request when a
            strictly higher-priority request waits with no free
            slot
``edf``     earliest TTFT deadline (``submit + ttft_target_s``)
            first; requests with no target sort last.  Preempts
            only victims that have not yet produced a first token
            (their TTFT is still at stake) for earlier deadlines
``fair``    tenants with the fewest served tokens first (the
            engine feeds committed-token counts back per tick);
            never preempts
=========== ======================================================
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Per-request service-level objectives + multi-tenant tags.

    Immutable — safe to share across every request of a tenant.  ``None``
    targets mean "no objective": the request trivially meets its SLO and
    sorts last under EDF.  ``deadline_s`` is HARD, not advisory: the
    engine cancels the request (status ``timeout``, resources released)
    once that many seconds elapse after submission."""

    ttft_target_s: float | None = None
    tpot_target_s: float | None = None
    tenant: str = "default"
    priority: int = 0
    deadline_s: float | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SLOSpec":
        return cls(**{k: d[k] for k in
                      ("ttft_target_s", "tpot_target_s", "tenant",
                       "priority", "deadline_s")
                      if k in d})


def _slo(req) -> SLOSpec:
    return req.slo if getattr(req, "slo", None) is not None else _DEFAULT


_DEFAULT = SLOSpec()


class SLOPolicy:
    """Admission-order + preemption policy.  Subclass and override
    :meth:`key` (waiting-queue sort key; stable sort — ties keep arrival
    order) and, for preemptive policies, :meth:`victims`."""

    name = "base"
    #: False skips the (stable) waiting-queue sort entirely — FCFS stays
    #: byte-identical to the policy-free scheduler
    orders = True
    #: the engine only calls :meth:`victims` when this is True (and only
    #: in paged mode, where preempted KV can be parked in the prefix store)
    preemptive = False

    def key(self, req, now: float):
        """Sort key for the waiting queue; smaller admits first."""
        return req.rid

    def victims(self, waiting, running, n_free: int, now: float) -> list:
        """Running requests to preempt this tick, given the waiting list,
        the ``slot -> request`` running map and the free-slot count.
        Called before admission; each victim is parked and requeued."""
        return []

    def note_tokens(self, req, n: int = 1) -> None:
        """Feedback hook: ``n`` tokens just committed for ``req``."""

    def __repr__(self):
        return f"{type(self).__name__}()"


class FCFSPolicy(SLOPolicy):
    """Arrival order, no preemption — the default, byte-identical to the
    policy-free scheduler."""

    name = "fcfs"
    orders = False


class PriorityPolicy(SLOPolicy):
    """Strict priority admission; optionally preempts the youngest
    lowest-priority running request when a strictly higher-priority
    request waits and no slot is free."""

    name = "priority"

    def __init__(self, preempt: bool = True):
        self.preemptive = preempt

    def key(self, req, now):
        return (-_slo(req).priority, req.rid)

    def victims(self, waiting, running, n_free, now):
        # highest-priority waiting first; candidate victims sorted lowest
        # priority first, youngest (largest rid) breaking ties so the
        # request with the least sunk work is evicted
        wait = sorted(waiting, key=lambda r: (-_slo(r).priority, r.rid))
        run = sorted(running.values(),
                     key=lambda r: (_slo(r).priority, -r.rid))
        out = []
        free = n_free
        for w in wait:
            if free > 0:
                free -= 1
                continue
            if run and _slo(run[0]).priority < _slo(w).priority:
                out.append(run.pop(0))
            else:
                break
        return out

    def __repr__(self):
        return f"PriorityPolicy(preempt={self.preemptive})"


def _deadline(req) -> float:
    t = _slo(req).ttft_target_s
    return req.submit_time + t if t is not None else math.inf


class EDFPolicy(SLOPolicy):
    """Earliest TTFT deadline first.  Preemption (on by default) only
    targets running requests that have not yet produced a first token —
    once TTFT is met, evicting the victim could no longer help any
    deadline it still has."""

    name = "edf"

    def __init__(self, preempt: bool = True):
        self.preemptive = preempt

    def key(self, req, now):
        return (_deadline(req), req.rid)

    def victims(self, waiting, running, n_free, now):
        wait = sorted(waiting, key=lambda r: (_deadline(r), r.rid))
        run = sorted((r for r in running.values() if not r.tokens),
                     key=lambda r: (-_deadline(r), r.rid))
        out = []
        free = n_free
        for w in wait:
            if free > 0:
                free -= 1
                continue
            if run and _deadline(run[0]) > _deadline(w):
                out.append(run.pop(0))
            else:
                break
        return out

    def __repr__(self):
        return f"EDFPolicy(preempt={self.preemptive})"


class FairSharePolicy(SLOPolicy):
    """Least-served tenant first: the waiting queue sorts by each
    tenant's lifetime committed tokens (the engine calls
    :meth:`note_tokens` per committed token), so a chatty tenant cannot
    starve a quiet one.  Non-preemptive."""

    name = "fair"

    def __init__(self):
        self.served: dict = {}

    def key(self, req, now):
        return (self.served.get(_slo(req).tenant, 0), req.rid)

    def note_tokens(self, req, n: int = 1):
        t = _slo(req).tenant
        self.served[t] = self.served.get(t, 0) + n

    def __repr__(self):
        return f"FairSharePolicy(served={self.served})"


POLICIES = {
    "fcfs": FCFSPolicy,
    "priority": PriorityPolicy,
    "edf": EDFPolicy,
    "fair": FairSharePolicy,
}


def get_policy(spec) -> SLOPolicy | None:
    """Resolve ``None`` | policy name | :class:`SLOPolicy` instance.
    Fresh instance per call — policies may carry state (fair share)."""
    if spec is None or isinstance(spec, SLOPolicy):
        return spec
    try:
        return POLICIES[spec]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {spec!r}; "
            f"known: {sorted(POLICIES)}") from None
