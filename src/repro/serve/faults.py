"""Deterministic chaos injection for the serving engine.

A :class:`FaultPlan` is a seeded, reproducible schedule of runtime faults
the engine volunteers to suffer: the same ``(preset, seed)`` always yields
the same faults at the same scheduler ticks against the same request ids,
so a chaos run is a replayable artifact exactly like a traffic trace — a
failure found under chaos reproduces under the same plan, and CI can
assert recovery properties (zero innocent loss, byte-identical resumed
outputs) instead of eyeballing flakes.

Fault kinds (each a :class:`FaultSpec`):

=============== ========================================================
``tick_error``  the tick raises before the fused dispatch — transient and
                attributable to no request; the engine retries the tick
``poison``      whenever the target request is live in a tick, the tick
                raises.  NOT row-attributable: the engine must *bisect*
                the live set (``FaultPlan.probe``) to find the culprit
``nan_logits``  the target request's logits row turns NaN after the fused
                forward — row-attributable, no bisection needed (and the
                same guard catches genuine numeric blowups)
``stall``       the tick sleeps ``stall_s`` for ``duration`` ticks — the
                slow-tick signal the degradation ladder sheds load on
``pressure``    ``blocks`` pool blocks are held back from admission for
                ``duration`` ticks — the pool-pressure degradation signal
``preempt``     a host-preemption signal: evict ``count`` running
                requests this tick (parked losslessly, like any victim)
=============== ========================================================

The engine consumes a plan through five hooks (``tick_stall_s`` /
``held_blocks`` / ``preempt_signals`` / ``check_tick`` /
``corrupt_logits``) plus ``probe`` during blame bisection.  ``check_tick``
*consumes* one ttl charge per armed fault per real tick; ``probe`` never
consumes — bisection replays the same tick's verdict as often as it needs.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class FaultInjected(RuntimeError):
    """An injected fault manifesting as a tick exception.

    ``attributable`` tells the recovery path whether blame bisection can
    find a culprit request (``poison``) or the whole tick is transient
    (``tick_error``).  The poisoned rid is deliberately NOT carried —
    recovery must earn it through :meth:`FaultPlan.probe`."""

    def __init__(self, kind: str, tick: int, attributable: bool):
        super().__init__(f"injected {kind} fault at tick {tick}")
        self.kind = kind
        self.tick = tick
        self.attributable = attributable


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.  ``tick`` is the 1-based engine tick the fault
    arms at (``None`` = armed from the start for request-targeted kinds);
    ``ttl`` is how many separate ticks a request-targeted fault fires
    before clearing (a large ttl ~ a deterministic hard fault)."""

    kind: str                      # tick_error|poison|nan_logits|stall|
    #                                pressure|preempt
    tick: int | None = None
    rid: int | None = None         # poison / nan_logits target
    ttl: int = 1
    duration: int = 1              # stall / pressure window width in ticks
    stall_s: float = 0.0
    blocks: int = 0                # pressure: blocks withheld
    count: int = 1                 # preempt: victims this tick

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


_REQUEST_KINDS = ("poison", "nan_logits")
_WINDOW_KINDS = ("stall", "pressure")


class FaultPlan:
    """A deterministic schedule of :class:`FaultSpec` plus runtime state
    (remaining ttls, what fired when).  One plan drives one engine run;
    build a fresh plan (same specs/seed) to replay the chaos exactly."""

    def __init__(self, specs=(), seed: int = 0, name: str = "custom"):
        self.specs = [dataclasses.replace(s) for s in specs]
        self.seed = seed
        self.name = name
        self._ttl = {id(s): s.ttl for s in self.specs}
        #: request-targeted specs that fired in the current tick — what
        #: :meth:`probe` answers from during blame bisection
        self._fired_now: list = []
        self._fired_tick = -1
        #: (tick, kind, rid) log of every manifested fault
        self.fired: list = []

    # ------------------------------------------------------------- presets
    @classmethod
    def preset(cls, name: str, seed: int = 0) -> "FaultPlan":
        """Named chaos scenarios.  All schedules derive from ``seed``
        alone, so a preset run replays bit-for-bit.

        * ``one-poison`` — one request is persistently poisoned: every
          tick it participates in raises, retries exhaust, it must end
          ``failed`` while every other request finishes byte-identically.
        * ``transient`` — one short-lived poison plus one tick error;
          everything recovers, nothing may be lost.
        * ``storm`` — transient poisons, a NaN row, tick errors, a stall
          window, a pressure window and a host-preemption signal; zero
          requests may be lost.
        * ``pressure`` — sustained pool pressure + stalls, no poisons:
          exercises the degradation ladder end to end.
        """
        if name not in _PRESET_SALT:
            raise ValueError(
                f"unknown chaos preset {name!r}; known: {sorted(PRESETS)}")
        rng = np.random.default_rng([seed, _PRESET_SALT[name]])
        t = lambda lo, hi: int(rng.integers(lo, hi))        # noqa: E731
        if name == "one-poison":
            specs = [FaultSpec(kind="poison", rid=t(0, 4), ttl=1_000_000)]
        elif name == "transient":
            specs = [FaultSpec(kind="poison", rid=t(0, 4), ttl=1),
                     FaultSpec(kind="tick_error", tick=t(3, 8))]
        elif name == "storm":
            r1 = t(0, 4)
            r2 = (r1 + 1 + t(0, 3)) % 8
            specs = [
                FaultSpec(kind="poison", rid=r1, ttl=1),
                FaultSpec(kind="nan_logits", rid=r2, ttl=1),
                FaultSpec(kind="tick_error", tick=t(2, 6)),
                FaultSpec(kind="tick_error", tick=t(10, 16)),
                FaultSpec(kind="stall", tick=t(4, 8), duration=3,
                          stall_s=0.08),
                FaultSpec(kind="pressure", tick=t(6, 10), duration=4,
                          blocks=2),
                FaultSpec(kind="preempt", tick=t(8, 12), count=1),
            ]
        elif name == "pressure":
            specs = [
                FaultSpec(kind="pressure", tick=t(2, 4), duration=8,
                          blocks=4),
                FaultSpec(kind="stall", tick=t(3, 6), duration=4,
                          stall_s=0.1),
                FaultSpec(kind="stall", tick=t(10, 13), duration=3,
                          stall_s=0.1),
            ]
        else:
            raise ValueError(
                f"unknown chaos preset {name!r}; known: {sorted(PRESETS)}")
        return cls(specs, seed=seed, name=name)

    # ------------------------------------------------------- tick-level hooks
    def _roll_tick(self, tick: int) -> None:
        if tick != self._fired_tick:
            self._fired_tick = tick
            self._fired_now = []

    def _armed(self, s: FaultSpec, tick: int) -> bool:
        if self._ttl[id(s)] <= 0:
            return False
        return s.tick is None or s.tick <= tick

    def _in_window(self, s: FaultSpec, tick: int) -> bool:
        return s.tick is not None and s.tick <= tick < s.tick + s.duration

    def tick_stall_s(self, tick: int) -> float:
        """Seconds this tick must stall (sum of open ``stall`` windows)."""
        total = 0.0
        for s in self.specs:
            if s.kind == "stall" and self._in_window(s, tick):
                total += s.stall_s
                self.fired.append((tick, "stall", None))
        return total

    def held_blocks(self, tick: int) -> int:
        """Pool blocks withheld from admission this tick (``pressure``)."""
        held = 0
        for s in self.specs:
            if s.kind == "pressure" and self._in_window(s, tick):
                held += s.blocks
        return held

    def preempt_signals(self, tick: int) -> int:
        """Host-preemption victims demanded this tick (consumes the spec)."""
        n = 0
        for s in self.specs:
            if s.kind == "preempt" and s.tick == tick \
                    and self._ttl[id(s)] > 0:
                self._ttl[id(s)] = 0
                self.fired.append((tick, "preempt", None))
                n += s.count
        return n

    # --------------------------------------------------- dispatch-level hooks
    def check_tick(self, tick: int, rids) -> None:
        """Called before a fused dispatch with the participating rids.
        Consumes and raises for an armed ``tick_error`` at this tick, or
        for any armed ``poison`` whose target is among ``rids`` (ALL
        matching poisons are charged, so one bisection can find several
        culprits)."""
        self._roll_tick(tick)
        rids = set(rids)
        poisoned = False
        for s in self.specs:
            if s.kind == "tick_error" and s.tick == tick \
                    and self._ttl[id(s)] > 0:
                self._ttl[id(s)] = 0
                self.fired.append((tick, "tick_error", None))
                raise FaultInjected("tick_error", tick, attributable=False)
            if s.kind == "poison" and s.rid in rids and self._armed(s, tick):
                self._ttl[id(s)] -= 1
                self._fired_now.append(s)
                self.fired.append((tick, "poison", s.rid))
                poisoned = True
        if poisoned:
            raise FaultInjected("poison", tick, attributable=True)

    def corrupt_logits(self, tick: int, rid_rows: dict, logits) -> list:
        """Overwrite the logits rows of armed ``nan_logits`` targets with
        NaN in place; returns the corrupted rids.  ``rid_rows`` maps
        rid -> row index into ``logits``."""
        self._roll_tick(tick)
        hit = []
        for s in self.specs:
            if s.kind == "nan_logits" and s.rid in rid_rows \
                    and self._armed(s, tick):
                self._ttl[id(s)] -= 1
                self._fired_now.append(s)
                self.fired.append((tick, "nan_logits", s.rid))
                logits[rid_rows[s.rid]] = np.nan
                hit.append(s.rid)
        return hit

    def probe(self, rids) -> bool:
        """Blame-bisection oracle: would a tick restricted to ``rids``
        have manifested the fault that just fired?  True = the subset is
        poisoned.  Never consumes ttl — recovery may probe freely."""
        rids = set(rids)
        return any(s.rid in rids for s in self._fired_now)

    # -------------------------------------------------------------- reporting
    def to_dict(self) -> dict:
        return {"name": self.name, "seed": self.seed,
                "specs": [s.to_dict() for s in self.specs],
                "fired": list(self.fired)}

    def __repr__(self):
        return (f"FaultPlan(name={self.name!r}, seed={self.seed}, "
                f"specs={len(self.specs)}, fired={len(self.fired)})")


#: preset name -> rng stream salt (stable across preset additions)
_PRESET_SALT = {"one-poison": 1, "transient": 2, "storm": 3, "pressure": 4}

#: named presets for the launch driver's ``--chaos`` flag
PRESETS = tuple(sorted(_PRESET_SALT))


def get_plan(spec, seed: int = 0) -> FaultPlan | None:
    """Resolve ``None`` | preset name | :class:`FaultPlan` instance."""
    if spec is None or isinstance(spec, FaultPlan):
        return spec
    return FaultPlan.preset(spec, seed=seed)
