"""Sharded, manifest-driven checkpointing with atomic publish.

Layout:  <dir>/step_<N>/
           manifest.json        — tree structure, shapes, dtypes, step
           shard_p<proc>.npz    — this process's leaf arrays
           COMMIT               — written last; a checkpoint without COMMIT
                                  is incomplete and ignored on restore

Writes go to ``step_<N>.tmp`` and are renamed into place only after COMMIT —
a crash mid-save can never corrupt the latest restorable state.  An optional
async mode snapshots to host memory and writes on a background thread so the
train loop is blocked only for the device→host copy.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _keys(tree) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _leaf in paths]


def save(ckpt_dir: str, step: int, state: dict, process_index: int = 0,
         async_: bool = False) -> str:
    """state: arbitrary pytree of arrays (params/opt/metadata)."""
    leaves, _ = _flatten(state)
    keys = _keys(state)
    host_leaves = [np.asarray(x) for x in leaves]      # device→host snapshot

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_p{process_index}.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "keys": keys,
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "n_processes": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return os.path.join(ckpt_dir, f"step_{step:08d}")
    return _write()


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *restorable* step: skips ``.tmp`` dirs (in-flight saves),
    dirs without COMMIT (crashed mid-save), and anything that merely looks
    like a checkpoint dir (``step_garbage``, stray files)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        try:
            step = int(name.split("_", 1)[1])
        except ValueError:
            continue
        if os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
            steps.append(step)
    return max(steps) if steps else None


def _validate(manifest: dict, like, path: str) -> None:
    """Refuse to restore a checkpoint whose tree disagrees with ``like``."""
    want_keys = _keys(like)
    got_keys = list(manifest.get("keys", []))
    if got_keys != want_keys:
        missing = [k for k in want_keys if k not in got_keys]
        extra = [k for k in got_keys if k not in want_keys]
        raise ValueError(
            f"{path}: checkpoint tree mismatch — "
            f"missing from checkpoint: {missing[:5]}, "
            f"unexpected in checkpoint: {extra[:5]}"
            + (" (keys agree but order differs)"
               if not missing and not extra else ""))
    leaves, _ = _flatten(like)
    bad = []
    for key, shape, dtype, leaf in zip(want_keys, manifest.get("shapes", []),
                                       manifest.get("dtypes", []), leaves):
        want_shape = list(np.shape(leaf))
        want_dtype = str(leaf.dtype) if hasattr(leaf, "dtype") \
            else str(np.asarray(leaf).dtype)
        if list(shape) != want_shape or str(dtype) != want_dtype:
            bad.append(f"{key}: checkpoint {tuple(shape)}/{dtype} "
                       f"vs target {tuple(want_shape)}/{want_dtype}")
    if bad:
        raise ValueError(f"{path}: leaf mismatch — " + "; ".join(bad[:5])
                         + (f" (+{len(bad) - 5} more)" if len(bad) > 5
                            else ""))


def restore(ckpt_dir: str, like: dict, step: int | None = None,
            shardings=None, process_index: int = 0) -> tuple:
    """Returns (step, state) with arrays placed per ``shardings`` (or host).

    The manifest is validated against ``like`` before any array leaves the
    shard file: a checkpoint saved from a different model (missing/extra
    keys, mismatched shapes or dtypes) fails with an error naming the
    offending leaves instead of silently unflattening garbage.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_p{process_index}.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(manifest["keys"]))]
    _, treedef = _flatten(like)
    _validate(manifest, like, path)
    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    state = jax.tree.unflatten(treedef, leaves)
    return step, state
